//! Topology explorer: sweep the five preset fabrics (paper Fig 9/10) at a
//! chosen scale and print normalized bandwidth + hop statistics.
//!
//! Run: `cargo run --release --example topology_explorer -- [--n 8]`

use esf::experiments::topology::{run_cell, PORT_GBPS};
use esf::interconnect::{build, LinkCfg, Routing, TopologyKind};
use esf::util::args::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let n = args.u64_or("n", 8) as usize;
    println!("N = {n} requesters + {n} memories (system scale {})", 2 * n);
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>12}",
        "topology", "switches", "avg hops", "max hops", "bw (x port)"
    );
    for kind in TopologyKind::ALL {
        let fabric = build(kind, n, LinkCfg::default());
        let routing = Routing::build_bfs(&fabric.topo);
        let mut sum = 0u64;
        let mut cnt = 0u64;
        let mut max = 0u16;
        for &r in &fabric.requesters {
            for &m in &fabric.memories {
                let d = routing.dist(r, m);
                sum += d as u64;
                cnt += 1;
                max = max.max(d);
            }
        }
        let bw = run_cell(kind, n, true);
        println!(
            "{:<16} {:>10} {:>10.2} {:>10} {:>12.2}",
            kind.name(),
            fabric.switches.len(),
            sum as f64 / cnt as f64,
            max,
            bw
        );
    }
    println!("\n(port bandwidth = {PORT_GBPS} GB/s; paper: chain/tree ~1x, ring ~2x, SL ~N/2, FC ~N)");
}
