//! Device-managed coherence study: sweep the snoop-filter victim policies
//! (paper Fig 14) and the InvBlk run lengths (Fig 15) on a skewed
//! workload, printing absolute and FIFO-normalized results.
//!
//! Run: `cargo run --release --example snoop_filter_study`

use esf::devices::VictimPolicy;
use esf::experiments::invblk::run_len;
use esf::experiments::snoopfilter::run_policy;

fn main() {
    println!("victim policy sweep (skewed 90/10 workload, SF = cache size):");
    println!(
        "{:<8} {:>12} {:>12} {:>14}",
        "policy", "bw (GB/s)", "lat (ns)", "invalidations"
    );
    let mut base_inv = 0;
    for policy in VictimPolicy::BASIC {
        let r = run_policy(policy, true);
        if policy == VictimPolicy::Fifo {
            base_inv = r.invalidations;
        }
        println!(
            "{:<8} {:>12.2} {:>12.1} {:>10} ({:>+5.1}%)",
            policy.name(),
            r.bandwidth_gbps,
            r.avg_latency_ns,
            r.invalidations,
            (r.invalidations as f64 - base_inv as f64) / base_inv.max(1) as f64 * 100.0
        );
    }

    println!("\nInvBlk length sweep (two streaming requesters):");
    println!(
        "{:<6} {:>12} {:>12} {:>16} {:>12}",
        "len", "bw (GB/s)", "lat (ns)", "inv wait (ns)", "BISnp msgs"
    );
    for len in 1..=4u8 {
        let r = run_len(len, true);
        println!(
            "{:<6} {:>12.2} {:>12.1} {:>16.1} {:>12}",
            len, r.bandwidth_gbps, r.avg_latency_ns, r.avg_inv_wait_ns, r.bisnp_sent
        );
    }
}
