//! CXL memory-pool serving scenario: a rack-level disaggregated pool
//! (the workload class the paper's intro motivates) with heterogeneous
//! endpoints — DDR5 expanders for the hot tier and CXL-SSDs for the
//! capacity tier — serving hosts with different access profiles over a
//! spine-leaf PBR fabric with adaptive routing.
//!
//! Run: `cargo run --release --example memory_pool_serving`

use esf::config::{build_on_fabric, BackendKind, SystemCfg};
use esf::devices::{Interleave, Pattern, Requester};
use esf::dram::DramCfg;
use esf::engine::time::ns;
use esf::interconnect::{build, LinkCfg, Routing, Strategy, TopologyKind};
use esf::metrics::aggregate;
use esf::ssd::SsdCfg;

fn main() {
    let n = 8;
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, n);
    cfg.strategy = Strategy::Adaptive;
    cfg.queue_capacity = 32;
    cfg.requests_per_endpoint = 600;
    cfg.warmup_fraction = 0.2;

    // Build the fabric, then assign backends: endpoints 0..5 are DDR5
    // expanders (hot tier), 6..7 are CXL-SSD capacity devices.
    let fabric = build(cfg.topology, n, LinkCfg::default());
    let routing = Routing::build_bfs(&fabric.topo);
    let dram_mems: Vec<_> = fabric.memories[..6].to_vec();
    let ssd_mems: Vec<_> = fabric.memories[6..].to_vec();

    // Host profiles: latency-sensitive OLTP hosts hit the hot tier;
    // throughput-oriented analytics hosts stream the capacity tier.
    let dram_targets = dram_mems.clone();
    let ssd_targets = ssd_mems.clone();
    let mut sys = build_on_fabric(&cfg, fabric, routing, &mut |idx, mut rc| {
        rc.warmup_requests = 0; // mixed-speed tiers: measure from t=0
        if idx < 6 {
            rc.endpoints = dram_targets.clone();
            rc.pattern = Pattern::Skewed { hot_frac: 0.05, hot_prob: 0.8 };
            rc.issue_interval = ns(6.0);
            rc.read_ratio = 0.7;
        } else {
            rc.endpoints = ssd_targets.clone();
            rc.pattern = Pattern::Stream;
            rc.issue_interval = ns(400.0); // SSD-paced
            rc.read_ratio = 0.9;
            rc.interleave = Interleave::Page(64);
            rc.total_requests /= 8;
        }
        rc
    });

    // Patch backends per tier: rebuild memdevs is not needed — the config
    // template applied DRAM everywhere; re-register SSD endpoints.
    // (Simplest: two separate configs; here we re-create components.)
    for &m in &ssd_mems {
        let mc = {
            let mut c = esf::devices::MemDevCfg::new(m);
            c.ctrl_time = ns(40.0);
            c.port_delay = ns(25.0);
            c
        };
        let backend = BackendKind::Ssd(SsdCfg::default()).instantiate(9);
        *sys.engine.component_mut::<esf::devices::MemDev>(m).unwrap() =
            esf::devices::MemDev::new(mc, backend);
    }
    for &m in &dram_mems {
        let mc = {
            let mut c = esf::devices::MemDevCfg::new(m);
            c.ctrl_time = ns(40.0);
            c.port_delay = ns(25.0);
            c
        };
        let backend = BackendKind::Dram(DramCfg::ddr5_4800()).instantiate(m as u64);
        *sys.engine.component_mut::<esf::devices::MemDev>(m).unwrap() =
            esf::devices::MemDev::new(mc, backend);
    }

    let events = sys.engine.run(u64::MAX);
    println!("pool served: {events} events");
    let a = aggregate(&sys);
    println!("aggregate: {:.2} GB/s, avg {:.0} ns", a.bandwidth_gbps(), a.avg_latency_ns());
    println!("\nper-host:");
    for (i, &r) in sys.requesters.iter().enumerate() {
        let rq: &Requester = sys.engine.component(r).unwrap();
        let tier = if i < 6 { "hot/DRAM" } else { "cap/SSD" };
        println!(
            "  host {i} ({tier}): {} reqs, avg {:.0} ns",
            rq.stats.completed,
            rq.stats.avg_latency_ns()
        );
    }
    println!("memory_pool_serving OK");
}
