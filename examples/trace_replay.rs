//! Trace I/O round trip: generate a synthetic real-world trace, save it in
//! the CSV trace format, load it back, replay it through a CXL system,
//! and compute per-window statistics through the AOT Pallas tracestats
//! kernel (PJRT) with the native fallback.
//!
//! Run: `cargo run --release --example trace_replay -- [--workload silo]`

use esf::experiments::realworld::{corr_slope, window_stats};
use esf::util::args::Args;
use esf::workloads::{RealWorkload, Trace};

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let name = args.str_or("workload", "silo");
    let workload = RealWorkload::ALL
        .into_iter()
        .find(|w| w.name() == name)
        .unwrap_or(RealWorkload::Silo);

    let trace = workload.generate(50_000, 11);
    let dir = std::env::temp_dir().join("esf_traces");
    std::fs::create_dir_all(&dir).expect("mkdir");
    let path = dir.join(format!("{}.csv", trace.name));
    trace.save(&path).expect("save trace");
    println!("saved {} accesses to {}", trace.len(), path.display());

    let back = Trace::load(&path).expect("load trace");
    assert_eq!(back.ops, trace.ops, "round trip must be lossless");
    println!(
        "loaded back: write ratio {:.3}, mix degree {:.3}",
        back.write_ratio(),
        back.mix_degree()
    );

    // Windowed statistics via the AOT kernel (PJRT) or native fallback.
    let stats = window_stats(&back, 1000);
    println!("windows: {}", stats.len());
    let mixes: Vec<f64> = stats
        .iter()
        .map(|&(r, w, _)| (r.min(w)) as f64 / 1000.0)
        .collect();
    let avg_mix = mixes.iter().sum::<f64>() / mixes.len().max(1) as f64;
    println!("avg window mix degree: {avg_mix:.3}");
    let idx: Vec<f64> = (0..mixes.len()).map(|i| i as f64).collect();
    let (corr, _) = corr_slope(&idx, &mixes);
    println!("mix drift over trace (corr vs window index): {corr:.3}");
    println!("trace_replay OK");
}
