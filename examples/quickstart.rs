//! End-to-end quickstart: the full three-layer stack on a real workload.
//!
//! 1. Loads the AOT-compiled Pallas min-plus APSP kernel (built once by
//!    `make artifacts`) through PJRT and computes the fabric routing
//!    tables from it (falls back to native BFS without artifacts).
//! 2. Builds a 16-node spine-leaf CXL system (8 hosts, 8 type-3 memory
//!    expanders with DDR5 timing, PBR switches, full-duplex PCIe links).
//! 3. Replays a real-ish workload (redis/YCSB profile) and reports the
//!    paper's headline metrics: aggregate bandwidth, latency breakdown
//!    by hop count, and bus utility.
//!
//! Run: `cargo run --release --example quickstart`

use esf::config::{build_system_with, BackendKind, RoutingSource, SystemCfg};
use esf::devices::Pattern;
use esf::dram::DramCfg;
use esf::engine::time::ns;
use esf::interconnect::TopologyKind;
use esf::metrics::{aggregate, endpoint_bus_utility, hop_breakdown};
use esf::workloads::RealWorkload;
use std::sync::Arc;

fn main() {
    // --- Layer 1/2 via PJRT: routing tables from the Pallas APSP kernel.
    let routing_src = match esf::runtime::Runtime::load_default() {
        Ok(rt) => {
            println!(
                "PJRT runtime up: APSP artifacts for fabrics of {:?} nodes",
                rt.apsp_sizes()
            );
            RoutingSource::Pjrt
        }
        Err(e) => {
            println!("PJRT unavailable ({e}); using native BFS routing");
            RoutingSource::Native
        }
    };

    // --- Layer 3: the simulated CXL system.
    let trace = RealWorkload::Redis.generate(120_000, 7);
    println!(
        "workload: {} ({} accesses, write ratio {:.2}, mix degree {:.2})",
        trace.name,
        trace.len(),
        trace.write_ratio(),
        trace.mix_degree()
    );
    let ops = Arc::new(trace.ops);

    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 8);
    cfg.backend = BackendKind::Dram(DramCfg::ddr5_4800());
    cfg.issue_interval = ns(2.0);
    cfg.queue_capacity = 32;
    cfg.requests_per_endpoint = 1500;
    cfg.warmup_fraction = 0.25;

    let mut sys = build_system_with(&cfg, routing_src, |idx, mut rc| {
        rc.pattern = Pattern::Trace(ops.clone());
        rc.seed ^= idx as u64;
        rc
    });

    let events = sys.engine.run(u64::MAX);
    let a = aggregate(&sys);
    println!("\n=== results ===");
    println!("events processed : {events}");
    println!("requests         : {}", a.completed);
    println!("aggregate bw     : {:.2} GB/s", a.bandwidth_gbps());
    println!("avg latency      : {:.1} ns", a.avg_latency_ns());
    println!("endpoint bus util: {:.2}", endpoint_bus_utility(&sys));
    println!("\nlatency by hop count:");
    for (hops, n, lat, q, sw, bus, dev) in hop_breakdown(&sys) {
        println!(
            "  {hops} hops: {n:>6} reqs  {lat:>7.1} ns  (queue {q:.1}, switch {sw:.1}, bus {bus:.1}, device {dev:.1})"
        );
    }
    assert!(a.completed > 0, "system must complete requests");
    println!("\nquickstart OK");
}
