//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The offline crate set this repository builds against has no registry
//! access, so the subset of `anyhow` the codebase actually uses is
//! implemented here: a message-carrying [`Error`], the [`anyhow!`] and
//! [`bail!`] macros, the [`Context`] extension trait, and the [`Result`]
//! alias. Like the real crate, `Error` deliberately does NOT implement
//! `std::error::Error` so the blanket `From<E: std::error::Error>` impl
//! (which powers `?` conversions) cannot conflict with the reflexive
//! `From<Error> for Error`.

use std::fmt;

/// A boxed-string error: the originating message plus any context frames
/// prepended by [`Context::context`] / [`Context::with_context`].
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prepend a context frame: `context: original`.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible result (subset of `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error {
            msg: format!("{context}: {e}"),
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error {
            msg: format!("{}: {e}", f()),
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error {
            msg: context.to_string(),
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error {
            msg: f().to_string(),
        })
    }
}

/// Build an [`Error`] from a format string or a printable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/esf")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_and_context() {
        let e = anyhow!("code {}", 7);
        assert_eq!(e.to_string(), "code 7");
        let x = 3;
        let e = anyhow!("inline {x}");
        assert_eq!(e.to_string(), "inline 3");
        let r: Result<()> = Err(anyhow!("inner"));
        let r = r.context("outer");
        assert_eq!(r.unwrap_err().to_string(), "outer: inner");
        let o: Option<u32> = None;
        assert!(o.with_context(|| "missing").is_err());
    }

    #[test]
    fn bail_returns() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("nope {}", 1);
            }
            Ok(5)
        }
        assert_eq!(f(false).unwrap(), 5);
        assert_eq!(f(true).unwrap_err().to_string(), "nope 1");
    }
}
