//! Offline stand-in for the `xla` PJRT bindings crate.
//!
//! The real crate links libxla/PJRT and executes compiled HLO; the
//! offline build environment cannot ship that, but `esf`'s `pjrt` cargo
//! feature still needs to **compile** against the bindings so CI can
//! guard the `runtime::pjrt` executor path (ROADMAP item). This shim
//! reproduces the API subset `esf::runtime::pjrt` uses with honest
//! semantics:
//!
//!  * host-side types (`Literal`, `HloModuleProto`, `XlaComputation`)
//!    behave for real — data is stored, reshape validates shapes, HLO
//!    text is read from disk;
//!  * the device side (`PjRtClient::cpu`) reports the runtime as
//!    unavailable, so `Runtime::load` fails with a clear message and
//!    every caller takes its graceful native-Rust fallback — exactly the
//!    behavior of a missing `artifacts/` directory.
//!
//! Swap in the real bindings (same crate name) to execute the AOT Pallas
//! kernels; nothing in `esf` changes.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side tensor literal (f32 only — all ESF kernels are f32).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(v: &[f32]) -> Literal {
        Literal {
            data: v.to_vec(),
            dims: vec![v.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "cannot reshape {} elements to {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Ok(self.clone())
    }

    pub fn to_vec(&self) -> Result<Vec<f32>> {
        Ok(self.data.clone())
    }
}

/// Parsed HLO module (text form; the real crate reassigns instruction
/// ids — the shim only has to carry the text to `compile`).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation {
    _hlo_text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _hlo_text: proto.text.clone(),
        }
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the shim: there is no PJRT runtime to host a CPU
    /// client offline. Callers must treat this like missing artifacts.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(
            "vendored xla shim: no PJRT runtime in the offline build \
             (link the real xla bindings crate to execute AOT artifacts)"
                .into(),
        ))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error("vendored xla shim cannot compile HLO".into()))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error("vendored xla shim has no device buffers".into()))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error("vendored xla shim cannot execute".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape_validation() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("shim has no runtime");
        assert!(format!("{err}").contains("shim"));
    }
}
