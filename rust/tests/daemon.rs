//! `esfd` daemon integration: the tentpole contracts end-to-end over a
//! real Unix socket against an in-process daemon.
//!
//!  * **Byte identity** — an attached client's reassembled output equals
//!    one-shot `esf sweep` on the same grid, byte for byte (table, CSV,
//!    and JSON dump).
//!  * **Cache-served repeats** — resubmitting the same grid completes
//!    with every cell served from the shared cache, no re-simulation.
//!  * **Admission control** — concurrent jobs split the machine budget
//!    and the scheduler's peak counters prove it was never exceeded.
//!  * **Server-side validation** — a malformed grid is rejected at the
//!    socket with rule ids and `$.grid`-rooted loci, and the daemon
//!    keeps serving afterwards.

use esf::server::{client, serve, DaemonCfg};
use esf::sweep::{results_json, results_table, run_scenarios, GridSpec};
use esf::util::json::Json;
use std::path::PathBuf;
use std::thread::JoinHandle;

const GRID_A: &str = r#"{
    "base": {
        "link": {"bandwidth_gbps": 32, "header_bytes": 0},
        "requester": {"requests_per_endpoint": 40,
                      "issue_interval_ns": 2,
                      "queue_capacity": 32},
        "memory": {"backend": "fixed", "latency_ns": 20}
    },
    "sweep": {
        "topology": ["ring", "spine-leaf"],
        "read_ratio": [1.0, 0.5]
    }
}"#;

const GRID_B: &str = r#"{
    "base": {
        "link": {"bandwidth_gbps": 32, "header_bytes": 0},
        "requester": {"requests_per_endpoint": 40,
                      "issue_interval_ns": 2,
                      "queue_capacity": 32},
        "memory": {"backend": "fixed", "latency_ns": 20}
    },
    "sweep": {
        "topology": ["chain", "fc"],
        "scale": [4, 8]
    }
}"#;

struct TestDaemon {
    socket: PathBuf,
    cache_dir: PathBuf,
    handle: Option<JoinHandle<anyhow::Result<()>>>,
}

impl TestDaemon {
    /// Start an in-process daemon on a fresh socket and wait until it
    /// answers a status request.
    fn start(tag: &str, budget: usize, job_width: usize) -> TestDaemon {
        let base = std::env::temp_dir().join(format!("esfd-it-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        let socket = base.join("esfd.sock");
        let cache_dir = base.join("cache");
        let cfg = DaemonCfg {
            socket: socket.clone(),
            cache_dir: cache_dir.clone(),
            budget,
            job_width,
        };
        let handle = std::thread::spawn(move || serve(cfg));
        for _ in 0..200 {
            if client::status(&socket, None).is_ok() {
                return TestDaemon {
                    socket,
                    cache_dir,
                    handle: Some(handle),
                };
            }
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
        panic!("daemon on {} never became ready", socket.display());
    }

    fn stop(mut self) {
        client::shutdown(&self.socket).expect("shutdown accepted");
        let serve_result = self.handle.take().unwrap().join().expect("serve thread joins");
        serve_result.expect("daemon exits cleanly");
        assert!(!self.socket.exists(), "socket removed on shutdown");
        let base = self.socket.parent().unwrap().to_path_buf();
        let _ = std::fs::remove_dir_all(base);
    }
}

fn job_field(status: &Json, id: &str, field: &str) -> u64 {
    status
        .get("jobs")
        .and_then(Json::as_arr)
        .and_then(|jobs| jobs.iter().find(|j| j.str_or("id", "") == id))
        .map(|j| j.u64_or(field, u64::MAX))
        .unwrap_or_else(|| panic!("job {id} missing from status"))
}

#[test]
fn attach_is_byte_identical_to_one_shot_and_repeat_is_cache_served() {
    // One-shot ground truth through the exact code path `esf sweep` uses.
    let grid = GridSpec::from_json_str(GRID_A).unwrap();
    let cells = grid.scenarios.len();
    let baseline = run_scenarios(grid.scenarios, 2);
    let want_table = results_table(&baseline).render();
    let want_csv = results_table(&baseline).to_csv();
    let want_json = results_json(&baseline).to_string();

    let d = TestDaemon::start("bytes", 2, 0);
    let grid_doc = Json::parse(GRID_A).unwrap();

    // First submission simulates every cell.
    let resp = client::submit(&d.socket, &grid_doc).unwrap();
    let job1 = resp.str_or("job", "").to_string();
    assert!(job1.starts_with("j0-"), "deterministic first id, got {job1}");
    assert_eq!(resp.u64_or("cells", 0) as usize, cells);
    let mut streamed = Vec::new();
    let rows = client::attach(&d.socket, &job1, |idx, cached, r| {
        streamed.push((idx, cached, r.label.clone()));
    })
    .unwrap();
    assert_eq!(results_table(&rows).render(), want_table, "attach table != one-shot");
    assert_eq!(results_table(&rows).to_csv(), want_csv, "attach CSV != one-shot");
    assert_eq!(results_json(&rows).to_string(), want_json, "attach JSON != one-shot");
    // Every cell streamed exactly once, labels matching its grid slot.
    streamed.sort();
    let want_labels: Vec<(usize, bool, String)> = baseline
        .iter()
        .enumerate()
        .map(|(i, r)| (i, false, r.label.clone()))
        .collect();
    assert_eq!(streamed, want_labels, "fresh cells must stream uncached");

    // Repeat submission (any client, same content): served entirely from
    // the shared cache, byte-identical again, and the id is predictable —
    // next sequence number, same grid hash suffix.
    let resp2 = client::submit(&d.socket, &grid_doc).unwrap();
    let job2 = resp2.str_or("job", "").to_string();
    assert!(job2.starts_with("j1-"), "second id is j1-<hash>, got {job2}");
    assert_eq!(
        job1.split('-').nth(1),
        job2.split('-').nth(1),
        "same grid content must hash to the same id suffix"
    );
    let mut cached_flags = Vec::new();
    let rows2 = client::attach(&d.socket, &job2, |_, c, _| cached_flags.push(c)).unwrap();
    assert_eq!(results_table(&rows2).render(), want_table);
    assert_eq!(cached_flags.len(), cells);
    assert!(
        cached_flags.iter().all(|&c| c),
        "repeat submission must be fully cache-served, got {cached_flags:?}"
    );
    let status = client::status(&d.socket, Some(&job2)).unwrap();
    assert_eq!(job_field(&status, &job2, "cached_cells") as usize, cells);
    assert_eq!(job_field(&status, &job2, "done_cells") as usize, cells);
    assert!(d.cache_dir.is_dir(), "daemon created the shared cache dir");
    d.stop();
}

#[test]
fn malformed_submission_is_rejected_with_loci_and_daemon_survives() {
    let d = TestDaemon::start("reject", 2, 0);
    // Unknown sweep axis: rejected server-side by the ESF-C016 pass with
    // the grid rule id re-rooted under $.grid.
    let bad = Json::parse(r#"{"sweep": {"warp": [1, 2]}}"#).unwrap();
    let err = client::submit(&d.socket, &bad).expect_err("bad grid must be rejected");
    let text = err.to_string();
    assert!(text.contains("ESF-C010"), "missing rule id: {text}");
    assert!(text.contains("$.grid.sweep.warp"), "missing locus: {text}");
    // Nothing was queued and the daemon still serves.
    let status = client::status(&d.socket, None).unwrap();
    let jobs = status.get("jobs").and_then(Json::as_arr).unwrap();
    assert!(jobs.is_empty(), "rejected submissions must not queue");
    // Attaching to a job that never existed is an error, not a hang.
    let err = client::attach(&d.socket, "j9-0000000000000000", |_, _, _| {})
        .expect_err("unknown job");
    assert!(err.to_string().contains("unknown job"), "{err}");
    // A healthy submission still works on the same daemon afterwards.
    let ok = client::submit(&d.socket, &Json::parse(GRID_A).unwrap()).unwrap();
    client::attach(&d.socket, ok.str_or("job", ""), |_, _, _| {}).unwrap();
    d.stop();
}

#[test]
fn concurrent_jobs_split_the_budget_and_never_exceed_it() {
    // Budget 4, job width 2: two jobs admitted concurrently, each granted
    // exactly 2 threads; the scheduler's peak counters prove the budget
    // held the whole time.
    let d = TestDaemon::start("budget", 4, 2);
    let a = client::submit(&d.socket, &Json::parse(GRID_A).unwrap()).unwrap();
    let b = client::submit(&d.socket, &Json::parse(GRID_B).unwrap()).unwrap();
    let (id_a, id_b) = (a.str_or("job", "").to_string(), b.str_or("job", "").to_string());
    assert_ne!(
        id_a.split('-').nth(1),
        id_b.split('-').nth(1),
        "different grids must hash differently"
    );
    // Attach to both from separate threads while they run.
    let sock_a = d.socket.clone();
    let sock_b = d.socket.clone();
    let (ja, jb) = (id_a.clone(), id_b.clone());
    let ta = std::thread::spawn(move || client::attach(&sock_a, &ja, |_, _, _| {}).unwrap());
    let tb = std::thread::spawn(move || client::attach(&sock_b, &jb, |_, _, _| {}).unwrap());
    let rows_a = ta.join().unwrap();
    let rows_b = tb.join().unwrap();

    // Both jobs produce their own one-shot-identical output even while
    // sharing the machine.
    let scen_a = GridSpec::from_json_str(GRID_A).unwrap().scenarios;
    let scen_b = GridSpec::from_json_str(GRID_B).unwrap().scenarios;
    let want_a = results_table(&run_scenarios(scen_a, 1)).to_csv();
    let want_b = results_table(&run_scenarios(scen_b, 1)).to_csv();
    assert_eq!(results_table(&rows_a).to_csv(), want_a);
    assert_eq!(results_table(&rows_b).to_csv(), want_b);

    let status = client::status(&d.socket, None).unwrap();
    let budget = status.u64_or("budget", 0);
    assert_eq!(budget, 4);
    assert!(status.u64_or("peak_in_use", u64::MAX) <= budget, "budget exceeded: {status}");
    assert!(status.u64_or("peak_running", 0) >= 1);
    assert!(status.u64_or("peak_running", u64::MAX) <= 2);
    assert_eq!(status.u64_or("in_use", u64::MAX), 0, "grants released after completion");
    for id in [&id_a, &id_b] {
        assert!(job_field(&status, id, "granted") <= 2, "job width exceeded");
    }
    d.stop();
}
