//! Scenario-axis goldens: one digest-pinned scenario per new sweep axis
//! value (access pattern x media backend x snoop-filter policy), plus the
//! grid-level byte-identity contracts — jobs=1 vs jobs=N, and fresh vs
//! cache-resumed runs on a 3-axis grid.
//!
//! The per-axis digests are pinned through the shared recorded-constant
//! store (`tests/golden_digest.txt`, see `tests/common/mod.rs`): CI
//! records them once with ESF_GOLDEN=record and enforces them with
//! ESF_GOLDEN=require, so every new axis value's full observable output
//! is locked byte-for-byte. The self-consistency tests below need no
//! constants and guard the contracts on any machine.

mod common;

use common::{check_recorded, run_digest};
use esf::config::{BackendKind, SystemCfg};
use esf::devices::{Pattern, VictimPolicy};
use esf::dram::DramCfg;
use esf::engine::time::ns;
use esf::interconnect::TopologyKind;
use esf::ssd::SsdCfg;
use esf::sweep::{
    results_json, run_scenarios, run_scenarios_cached, GridSpec, ScenarioResult, SweepCache,
};

/// Small-but-busy base scenario for the per-axis digests.
fn axis_base() -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 4);
    cfg.seed = 99;
    cfg.read_ratio = 0.8;
    cfg.queue_capacity = 16;
    cfg.issue_interval = ns(3.0);
    cfg.requests_per_endpoint = 150;
    cfg.warmup_fraction = 0.2;
    cfg.footprint_lines = 2048;
    cfg
}

fn pattern_cfg(p: Pattern) -> SystemCfg {
    let mut cfg = axis_base();
    cfg.pattern = p;
    cfg
}

fn backend_cfg(b: BackendKind) -> SystemCfg {
    let mut cfg = axis_base();
    cfg.backend = b;
    cfg
}

/// Coherent config exercising the DCOH: small caches + small filter so
/// the victim policy actually shapes the BISnp traffic.
fn sf_cfg(policy: VictimPolicy) -> SystemCfg {
    let mut cfg = axis_base();
    cfg.pattern = Pattern::Skewed {
        hot_frac: 0.1,
        hot_prob: 0.9,
    };
    cfg.footprint_lines = 1024;
    cfg.cache_lines = 256;
    cfg.snoop_filter = Some((48, policy));
    cfg
}

fn pattern_digests() -> Vec<(&'static str, u64)> {
    vec![
        ("axis_pattern_sequential", run_digest(&pattern_cfg(Pattern::Stream), false)),
        ("axis_pattern_random", run_digest(&pattern_cfg(Pattern::Random), false)),
        ("axis_pattern_zipfian", run_digest(&pattern_cfg(Pattern::Zipf { theta: 0.99 }), false)),
        ("axis_pattern_pointer_chase", run_digest(&pattern_cfg(Pattern::PointerChase), false)),
    ]
}

fn backend_digests() -> Vec<(&'static str, u64)> {
    vec![
        ("axis_backend_fixed", run_digest(&backend_cfg(BackendKind::Fixed(45.0)), false)),
        (
            "axis_backend_dram",
            run_digest(&backend_cfg(BackendKind::Dram(DramCfg::ddr5_4800())), false),
        ),
        ("axis_backend_hbm", run_digest(&backend_cfg(BackendKind::Dram(DramCfg::hbm2())), false)),
        ("axis_backend_ssd", run_digest(&backend_cfg(BackendKind::Ssd(SsdCfg::default())), false)),
    ]
}

fn sf_digests() -> Vec<(&'static str, u64)> {
    vec![
        ("axis_sf_fifo", run_digest(&sf_cfg(VictimPolicy::Fifo), false)),
        ("axis_sf_lru", run_digest(&sf_cfg(VictimPolicy::Lru), false)),
        ("axis_sf_lfi", run_digest(&sf_cfg(VictimPolicy::Lfi), false)),
        ("axis_sf_lifo", run_digest(&sf_cfg(VictimPolicy::Lifo), false)),
        ("axis_sf_mru", run_digest(&sf_cfg(VictimPolicy::Mru), false)),
        ("axis_sf_blocklen", run_digest(&sf_cfg(VictimPolicy::BlockLen { max_len: 4 }), false)),
    ]
}

/// One digest per new axis value, pinned against the recorded constants.
#[test]
fn axis_digests_match_recorded_constants() {
    let mut entries = pattern_digests();
    entries.extend(backend_digests());
    entries.extend(sf_digests());
    check_recorded(&entries);
}

/// The digests must be *sensitive* to the axes they pin: each access
/// pattern and each media backend produces a different observable run
/// (guards against an axis value silently mapping to the wrong config).
#[test]
fn axis_values_change_observable_output() {
    for set in [pattern_digests(), backend_digests()] {
        for (i, (name_a, dig_a)) in set.iter().enumerate() {
            for (name_b, dig_b) in set.iter().skip(i + 1) {
                assert_ne!(dig_a, dig_b, "'{name_a}' and '{name_b}' produced identical runs");
            }
        }
    }
    // Repeat runs stay deterministic per policy (cross-policy equality is
    // not asserted: distinct policies can legitimately coincide on some
    // traffic, but each must reproduce itself exactly).
    for (key, val) in sf_digests() {
        let again = match key {
            "axis_sf_fifo" => run_digest(&sf_cfg(VictimPolicy::Fifo), false),
            "axis_sf_lru" => run_digest(&sf_cfg(VictimPolicy::Lru), false),
            "axis_sf_lfi" => run_digest(&sf_cfg(VictimPolicy::Lfi), false),
            "axis_sf_lifo" => run_digest(&sf_cfg(VictimPolicy::Lifo), false),
            "axis_sf_mru" => run_digest(&sf_cfg(VictimPolicy::Mru), false),
            _ => run_digest(&sf_cfg(VictimPolicy::BlockLen { max_len: 4 }), false),
        };
        assert_eq!(val, again, "{key} not repeat-deterministic");
    }
}

/// The new axes must preserve the ladder-vs-heap scheduler equivalence
/// (the PR 2 A/B guard) on the heaviest new machinery: the SSD backend
/// and the LFI bucket index.
#[test]
fn new_axis_scenarios_match_heap_reference() {
    for cfg in [
        backend_cfg(BackendKind::Ssd(SsdCfg::default())),
        sf_cfg(VictimPolicy::Lfi),
        pattern_cfg(Pattern::PointerChase),
    ] {
        assert_eq!(
            run_digest(&cfg, false),
            run_digest(&cfg, true),
            "ladder and heap schedulers diverged on a new-axis scenario"
        );
    }
}

/// The 3-axis grid (pattern x backend x sf_policy) used by the
/// byte-identity contracts below.
fn three_axis_grid() -> GridSpec {
    GridSpec::from_json_str(
        r#"{
            "base": {
                "topology": "spine-leaf",
                "scale": 8,
                "seed": 7,
                "link": {"bandwidth_gbps": 32, "header_bytes": 16},
                "requester": {"requests_per_endpoint": 80,
                              "issue_interval_ns": 2,
                              "queue_capacity": 16,
                              "cache_lines": 128,
                              "footprint_lines": 1024},
                "memory": {"backend": "fixed",
                           "snoop_filter": {"capacity": 32, "policy": "fifo"}}
            },
            "sweep": {
                "pattern": ["random", "zipfian"],
                "backend": ["fixed", "dram"],
                "sf_policy": ["fifo", "lfi"]
            }
        }"#,
    )
    .expect("valid 3-axis grid")
}

fn dump(results: &[ScenarioResult]) -> String {
    results_json(results).to_string()
}

/// jobs=1 and jobs=N produce byte-identical table, CSV, and JSON output
/// on the 3-axis grid.
#[test]
fn three_axis_grid_identical_across_job_counts() {
    let serial = run_scenarios(three_axis_grid().scenarios, 1);
    let parallel = run_scenarios(three_axis_grid().scenarios, 8);
    assert_eq!(serial.len(), 8);
    assert_eq!(dump(&serial), dump(&parallel));
    let t1 = esf::sweep::results_table(&serial);
    let t8 = esf::sweep::results_table(&parallel);
    assert_eq!(t1.render(), t8.render());
    assert_eq!(t1.to_csv(), t8.to_csv());
    // Percentile columns are populated and ordered in every scenario.
    for r in &serial {
        assert!(r.completed > 0, "{}: no completions", r.label);
        assert!(r.p50_ns > 0.0, "{}: empty p50", r.label);
        assert!(r.p50_ns <= r.p95_ns && r.p95_ns <= r.p99_ns, "{}", r.label);
        assert!(r.p99_ns <= r.max_latency_ns, "{}", r.label);
    }
}

/// Cache-resume byte-identity: a fresh run, a cache-populating run, a
/// half-deleted-cache resume, and an all-hits resume must all emit the
/// same JSON dump, byte for byte.
#[test]
fn three_axis_grid_cache_resume_is_byte_identical() {
    let dir = std::env::temp_dir().join(format!("esf-axes-cache-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = SweepCache::open(&dir).unwrap();

    let fresh = run_scenarios(three_axis_grid().scenarios, 2);
    let populate = run_scenarios_cached(three_axis_grid().scenarios, 4, &cache);
    assert_eq!(dump(&fresh), dump(&populate), "populating run diverged");

    // Simulate an interrupted grid: kill half the finished cells.
    let mut cells: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .collect();
    cells.sort();
    assert_eq!(cells.len(), 8, "every scenario persisted a cell");
    for path in cells.iter().step_by(2) {
        std::fs::remove_file(path).unwrap();
    }
    let resumed = run_scenarios_cached(three_axis_grid().scenarios, 2, &cache);
    assert_eq!(dump(&fresh), dump(&resumed), "half-cache resume diverged");

    // All-hits rerun (nothing recomputed) is identical too.
    let warm = run_scenarios_cached(three_axis_grid().scenarios, 1, &cache);
    assert_eq!(dump(&fresh), dump(&warm), "warm rerun diverged");
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 8);
    let _ = std::fs::remove_dir_all(&dir);
}
