//! Cross-module integration tests: full systems exercising requesters,
//! switches, buses, snoop filters and media backends together, plus
//! failure injection and determinism at system level.

use esf::config::{build_system, build_system_with, BackendKind, RoutingSource, SystemCfg};
use esf::devices::{MemDev, Pattern, Requester, VictimPolicy};
use esf::dram::DramCfg;
use esf::engine::time::ns;
use esf::interconnect::{Strategy, TopologyKind};
use esf::metrics::{aggregate, hop_breakdown};

#[test]
fn every_topology_runs_end_to_end_with_dram() {
    for kind in TopologyKind::ALL {
        let mut cfg = SystemCfg::new(kind, 4);
        cfg.backend = BackendKind::Dram(DramCfg::ddr5_4800());
        cfg.requests_per_endpoint = 100;
        let mut sys = build_system(&cfg);
        sys.engine.run(u64::MAX);
        let a = aggregate(&sys);
        assert!(a.completed > 0, "{}: no completions", kind.name());
        assert_eq!(sys.engine.shared.dropped, 0, "{}: drops", kind.name());
        for &r in &sys.requesters {
            assert!(
                sys.engine.component::<Requester>(r).unwrap().done(),
                "{}: requester {r} unfinished",
                kind.name()
            );
        }
    }
}

#[test]
fn coherent_system_with_snoop_filters_converges() {
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 4);
    cfg.pattern = Pattern::Skewed { hot_frac: 0.1, hot_prob: 0.9 };
    cfg.footprint_lines = 4000;
    cfg.cache_lines = 800;
    cfg.snoop_filter = Some((200, VictimPolicy::Lifo));
    cfg.requests_per_endpoint = 500;
    cfg.warmup_fraction = 0.5;
    let mut sys = build_system(&cfg);
    sys.engine.run(u64::MAX);
    let a = aggregate(&sys);
    assert!(a.completed > 0);
    // BISnp traffic must have flowed and every eviction completed.
    let bisnp: u64 = sys
        .memories
        .iter()
        .map(|&m| sys.engine.component::<MemDev>(m).unwrap().stats.bisnp_sent)
        .sum();
    assert!(bisnp > 0, "skewed + small SF must trigger back-invalidation");
    // Inclusive SF never exceeds capacity.
    for &m in &sys.memories {
        let md = sys.engine.component::<MemDev>(m).unwrap();
        let sf = md.snoop_filter().unwrap();
        assert!(sf.len() <= sf.capacity());
        sf.check_invariants().unwrap();
    }
}

#[test]
fn system_level_determinism() {
    let run = || {
        let mut cfg = SystemCfg::new(TopologyKind::Ring, 4);
        cfg.seed = 99;
        cfg.requests_per_endpoint = 200;
        cfg.cache_lines = 256;
        cfg.footprint_lines = 2048;
        cfg.snoop_filter = Some((64, VictimPolicy::Fifo));
        let mut sys = build_system(&cfg);
        let events = sys.engine.run(u64::MAX);
        let a = aggregate(&sys);
        (events, a.completed, a.lat_sum_ns as u64, a.bytes)
    };
    assert_eq!(run(), run());
}

#[test]
fn adaptive_and_oblivious_both_complete() {
    for strategy in [Strategy::Oblivious, Strategy::Adaptive] {
        let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 8);
        cfg.strategy = strategy;
        cfg.requests_per_endpoint = 50;
        let mut sys = build_system(&cfg);
        sys.engine.run(u64::MAX);
        assert!(aggregate(&sys).completed > 0);
        assert_eq!(sys.engine.shared.dropped, 0);
    }
}

#[test]
fn failure_injection_unroutable_packets_are_counted_not_fatal() {
    // Build a valid system, then point one requester at a node that is in
    // the topology but unreachable (we cut its links by building a custom
    // fabric with an isolated memory).
    use esf::config::build_on_fabric;
    use esf::interconnect::{Fabric, LinkCfg, NodeKind, Routing, Topology};
    let mut topo = Topology::new();
    let r = topo.add_node("r", NodeKind::Requester);
    let m0 = topo.add_node("m0", NodeKind::Memory);
    let m1 = topo.add_node("m1-isolated", NodeKind::Memory); // no links!
    topo.add_link(r, m0, LinkCfg::default());
    let routing = Routing::build_bfs(&topo);
    let fabric = Fabric {
        topo,
        requesters: vec![r],
        memories: vec![m0, m1],
        switches: vec![],
    };
    let mut cfg = SystemCfg::new(TopologyKind::Chain, 1);
    cfg.requests_per_endpoint = 50;
    cfg.warmup_fraction = 0.0;
    let mut sys = build_on_fabric(&cfg, fabric, routing, &mut |_i, rc| rc);
    sys.engine.run(u64::MAX);
    // Packets to the isolated endpoint are dropped and counted; the rest
    // of the system still completes.
    assert!(sys.engine.shared.dropped > 0);
    let rq = sys.engine.component::<Requester>(r).unwrap();
    assert!(rq.stats.completed > 0);
}

#[test]
fn hop_breakdown_consistent_with_totals() {
    let mut cfg = SystemCfg::new(TopologyKind::Chain, 4);
    cfg.requests_per_endpoint = 200;
    let mut sys = build_system(&cfg);
    sys.engine.run(u64::MAX);
    let a = aggregate(&sys);
    let hb = hop_breakdown(&sys);
    let total: u64 = hb.iter().map(|r| r.1).sum();
    // hop-grouped counts cover all non-cache-hit completions
    assert_eq!(total, a.completed);
}

#[test]
fn json_config_to_simulation() {
    let cfg = SystemCfg::from_json_str(
        r#"{
            "topology": "fc", "scale": 8, "seed": 5,
            "link": {"bandwidth_gbps": 32},
            "requester": {"requests_per_endpoint": 100, "read_ratio": 0.5},
            "memory": {"backend": "dram"}
        }"#,
    )
    .unwrap();
    let mut sys = build_system(&cfg);
    sys.engine.run(u64::MAX);
    let a = aggregate(&sys);
    assert!(a.completed > 0);
    assert!(a.writes > 0 && a.reads > 0);
}

#[test]
fn half_duplex_system_slower_than_full_on_mixed_rw() {
    use esf::interconnect::Duplex;
    let run = |duplex| {
        let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 2);
        cfg.link.duplex = duplex;
        cfg.link.turnaround = ns(2.0);
        cfg.read_ratio = 0.5;
        cfg.issue_interval = ns(0.5);
        cfg.queue_capacity = 256;
        cfg.requests_per_endpoint = 1500;
        cfg.backend = BackendKind::Fixed(20.0);
        let mut sys = build_system(&cfg);
        sys.engine.run(u64::MAX);
        aggregate(&sys).bandwidth_gbps()
    };
    let full = run(Duplex::Full);
    let half = run(Duplex::Half);
    assert!(
        full > half * 1.3,
        "full {full:.1} should clearly beat half {half:.1} on 1:1 mix"
    );
}

#[test]
fn pjrt_routing_source_falls_back_gracefully() {
    // With or without artifacts this must produce a working system.
    let mut cfg = SystemCfg::new(TopologyKind::Tree, 2);
    cfg.requests_per_endpoint = 50;
    let mut sys = build_system_with(&cfg, RoutingSource::Pjrt, |_i, rc| rc);
    sys.engine.run(u64::MAX);
    assert!(aggregate(&sys).completed > 0);
}
