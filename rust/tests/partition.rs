//! Partitioned event-domain engine: byte-identity vs the sequential
//! reference (`Engine::reference_sequential`), partition-pass contracts at
//! the public API, the randomized cross-domain merge-order churn test, and
//! the warmup-drop accounting regression.
//!
//! `--intra-jobs N` must be invisible in every observable: the full result
//! digest (per-requester stats incl. exact latency histograms, hop
//! breakdowns, DCOH traffic, per-link bytes + bus utility) is compared
//! bit-for-bit for N in {2, 4, 8} against the sequential engine.

mod common;

use common::{digest, run_digest, run_digest_partitioned};
use esf::config::{build_on_fabric, BackendKind, SystemCfg};
use esf::devices::{Pattern, Requester, VictimPolicy};
use esf::engine::time::ns;
use esf::interconnect::{
    build, Duplex, Fabric, LinkCfg, NodeKind, Partition, Routing, Strategy, Topology,
    TopologyKind,
};

/// Mid-size spine-leaf scenario with FULL-duplex links: genuinely
/// partitionable (half-duplex links are contracted, so the golden
/// half-duplex spine-leaf exercises the single-domain fallback instead —
/// covered separately below).
fn spine_leaf_full_cfg() -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 6);
    cfg.seed = 1234;
    cfg.strategy = Strategy::Adaptive;
    cfg.pattern = Pattern::Random;
    cfg.read_ratio = 0.7;
    cfg.queue_capacity = 32;
    cfg.issue_interval = ns(2.0);
    cfg.requests_per_endpoint = 400;
    cfg.warmup_fraction = 0.25;
    cfg.backend = BackendKind::Fixed(30.0);
    cfg
}

/// The golden suite's half-duplex spine-leaf scenario: every link is
/// contracted, so the partitioner must fall back to one domain — and the
/// run must still be byte-identical (it IS the sequential loop then).
fn spine_leaf_half_cfg() -> SystemCfg {
    let mut cfg = spine_leaf_full_cfg();
    cfg.link.duplex = Duplex::Half;
    cfg.link.turnaround = ns(2.0);
    cfg
}

/// Coherent scenario exercising the DCOH across domains: skewed traffic,
/// small snoop filters, BISnp/BIRsp crossing cuts mid-eviction.
fn coherent_cfg(policy: VictimPolicy) -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 4);
    cfg.seed = 77;
    cfg.pattern = Pattern::Skewed {
        hot_frac: 0.1,
        hot_prob: 0.9,
    };
    cfg.footprint_lines = 4000;
    cfg.cache_lines = 800;
    cfg.snoop_filter = Some((100, policy));
    cfg.requests_per_endpoint = 300;
    cfg.warmup_fraction = 0.5;
    cfg
}

#[test]
fn partitioned_spine_leaf_is_byte_identical() {
    let cfg = spine_leaf_full_cfg();
    let seq = run_digest(&cfg, false);
    for jobs in [2, 4, 8] {
        assert_eq!(
            run_digest_partitioned(&cfg, jobs),
            seq,
            "spine-leaf digest diverged at intra_jobs={jobs}"
        );
    }
}

#[test]
fn partitioned_coherent_is_byte_identical() {
    for policy in [
        VictimPolicy::Fifo,
        VictimPolicy::Lfi,
        VictimPolicy::BlockLen { max_len: 4 },
    ] {
        let cfg = coherent_cfg(policy);
        let seq = run_digest(&cfg, false);
        for jobs in [2, 4, 8] {
            assert_eq!(
                run_digest_partitioned(&cfg, jobs),
                seq,
                "coherent digest diverged under {policy:?} at intra_jobs={jobs}"
            );
        }
    }
}

#[test]
fn half_duplex_fabric_falls_back_to_one_domain_identically() {
    let cfg = spine_leaf_half_cfg();
    let fabric = build(cfg.topology, cfg.n, cfg.link);
    let p = Partition::compute(&fabric.topo, 8);
    assert_eq!(p.n_domains(), 1, "half-duplex links must contract everything");
    assert_eq!(run_digest_partitioned(&cfg, 8), run_digest(&cfg, false));
}

// ---------------------------------------------- partition-pass contracts

#[test]
fn partition_assigns_every_node_exactly_once_with_positive_lookahead() {
    for kind in [TopologyKind::SpineLeaf, TopologyKind::FullyConnected, TopologyKind::Ring] {
        let fabric = build(kind, 16, LinkCfg::default());
        for jobs in [2, 4, 8] {
            let p = Partition::compute(&fabric.topo, jobs);
            let mut seen = vec![0u32; fabric.topo.n()];
            for (d, nodes) in p.domains.iter().enumerate() {
                for &node in nodes {
                    seen[node] += 1;
                    assert_eq!(p.domain_of[node], d as u32);
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{}: node multiplicity", kind.name());
            assert!(p.n_domains() > 1, "{} jobs={jobs} did not split", kind.name());
            assert!(p.lookahead > 0, "cut lookahead must be positive");
            for &l in &p.cut_links {
                assert!(fabric.topo.links[l].cfg.latency >= p.lookahead);
            }
        }
    }
}

/// Non-tree fabric (explicit cycle mesh — ESF's arbitrary-topology claim):
/// partition + partitioned run both work, byte-identically.
#[test]
fn non_tree_mesh_partitions_and_runs_identically() {
    // 2x3 switch torus with requesters/memories hanging off opposite rims.
    let mut t = Topology::new();
    let mut sw = Vec::new();
    for i in 0..6 {
        sw.push(t.add_node(format!("s{i}"), NodeKind::Switch));
    }
    for r in 0..2usize {
        for c in 0..3usize {
            t.add_link(sw[r * 3 + c], sw[r * 3 + (c + 1) % 3], LinkCfg::default());
        }
    }
    for c in 0..3 {
        t.add_link(sw[c], sw[3 + c], LinkCfg::default());
    }
    let mut requesters = Vec::new();
    let mut memories = Vec::new();
    for i in 0..4 {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, sw[i % 3], LinkCfg::default());
        requesters.push(r);
    }
    for i in 0..4 {
        let m = t.add_node(format!("m{i}"), NodeKind::Memory);
        t.add_link(m, sw[3 + i % 3], LinkCfg::default());
        memories.push(m);
    }
    let p = Partition::compute(&t, 4);
    assert!(p.n_domains() > 1 && p.lookahead > 0);

    let fabric = || Fabric {
        topo: t.clone(),
        requesters: requesters.clone(),
        memories: memories.clone(),
        switches: sw.clone(),
    };
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 4); // kind unused below
    cfg.seed = 9;
    cfg.requests_per_endpoint = 200;
    cfg.warmup_fraction = 0.2;
    let run = |jobs: usize| {
        let f = fabric();
        let routing = Routing::build_bfs(&f.topo);
        let mut sys = build_on_fabric(&cfg, f, routing, &mut |_i, rc| rc);
        let events = if jobs == 1 {
            sys.engine.reference_sequential()
        } else {
            sys.engine.run_partitioned(jobs)
        };
        digest(&sys, events)
    };
    let seq = run(1);
    for jobs in [2, 4] {
        assert_eq!(run(jobs), seq, "mesh digest diverged at intra_jobs={jobs}");
    }
}

// ------------------------------------------- randomized merge-order churn

/// Randomized scenario churn: arbitrary topology/pattern/duplex/coherence
/// mixes must merge cross-domain events in exactly the sequential order —
/// any tie-break or barrier bug shows up as a digest mismatch.
#[test]
fn random_scenarios_merge_identically_across_domain_counts() {
    use esf::util::prop::forall;
    forall(
        "partitioned == sequential on random scenarios",
        12,
        |rng| {
            let mut cfg = SystemCfg::new(
                match rng.gen_range(5) {
                    0 => TopologyKind::Chain,
                    1 => TopologyKind::Ring,
                    2 => TopologyKind::Tree,
                    3 => TopologyKind::SpineLeaf,
                    _ => TopologyKind::FullyConnected,
                },
                2 + rng.gen_range(3) as usize,
            );
            cfg.seed = rng.next_u64();
            cfg.read_ratio = 0.25 * rng.gen_range(5) as f64;
            cfg.requests_per_endpoint = 50 + rng.gen_range(100);
            cfg.warmup_fraction = 0.1 * rng.gen_range(5) as f64;
            cfg.issue_interval = ns(1.0 + rng.gen_range(4) as f64);
            cfg.strategy = if rng.chance(0.5) {
                Strategy::Adaptive
            } else {
                Strategy::Oblivious
            };
            if rng.chance(0.3) {
                // Half-duplex fabrics contract whole: exercises fallback.
                cfg.link.duplex = Duplex::Half;
                cfg.link.turnaround = ns(2.0);
            }
            if rng.chance(0.4) {
                cfg.footprint_lines = 1024;
                cfg.cache_lines = 128 + rng.gen_range(256);
                cfg.snoop_filter = Some((
                    32 + rng.gen_range(64) as usize,
                    [VictimPolicy::Fifo, VictimPolicy::Lru, VictimPolicy::Lfi]
                        [rng.gen_range(3) as usize],
                ));
            }
            let jobs = 2 + rng.gen_range(3) as usize;
            (cfg, jobs)
        },
        |(cfg, jobs)| {
            let seq = run_digest(cfg, false);
            let par = run_digest_partitioned(cfg, *jobs);
            if seq != par {
                return Err(format!(
                    "digest diverged at jobs={jobs}: seq {seq:#x} vs par {par:#x}"
                ));
            }
            Ok(())
        },
    );
}

// ------------------------------------------------ warmup-drop regression

/// A packet dropped (unroutable destination) during warm-up — including
/// at a partition boundary — must not leak txn-id state, undercount
/// `busy_ps`, or desynchronize the engines (satellite audit of
/// `Shared::forward_boxed`). The fabric routes half its endpoints through
/// a disconnected memory, so every requester keeps dropping from t=0
/// through warm-up and beyond.
#[test]
fn drops_during_warmup_stay_deterministic_and_accounted() {
    let mut t = Topology::new();
    let s0 = t.add_node("s0", NodeKind::Switch);
    let s1 = t.add_node("s1", NodeKind::Switch);
    t.add_link(s0, s1, LinkCfg::default());
    let mut requesters = Vec::new();
    for i in 0..3 {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, s0, LinkCfg::default());
        requesters.push(r);
    }
    let m0 = t.add_node("m0", NodeKind::Memory);
    t.add_link(m0, s1, LinkCfg::default());
    let m1 = t.add_node("m1", NodeKind::Memory); // intentionally isolated
    let memories = vec![m0, m1];
    let switches = vec![s0, s1];

    let mut cfg = SystemCfg::new(TopologyKind::Chain, 2); // kind unused
    cfg.seed = 5;
    cfg.requests_per_endpoint = 120;
    cfg.warmup_fraction = 0.4; // plenty of drops before the epoch opens
    let run = |jobs: usize| {
        let fabric = Fabric {
            topo: t.clone(),
            requesters: requesters.clone(),
            memories: memories.clone(),
            switches: switches.clone(),
        };
        let routing = Routing::build_bfs(&fabric.topo);
        let mut sys = build_on_fabric(&cfg, fabric, routing, &mut |_i, rc| rc);
        let events = if jobs == 1 {
            sys.engine.reference_sequential()
        } else {
            sys.engine.run_partitioned(jobs)
        };
        (digest(&sys, events), sys)
    };
    let (seq_digest, seq_sys) = run(1);
    assert!(seq_sys.engine.shared.dropped > 0, "scenario must drop packets");
    // Requesters drain their full budget: dropped issues reclaim their
    // queue slot and count toward completion, warm-up included.
    for &r in &seq_sys.requesters {
        let rq = seq_sys.engine.component::<Requester>(r).unwrap();
        assert!(rq.done(), "requester {r} wedged on dropped packets");
    }
    for jobs in [2, 4] {
        let (par_digest, par_sys) = run(jobs);
        assert_eq!(par_digest, seq_digest, "drop scenario diverged at jobs={jobs}");
        assert_eq!(par_sys.engine.shared.dropped, seq_sys.engine.shared.dropped);
    }
}
