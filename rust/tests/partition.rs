//! Partitioned event-domain engine: byte-identity vs the sequential
//! reference (`Engine::reference_sequential`), partition-pass contracts at
//! the public API, the randomized cross-domain merge-order churn test, and
//! the warmup-drop accounting regression.
//!
//! `--intra-jobs N` must be invisible in every observable: the full result
//! digest (per-requester stats incl. exact latency histograms, hop
//! breakdowns, DCOH traffic, per-link bytes + bus utility) is compared
//! bit-for-bit for N in {2, 4, 8} against the sequential engine — under
//! ALL barrier modes (adaptive windows, the fixed-window oracle, and the
//! speculative engine with deterministic rollback), on preset and
//! generated (dragonfly) fabrics up to 1000 nodes.
//!
//! The quiet-run elision safety property — a domain is never advanced
//! past a neighbor's published horizon — is an always-on assertion in the
//! adaptive worker loop (`engine/parallel.rs`), so every adaptive run in
//! this file doubles as a property test for it; the randomized churn test
//! below fuzzes it across arbitrary scenario mixes.

mod common;

use common::{
    digest, run_digest, run_digest_partitioned, run_digest_partitioned_model,
    run_digest_partitioned_opts,
};
use esf::config::{build_on_fabric, BackendKind, SystemCfg};
use esf::devices::{Pattern, Requester, VictimPolicy};
use esf::engine::parallel::BarrierMode;
use esf::engine::time::{ns, Ps};
use esf::interconnect::{
    build, Duplex, Fabric, LinkCfg, NodeKind, Partition, Routing, Strategy, Topology,
    TopologyKind, WeightModel,
};

/// Mid-size spine-leaf scenario with FULL-duplex links: genuinely
/// partitionable (half-duplex links are contracted, so the golden
/// half-duplex spine-leaf exercises the single-domain fallback instead —
/// covered separately below).
fn spine_leaf_full_cfg() -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 6);
    cfg.seed = 1234;
    cfg.strategy = Strategy::Adaptive;
    cfg.pattern = Pattern::Random;
    cfg.read_ratio = 0.7;
    cfg.queue_capacity = 32;
    cfg.issue_interval = ns(2.0);
    cfg.requests_per_endpoint = 400;
    cfg.warmup_fraction = 0.25;
    cfg.backend = BackendKind::Fixed(30.0);
    cfg
}

/// The golden suite's half-duplex spine-leaf scenario: every link is
/// contracted, so the partitioner must fall back to one domain — and the
/// run must still be byte-identical (it IS the sequential loop then).
fn spine_leaf_half_cfg() -> SystemCfg {
    let mut cfg = spine_leaf_full_cfg();
    cfg.link.duplex = Duplex::Half;
    cfg.link.turnaround = ns(2.0);
    cfg
}

/// Coherent scenario exercising the DCOH across domains: skewed traffic,
/// small snoop filters, BISnp/BIRsp crossing cuts mid-eviction.
fn coherent_cfg(policy: VictimPolicy) -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 4);
    cfg.seed = 77;
    cfg.pattern = Pattern::Skewed {
        hot_frac: 0.1,
        hot_prob: 0.9,
    };
    cfg.footprint_lines = 4000;
    cfg.cache_lines = 800;
    cfg.snoop_filter = Some((100, policy));
    cfg.requests_per_endpoint = 300;
    cfg.warmup_fraction = 0.5;
    cfg
}

#[test]
fn partitioned_spine_leaf_is_byte_identical() {
    let cfg = spine_leaf_full_cfg();
    let seq = run_digest(&cfg, false);
    for model in [WeightModel::Traffic, WeightModel::NodeCount] {
        for mode in [
            BarrierMode::Adaptive,
            BarrierMode::FixedWindow,
            BarrierMode::Speculative,
        ] {
            for jobs in [2, 4, 8] {
                assert_eq!(
                    run_digest_partitioned_opts(&cfg, jobs, model, mode),
                    seq,
                    "spine-leaf digest diverged at intra_jobs={jobs} under {model:?}/{mode:?}"
                );
            }
        }
    }
}

#[test]
fn partitioned_coherent_is_byte_identical() {
    for policy in [
        VictimPolicy::Fifo,
        VictimPolicy::Lfi,
        VictimPolicy::BlockLen { max_len: 4 },
    ] {
        let cfg = coherent_cfg(policy);
        let seq = run_digest(&cfg, false);
        for jobs in [2, 4, 8] {
            assert_eq!(
                run_digest_partitioned(&cfg, jobs),
                seq,
                "coherent digest diverged under {policy:?} at intra_jobs={jobs}"
            );
            assert_eq!(
                run_digest_partitioned_model(&cfg, jobs, WeightModel::NodeCount),
                seq,
                "coherent digest diverged under {policy:?}/NodeCount at intra_jobs={jobs}"
            );
            assert_eq!(
                run_digest_partitioned_opts(
                    &cfg,
                    jobs,
                    WeightModel::Traffic,
                    BarrierMode::FixedWindow
                ),
                seq,
                "coherent digest diverged under {policy:?}/FixedWindow at intra_jobs={jobs}"
            );
            assert_eq!(
                run_digest_partitioned_opts(
                    &cfg,
                    jobs,
                    WeightModel::Traffic,
                    BarrierMode::Speculative
                ),
                seq,
                "coherent digest diverged under {policy:?}/Speculative at intra_jobs={jobs}"
            );
        }
    }
}

#[test]
fn half_duplex_fabric_falls_back_to_one_domain_identically() {
    let cfg = spine_leaf_half_cfg();
    let fabric = build(cfg.topology, cfg.n, cfg.link);
    let p = Partition::compute(&fabric.topo, 8);
    assert_eq!(p.n_domains(), 1, "half-duplex links must contract everything");
    assert_eq!(run_digest_partitioned(&cfg, 8), run_digest(&cfg, false));
}

// ---------------------------------------------- partition-pass contracts

#[test]
fn partition_assigns_every_node_exactly_once_with_positive_lookahead() {
    for kind in [TopologyKind::SpineLeaf, TopologyKind::FullyConnected, TopologyKind::Ring] {
        let fabric = build(kind, 16, LinkCfg::default());
        let routing = Routing::build_bfs(&fabric.topo);
        for model in [WeightModel::NodeCount, WeightModel::Traffic] {
            for jobs in [2, 4, 8] {
                let p = Partition::compute_weighted(&fabric.topo, &routing, jobs, model);
                let mut seen = vec![0u32; fabric.topo.n()];
                for (d, nodes) in p.domains.iter().enumerate() {
                    for &node in nodes {
                        seen[node] += 1;
                        assert_eq!(p.domain_of[node], d as u32);
                    }
                }
                assert!(seen.iter().all(|&c| c == 1), "{}: node multiplicity", kind.name());
                assert!(
                    p.n_domains() > 1,
                    "{} jobs={jobs} {model:?} did not split",
                    kind.name()
                );
                assert!(p.lookahead > 0, "cut lookahead must be positive");
                for &l in &p.cut_links {
                    assert!(fabric.topo.links[l].cfg.latency >= p.lookahead);
                }
                // Exchange peers mirror the cut set exactly.
                let peers = p.exchange_peers(&fabric.topo);
                for &l in &p.cut_links {
                    let (a, b) = (fabric.topo.links[l].a, fabric.topo.links[l].b);
                    let (da, db) = (p.domain_of[a] as usize, p.domain_of[b] as usize);
                    assert!(peers[da].contains(&db) && peers[db].contains(&da));
                }
            }
        }
    }
}

/// Non-tree fabric (explicit cycle mesh — ESF's arbitrary-topology claim):
/// partition + partitioned run both work, byte-identically.
#[test]
fn non_tree_mesh_partitions_and_runs_identically() {
    // 2x3 switch torus with requesters/memories hanging off opposite rims.
    let mut t = Topology::new();
    let mut sw = Vec::new();
    for i in 0..6 {
        sw.push(t.add_node(format!("s{i}"), NodeKind::Switch));
    }
    for r in 0..2usize {
        for c in 0..3usize {
            t.add_link(sw[r * 3 + c], sw[r * 3 + (c + 1) % 3], LinkCfg::default());
        }
    }
    for c in 0..3 {
        t.add_link(sw[c], sw[3 + c], LinkCfg::default());
    }
    let mut requesters = Vec::new();
    let mut memories = Vec::new();
    for i in 0..4 {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, sw[i % 3], LinkCfg::default());
        requesters.push(r);
    }
    for i in 0..4 {
        let m = t.add_node(format!("m{i}"), NodeKind::Memory);
        t.add_link(m, sw[3 + i % 3], LinkCfg::default());
        memories.push(m);
    }
    let p = Partition::compute(&t, 4);
    assert!(p.n_domains() > 1 && p.lookahead > 0);

    let fabric = || Fabric {
        topo: t.clone(),
        requesters: requesters.clone(),
        memories: memories.clone(),
        switches: sw.clone(),
    };
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 4); // kind unused below
    cfg.seed = 9;
    cfg.requests_per_endpoint = 200;
    cfg.warmup_fraction = 0.2;
    let run = |jobs: usize, model: WeightModel, mode: BarrierMode| {
        let f = fabric();
        let routing = Routing::build_bfs(&f.topo);
        let mut sys = build_on_fabric(&cfg, f, routing, &mut |_i, rc| rc);
        let events = if jobs == 1 {
            sys.engine.reference_sequential()
        } else {
            sys.engine.run_partitioned_opts(jobs, model, mode)
        };
        digest(&sys, events)
    };
    let seq = run(1, WeightModel::Traffic, BarrierMode::Adaptive);
    for model in [WeightModel::Traffic, WeightModel::NodeCount] {
        for mode in [
            BarrierMode::Adaptive,
            BarrierMode::FixedWindow,
            BarrierMode::Speculative,
        ] {
            for jobs in [2, 4] {
                assert_eq!(
                    run(jobs, model, mode),
                    seq,
                    "mesh digest diverged at intra_jobs={jobs} under {model:?}/{mode:?}"
                );
            }
        }
    }
}

// ------------------------------------------- randomized merge-order churn

/// Randomized scenario churn: arbitrary topology/pattern/duplex/coherence
/// mixes must merge cross-domain events in exactly the sequential order —
/// any tie-break or barrier bug shows up as a digest mismatch.
#[test]
fn random_scenarios_merge_identically_across_domain_counts() {
    use esf::util::prop::forall;
    forall(
        "partitioned == sequential on random scenarios",
        12,
        |rng| {
            let mut cfg = SystemCfg::new(
                match rng.gen_range(5) {
                    0 => TopologyKind::Chain,
                    1 => TopologyKind::Ring,
                    2 => TopologyKind::Tree,
                    3 => TopologyKind::SpineLeaf,
                    _ => TopologyKind::FullyConnected,
                },
                2 + rng.gen_range(3) as usize,
            );
            cfg.seed = rng.next_u64();
            cfg.read_ratio = 0.25 * rng.gen_range(5) as f64;
            cfg.requests_per_endpoint = 50 + rng.gen_range(100);
            cfg.warmup_fraction = 0.1 * rng.gen_range(5) as f64;
            cfg.issue_interval = ns(1.0 + rng.gen_range(4) as f64);
            cfg.strategy = if rng.chance(0.5) {
                Strategy::Adaptive
            } else {
                Strategy::Oblivious
            };
            if rng.chance(0.3) {
                // Half-duplex fabrics contract whole: exercises fallback.
                cfg.link.duplex = Duplex::Half;
                cfg.link.turnaround = ns(2.0);
            }
            if rng.chance(0.4) {
                cfg.footprint_lines = 1024;
                cfg.cache_lines = 128 + rng.gen_range(256);
                cfg.snoop_filter = Some((
                    32 + rng.gen_range(64) as usize,
                    [VictimPolicy::Fifo, VictimPolicy::Lru, VictimPolicy::Lfi]
                        [rng.gen_range(3) as usize],
                ));
            }
            let jobs = 2 + rng.gen_range(3) as usize;
            let model = if rng.chance(0.5) {
                WeightModel::Traffic
            } else {
                WeightModel::NodeCount
            };
            (cfg, jobs, model)
        },
        |(cfg, jobs, model)| {
            let seq = run_digest(cfg, false);
            let par = run_digest_partitioned_model(cfg, *jobs, *model);
            if seq != par {
                return Err(format!(
                    "digest diverged at jobs={jobs} {model:?}: seq {seq:#x} vs par {par:#x}"
                ));
            }
            // Same scenario through the fixed-window oracle: any adaptive
            // widening or elision bug splits the two partitioned digests.
            let fixed =
                run_digest_partitioned_opts(cfg, *jobs, *model, BarrierMode::FixedWindow);
            if seq != fixed {
                return Err(format!(
                    "fixed-window digest diverged at jobs={jobs} {model:?}: \
                     seq {seq:#x} vs par {fixed:#x}"
                ));
            }
            // And through the speculative engine: any unsound rollback
            // capture point or straggler miss diverges the digest here.
            let spec =
                run_digest_partitioned_opts(cfg, *jobs, *model, BarrierMode::Speculative);
            if seq != spec {
                return Err(format!(
                    "speculative digest diverged at jobs={jobs} {model:?}: \
                     seq {seq:#x} vs par {spec:#x}"
                ));
            }
            Ok(())
        },
    );
}

// --------------------------------------- speculative straggler injection

/// Randomized straggler-injection fuzz for the speculative engine: the
/// generator is biased toward RARE cross-cut traffic (long issue
/// intervals, small budgets, long warm-ups) — exactly the regime where
/// domains speculate far past their certified horizon and the occasional
/// cross-cut packet lands as a straggler inside a committed-looking
/// stint. Every case must keep per-node event order identical to the
/// sequential reference across intra-jobs {2, 4, 8}, and the stats
/// invariants (rollbacks bounded by stints, wasted work only from
/// rollbacks, token conservation) must hold throughout.
#[test]
fn speculative_straggler_fuzz_on_rare_cross_cut_traffic() {
    use esf::util::prop::forall;
    use std::cell::Cell;
    let total_stints = Cell::new(0u64);
    let total_rollbacks = Cell::new(0u64);
    forall(
        "speculative == sequential under rare cross-cut traffic",
        10,
        |rng| {
            let mut cfg = SystemCfg::new(
                match rng.gen_range(4) {
                    0 => TopologyKind::Ring,
                    1 => TopologyKind::Tree,
                    2 => TopologyKind::Dragonfly,
                    _ => TopologyKind::SpineLeaf,
                },
                3 + rng.gen_range(4) as usize,
            );
            cfg.seed = rng.next_u64();
            // Quiet bias: sparse issue stream, small budget, long warm-up
            // => cut crossings are rare and stints routinely over-run the
            // next certified horizon.
            cfg.issue_interval = ns(4.0 + rng.gen_range(13) as f64);
            cfg.requests_per_endpoint = 40 + rng.gen_range(80);
            cfg.warmup_fraction = 0.1 * rng.gen_range(4) as f64;
            cfg.read_ratio = 0.25 * rng.gen_range(5) as f64;
            cfg.backend = BackendKind::Fixed(20.0 + rng.gen_range(30) as f64);
            cfg
        },
        |cfg| {
            let seq = run_digest(cfg, false);
            for jobs in [2usize, 4, 8] {
                let mut sys = esf::config::build_system(cfg);
                let events = sys.engine.run_partitioned_opts(
                    jobs,
                    WeightModel::Traffic,
                    BarrierMode::Speculative,
                );
                let spec = digest(&sys, events);
                if seq != spec {
                    return Err(format!(
                        "speculative digest diverged at jobs={jobs}: \
                         seq {seq:#x} vs par {spec:#x}"
                    ));
                }
                if let Some(s) = sys.engine.intra_stats {
                    if s.rollbacks > s.speculative_windows {
                        return Err(format!(
                            "jobs={jobs}: {} rollbacks exceed {} stints",
                            s.rollbacks, s.speculative_windows
                        ));
                    }
                    if s.wasted_events > 0 && s.rollbacks == 0 {
                        return Err(format!(
                            "jobs={jobs}: {} wasted events without a rollback",
                            s.wasted_events
                        ));
                    }
                    if s.messages + s.elided_tokens != s.windows * s.channels as u64 {
                        return Err(format!(
                            "jobs={jobs}: token conservation broken \
                             ({} + {} != {} * {})",
                            s.messages, s.elided_tokens, s.windows, s.channels
                        ));
                    }
                    total_stints.set(total_stints.get() + s.speculative_windows);
                    total_rollbacks.set(total_rollbacks.get() + s.rollbacks);
                }
            }
            Ok(())
        },
    );
    // Across the whole fuzz run the engine must actually have speculated —
    // a zero here means the stint guard is wedged shut and every case
    // above degenerated to plain adaptive execution.
    assert!(
        total_stints.get() > 0,
        "fuzz never opened a speculative stint (rollbacks seen: {})",
        total_rollbacks.get()
    );
}

/// Forced-rollback convergence: the busy spine-leaf scenario keeps every
/// cut channel hot, so speculative stints are repeatedly invalidated by
/// stragglers — and every rollback must still converge to the sequential
/// digest. The adversarial counterpart to the quiet-cut fuzz above.
#[test]
fn speculative_rollbacks_converge_on_straggler_heavy_cut() {
    let cfg = spine_leaf_full_cfg();
    let seq = run_digest(&cfg, false);
    let mut total_rollbacks = 0u64;
    for jobs in [2usize, 4, 8] {
        let mut sys = esf::config::build_system(&cfg);
        let events =
            sys.engine
                .run_partitioned_opts(jobs, WeightModel::Traffic, BarrierMode::Speculative);
        assert_eq!(
            digest(&sys, events),
            seq,
            "straggler-heavy speculative run diverged at intra_jobs={jobs}"
        );
        let s = sys.engine.intra_stats.expect("spine-leaf must partition");
        assert!(
            s.speculative_windows > 0,
            "busy cut opened no stints at jobs={jobs}"
        );
        assert!(s.rollbacks <= s.speculative_windows);
        assert!(s.wasted_events >= s.rollbacks, "each rollback wastes >= 1 event");
        assert!(
            s.committed_frontier_advances > 0 && s.committed_frontier_advances <= s.windows,
            "commit frontier must advance monotonically within the window count"
        );
        total_rollbacks += s.rollbacks;
    }
    // With ~2ns issue spacing against a 1ns-lookahead cut, stragglers are
    // unavoidable at some domain width: the rollback path itself must
    // have been exercised, not just the adopt path.
    assert!(
        total_rollbacks > 0,
        "straggler-heavy scenario never forced a rollback"
    );
}

// ------------------------------------------------ warmup-drop regression

/// A packet dropped (unroutable destination) during warm-up — including
/// at a partition boundary — must not leak txn-id state, undercount
/// `busy_ps`, or desynchronize the engines (satellite audit of
/// `Shared::forward_boxed`). The fabric routes half its endpoints through
/// a disconnected memory, so every requester keeps dropping from t=0
/// through warm-up and beyond.
#[test]
fn drops_during_warmup_stay_deterministic_and_accounted() {
    let mut t = Topology::new();
    let s0 = t.add_node("s0", NodeKind::Switch);
    let s1 = t.add_node("s1", NodeKind::Switch);
    t.add_link(s0, s1, LinkCfg::default());
    let mut requesters = Vec::new();
    for i in 0..3 {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, s0, LinkCfg::default());
        requesters.push(r);
    }
    let m0 = t.add_node("m0", NodeKind::Memory);
    t.add_link(m0, s1, LinkCfg::default());
    let m1 = t.add_node("m1", NodeKind::Memory); // intentionally isolated
    let memories = vec![m0, m1];
    let switches = vec![s0, s1];

    let mut cfg = SystemCfg::new(TopologyKind::Chain, 2); // kind unused
    cfg.seed = 5;
    cfg.requests_per_endpoint = 120;
    cfg.warmup_fraction = 0.4; // plenty of drops before the epoch opens
    let run = |jobs: usize| {
        let fabric = Fabric {
            topo: t.clone(),
            requesters: requesters.clone(),
            memories: memories.clone(),
            switches: switches.clone(),
        };
        let routing = Routing::build_bfs(&fabric.topo);
        let mut sys = build_on_fabric(&cfg, fabric, routing, &mut |_i, rc| rc);
        let events = if jobs == 1 {
            sys.engine.reference_sequential()
        } else {
            sys.engine.run_partitioned(jobs)
        };
        (digest(&sys, events), sys)
    };
    let (seq_digest, seq_sys) = run(1);
    assert!(seq_sys.engine.shared.dropped > 0, "scenario must drop packets");
    // Requesters drain their full budget: dropped issues reclaim their
    // queue slot and count toward completion, warm-up included.
    for &r in &seq_sys.requesters {
        let rq = seq_sys.engine.component::<Requester>(r).unwrap();
        assert!(rq.done(), "requester {r} wedged on dropped packets");
    }
    for jobs in [2, 4] {
        let (par_digest, par_sys) = run(jobs);
        assert_eq!(par_digest, seq_digest, "drop scenario diverged at jobs={jobs}");
        assert_eq!(par_sys.engine.shared.dropped, seq_sys.engine.shared.dropped);
    }
}

// ------------------------------------------ disconnected-fabric regression

/// A fabric of mutually disconnected components split across domains has
/// NO cut links: the partition's lookahead legitimately stays `Ps::MAX`
/// and the window end `tmin + lookahead` must saturate instead of
/// wrapping (regression for the overflow hazard). Each island is a
/// complete requester/switch/memory system, so the simulation runs a
/// full workload per component; requesters whose round-robin targets
/// live on a foreign island produce deterministic drops.
#[test]
fn disconnected_fabric_partitions_without_cuts_and_stays_identical() {
    let mut t = Topology::new();
    let mut requesters = Vec::new();
    let mut memories = Vec::new();
    let mut switches = Vec::new();
    for c in 0..3 {
        let s = t.add_node(format!("s{c}"), NodeKind::Switch);
        switches.push(s);
        for i in 0..2 {
            let r = t.add_node(format!("r{c}_{i}"), NodeKind::Requester);
            t.add_link(r, s, LinkCfg::default());
            requesters.push(r);
            let m = t.add_node(format!("m{c}_{i}"), NodeKind::Memory);
            t.add_link(m, s, LinkCfg::default());
            memories.push(m);
        }
    }
    let routing = Routing::build_bfs(&t);
    // Component granularity (<= 3 domains): nothing can be cut.
    for model in [WeightModel::NodeCount, WeightModel::Traffic] {
        let p = Partition::compute_weighted(&t, &routing, 3, model);
        assert!(p.n_domains() > 1, "disconnected fabric must split");
        assert!(p.cut_links.is_empty());
        assert_eq!(p.lookahead, Ps::MAX, "no cut => unbounded lookahead");
        assert!(p.exchange_peers(&t).iter().all(Vec::is_empty));
    }

    let mut cfg = SystemCfg::new(TopologyKind::Chain, 6); // kind unused
    cfg.seed = 21;
    cfg.requests_per_endpoint = 150;
    cfg.warmup_fraction = 0.2;
    let run = |jobs: usize| {
        let fabric = Fabric {
            topo: t.clone(),
            requesters: requesters.clone(),
            memories: memories.clone(),
            switches: switches.clone(),
        };
        let routing = Routing::build_bfs(&fabric.topo);
        let mut sys = build_on_fabric(&cfg, fabric, routing, &mut |_i, rc| rc);
        let events = if jobs == 1 {
            sys.engine.reference_sequential()
        } else {
            sys.engine.run_partitioned(jobs)
        };
        (digest(&sys, events), sys)
    };
    let (seq_digest, seq_sys) = run(1);
    for jobs in [2, 3] {
        let (par_digest, par_sys) = run(jobs);
        assert_eq!(
            par_digest, seq_digest,
            "disconnected fabric diverged at intra_jobs={jobs}"
        );
        let stats = par_sys.engine.intra_stats.expect("partitioned path taken");
        assert_eq!(
            stats.messages + stats.elided_tokens,
            stats.windows * stats.channels as u64,
            "token conservation: every (window, channel) slot is a message or elided"
        );
        if jobs == 3 {
            // One domain per island: the partitioned path ran with ZERO
            // exchange channels and unbounded (saturated) windows —
            // whole components never talk across domains.
            assert_eq!(stats.channels, 0, "disconnected domains need no channels");
            assert_eq!(stats.events_exchanged, 0);
            assert_eq!(stats.messages, 0);
        }
        assert_eq!(seq_sys.engine.shared.dropped, par_sys.engine.shared.dropped);
    }
}

// --------------------------------------------- published-numbers pinning

/// Pins the exact, machine-independent partition numbers published in
/// EXPERIMENTS.md §Traffic-weighted partitioning and BENCH_hotpath.json
/// `intra_exchange` for the 162-node spine-leaf bench fabric (scale 128
/// = 64+64 endpoints, 2 spines, 32 leaves). Everything here is a pure
/// function of the topology, so any change to the partition pass that
/// moves these numbers must update the docs with it.
#[test]
fn published_spine_leaf_162_partition_numbers_hold() {
    let f = build(TopologyKind::SpineLeaf, 64, LinkCfg::default());
    assert_eq!(f.topo.n(), 162);
    let routing = Routing::build_bfs(&f.topo);
    let sizes = |p: &Partition| p.domains.iter().map(Vec::len).collect::<Vec<_>>();
    let channels =
        |p: &Partition| p.exchange_peers(&f.topo).iter().map(Vec::len).sum::<usize>();

    for model in [WeightModel::Traffic, WeightModel::NodeCount] {
        let p2 = Partition::compute_weighted(&f.topo, &routing, 2, model);
        assert_eq!(sizes(&p2), vec![81, 81], "{model:?} jobs=2 sizes");
        assert_eq!(channels(&p2), 2, "{model:?} jobs=2 channels");
    }

    let tr4 = Partition::compute_weighted(&f.topo, &routing, 4, WeightModel::Traffic);
    assert_eq!(sizes(&tr4), vec![8, 8, 73, 73], "traffic jobs=4 sizes");
    assert_eq!(channels(&tr4), 10, "traffic jobs=4 channels (all-to-all 12)");
    let nc4 = Partition::compute_weighted(&f.topo, &routing, 4, WeightModel::NodeCount);
    assert_eq!(sizes(&nc4), vec![41, 41, 40, 40], "node-count jobs=4 sizes");

    let tr8 = Partition::compute_weighted(&f.topo, &routing, 8, WeightModel::Traffic);
    assert_eq!(
        sizes(&tr8),
        vec![3, 3, 19, 22, 22, 37, 37, 19],
        "traffic jobs=8 sizes"
    );
    assert_eq!(channels(&tr8), 46, "traffic jobs=8 channels (all-to-all 56)");
    let nc8 = Partition::compute_weighted(&f.topo, &routing, 8, WeightModel::NodeCount);
    assert_eq!(
        sizes(&nc8),
        vec![21, 21, 20, 20, 20, 20, 20, 20],
        "node-count jobs=8 sizes"
    );
}

// ------------------------------------------------- sparse exchange volume

/// The acceptance datapoint behind BENCH_hotpath.json `intra_exchange`:
/// on the partitionable spine-leaf scenario the sparse neighbor exchange
/// must open strictly fewer channels than the `ndom * (ndom - 1)`
/// all-to-all mesh it replaced, and token conservation must hold: every
/// `(window, channel)` slot is accounted for either as a sent message or
/// as an elided token, under both weight models.
#[test]
fn sparse_exchange_volume_beats_all_to_all_on_spine_leaf() {
    let cfg = spine_leaf_full_cfg();
    for model in [WeightModel::Traffic, WeightModel::NodeCount] {
        for jobs in [4, 8] {
            let mut sys = esf::config::build_system(&cfg);
            sys.engine.run_partitioned_model(jobs, model);
            let s = sys.engine.intra_stats.expect("spine-leaf must partition");
            assert!(s.domains > 1);
            let all_to_all = s.domains * (s.domains - 1);
            if s.domains > 2 {
                assert!(
                    s.channels < all_to_all,
                    "{model:?} jobs={jobs}: sparse {} !< all-to-all {all_to_all}",
                    s.channels
                );
            } else {
                assert!(s.channels <= all_to_all);
            }
            assert_eq!(
                s.messages + s.elided_tokens,
                s.windows * s.channels as u64,
                "token conservation: every (window, channel) slot is a message or elided"
            );
            assert!(s.quiet_messages <= s.messages);
        }
    }
}

// --------------------------------------- generated fabrics: byte identity

/// Generated-topology scenarios join the byte-identity suite: a small
/// dragonfly (40 nodes at scale 16) must be invisible to `--intra-jobs`
/// under both barrier modes and both weight models, exactly like the
/// paper presets.
#[test]
fn partitioned_dragonfly_is_byte_identical() {
    let mut cfg = SystemCfg::new(TopologyKind::Dragonfly, 16);
    cfg.seed = 4242;
    cfg.pattern = Pattern::Random;
    cfg.read_ratio = 0.7;
    cfg.queue_capacity = 32;
    cfg.issue_interval = ns(2.0);
    cfg.requests_per_endpoint = 200;
    cfg.warmup_fraction = 0.2;
    cfg.backend = BackendKind::Fixed(30.0);
    let seq = run_digest(&cfg, false);
    for model in [WeightModel::Traffic, WeightModel::NodeCount] {
        for mode in [
            BarrierMode::Adaptive,
            BarrierMode::FixedWindow,
            BarrierMode::Speculative,
        ] {
            for jobs in [2, 4, 8] {
                assert_eq!(
                    run_digest_partitioned_opts(&cfg, jobs, model, mode),
                    seq,
                    "dragonfly digest diverged at intra_jobs={jobs} under {model:?}/{mode:?}"
                );
            }
        }
    }
}

/// The large-fabric smoke at test scale: a 1000-node generated dragonfly
/// (scale 400 — 200 routers + 800 endpoints) with a small per-endpoint
/// workload stays byte-identical through the two-level partitioner and
/// the adaptive barrier. Same shape as CI's quick large-fabric job,
/// which drives it through the `esf` binary instead.
#[test]
fn thousand_node_dragonfly_partitioned_matches_sequential() {
    let mut cfg = SystemCfg::new(TopologyKind::Dragonfly, 400);
    cfg.seed = 7;
    cfg.pattern = Pattern::Random;
    cfg.queue_capacity = 32;
    cfg.issue_interval = ns(2.0);
    cfg.requests_per_endpoint = 10;
    cfg.warmup_fraction = 0.05;
    cfg.backend = BackendKind::Fixed(30.0);
    let seq = run_digest(&cfg, false);
    for jobs in [4, 16] {
        for mode in [BarrierMode::Adaptive, BarrierMode::Speculative] {
            assert_eq!(
                run_digest_partitioned_opts(&cfg, jobs, WeightModel::Traffic, mode),
                seq,
                "1k-node dragonfly diverged at intra_jobs={jobs} under {mode:?}"
            );
        }
    }
}

// --------------------------------------- adaptive-barrier acceptance pin

/// ISSUE 7 acceptance pin: on the published 162-node spine-leaf bench
/// fabric (scale 64, 8 traffic-weighted domains) the adaptive barrier
/// must cut total exchange messages by >= 40% vs the fixed-window
/// protocol it replaced as the default — without moving one simulation
/// byte. Counts are pure functions of the scenario, so this holds on any
/// machine; the wall-clock side lives in BENCH_hotpath.json. The
/// workload is a scaled-down replica of the `intra_exchange` bench
/// scenario (same fabric, same traffic shape, fewer requests).
#[test]
fn adaptive_barrier_cuts_messages_forty_percent_on_bench_spine_leaf() {
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 64);
    cfg.pattern = Pattern::Random;
    cfg.issue_interval = ns(2.0);
    cfg.queue_capacity = 64;
    cfg.requests_per_endpoint = 100;
    cfg.warmup_fraction = 0.05;
    cfg.backend = BackendKind::Fixed(30.0);

    let run = |mode: BarrierMode| {
        let mut sys = esf::config::build_system(&cfg);
        let events = sys.engine.run_partitioned_opts(8, WeightModel::Traffic, mode);
        let stats = sys.engine.intra_stats.expect("bench fabric must partition");
        (digest(&sys, events), stats)
    };
    let (da, a) = run(BarrierMode::Adaptive);
    let (df, f) = run(BarrierMode::FixedWindow);
    assert_eq!(da, df, "barrier mode changed simulation output");
    assert_eq!(a.domains, 8);
    assert_eq!(a.channels, f.channels, "channel set is a partition property");
    assert_eq!(
        a.events_exchanged, f.events_exchanged,
        "every cut-crossing event is exchanged exactly once in either mode"
    );
    assert!(a.windows <= f.windows, "widening can only shrink the window count");
    assert!(a.widened_windows > 0, "bench scenario must exercise widening");
    assert!(a.elided_tokens > 0, "bench scenario must exercise elision");
    assert_eq!(a.quiet_messages, 0, "adaptive mode never sends quiet tokens");
    // The headline acceptance number: >= 40% fewer barrier messages.
    assert!(
        a.messages * 10 <= f.messages * 6,
        "adaptive barrier saved only {:.1}% of {} fixed-window messages",
        100.0 * (1.0 - a.messages as f64 / f.messages as f64),
        f.messages
    );
}
