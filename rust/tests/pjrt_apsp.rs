//! PJRT integration: the AOT-compiled Pallas min-plus APSP kernel must
//! agree exactly with the native Rust implementation on every preset
//! fabric. Requires `make artifacts` (skips cleanly when absent).

use esf::interconnect::{build, LinkCfg, Routing, TopologyKind};
use esf::runtime::{apsp_native, Runtime, UNREACH};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::load_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT tests: {e}");
            None
        }
    }
}

#[test]
fn pallas_apsp_matches_native_on_all_preset_fabrics() {
    let Some(mut rt) = runtime_or_skip() else { return };
    for kind in TopologyKind::ALL {
        for n in [2, 4, 8] {
            let fabric = build(kind, n, LinkCfg::default());
            let nodes = fabric.topo.n();
            if nodes > rt.max_apsp() {
                continue;
            }
            let adj = fabric.topo.adjacency_matrix(UNREACH);
            let native = apsp_native(&adj, nodes);
            let pjrt = rt.apsp(&adj, nodes).expect("pjrt apsp");
            for (i, (a, b)) in native.iter().zip(&pjrt).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3,
                    "{} n={} entry {}: native {} vs pjrt {}",
                    kind.name(),
                    n,
                    i,
                    a,
                    b
                );
            }
        }
    }
}

#[test]
fn pallas_apsp_feeds_identical_routing_tables() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let fabric = build(TopologyKind::SpineLeaf, 8, LinkCfg::default());
    let n = fabric.topo.n();
    let bfs = Routing::build_bfs(&fabric.topo);
    let adj = fabric.topo.adjacency_matrix(UNREACH);
    let d = rt.apsp(&adj, n).unwrap();
    let via_kernel = Routing::from_distances(&fabric.topo, &d, UNREACH);
    for u in 0..n {
        for v in 0..n {
            assert_eq!(bfs.dist(u, v), via_kernel.dist(u, v), "dist {u}->{v}");
            assert_eq!(
                bfs.candidates(u, v),
                via_kernel.candidates(u, v),
                "candidates {u}->{v}"
            );
        }
    }
}

#[test]
fn tracestats_kernel_matches_native() {
    let Some(mut rt) = runtime_or_skip() else { return };
    let trace = esf::workloads::RealWorkload::Redis.generate(20_000, 5);
    let native = trace.windowed_stats(1000);
    let w = native.len();
    let mut is_write = vec![0f32; w * 1000];
    let mut bytes = vec![0f32; w * 1000];
    for i in 0..w * 1000 {
        is_write[i] = if trace.ops[i].is_write { 1.0 } else { 0.0 };
        bytes[i] = 64.0;
    }
    let rows = rt.tracestats(&is_write, &bytes, w, 1000).expect("tracestats");
    assert_eq!(rows.len(), w);
    for (i, [r, wr, b]) in rows.iter().enumerate() {
        assert_eq!((*r as u64, *wr as u64, *b as u64), native[i], "window {i}");
    }
}

#[test]
fn padded_fabric_sizes_work() {
    // Fabric sizes that do NOT match an artifact size exactly exercise
    // the padding path.
    let Some(mut rt) = runtime_or_skip() else { return };
    for n in [3usize, 5, 17, 33] {
        // ring of n nodes
        let mut adj = vec![UNREACH; n * n];
        for i in 0..n {
            adj[i * n + i] = 0.0;
            let j = (i + 1) % n;
            adj[i * n + j] = 1.0;
            adj[j * n + i] = 1.0;
        }
        let native = apsp_native(&adj, n);
        let pjrt = rt.apsp(&adj, n).unwrap();
        assert_eq!(native.len(), pjrt.len());
        for (a, b) in native.iter().zip(&pjrt) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}
