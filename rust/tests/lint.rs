//! `esf lint` acceptance: every rule trips on its known-bad snippet with
//! the exact id and line, its waivered twin is silent, and — the real
//! gate — the repository's own sources lint clean.

use esf::lint::{lint_source, lint_tree, Finding};
use std::path::Path;

fn findings(rel: &str, src: &str) -> Vec<Finding> {
    lint_source(rel, src).findings
}

fn ids(rel: &str, src: &str) -> Vec<(&'static str, usize)> {
    findings(rel, src).iter().map(|f| (f.rule, f.line)).collect()
}

#[test]
fn l000_empty_waiver_reason() {
    assert_eq!(ids("devices/x.rs", "let a = 1;\nlet b = 2; // det-ok:\n"), vec![("ESF-L000", 2)]);
    // A reasoned waiver is itself silent.
    assert!(ids("devices/x.rs", "let b = 2; // det-ok: keyed only\n").is_empty());
}

#[test]
fn l001_hash_iteration() {
    let bad = "\
struct S { m: HashMap<u64, u64> }\n\
fn f(s: &S) { for (k, v) in s.m.iter() { use_kv(k, v); } }\n";
    let got = ids("devices/x.rs", bad);
    assert!(got.contains(&("ESF-L001", 2)), "{got:?}");
    // Twin: same shape, waivered (the declaration line too — ESF-L002).
    let ok = "\
// det-ok: ordering laundered through a sort below\n\
struct S { m: HashMap<u64, u64> }\n\
fn f(s: &S) {\n\
    // det-ok: collected into a BTreeMap before use\n\
    for (k, v) in s.m.iter() { use_kv(k, v); }\n\
}\n";
    assert!(ids("devices/x.rs", ok).is_empty());
    // for-loop sugar without an explicit iter() call also trips.
    let sugar = "let set: HashSet<u64> = HashSet::new();\nfor v in set { touch(v); }\n";
    let got = ids("devices/x.rs", sugar);
    assert!(got.contains(&("ESF-L001", 2)), "{got:?}");
}

#[test]
fn l002_hash_container_declaration() {
    assert_eq!(
        ids("engine/x.rs", "pub struct T { cache: HashMap<u64, u32> }\n"),
        vec![("ESF-L002", 1)]
    );
    // `use` lines never trip (importing is not using).
    assert!(ids("engine/x.rs", "use std::collections::HashMap;\n").is_empty());
    // Outside det paths the rule does not apply.
    assert!(ids("runtime/x.rs", "pub struct T { cache: HashMap<u64, u32> }\n").is_empty());
}

#[test]
fn l003_wall_clock_everywhere() {
    // Global rule: fires even outside det paths (util/, cli).
    assert_eq!(ids("util/x.rs", "let t0 = std::time::Instant::now();\n"), vec![("ESF-L003", 1)]);
    assert_eq!(ids("engine/x.rs", "let t = SystemTime::now();\n"), vec![("ESF-L003", 1)]);
    let waived = "// det-ok: host-side progress report only\nlet t0 = Instant::now();\n";
    assert!(ids("util/x.rs", waived).is_empty());
}

#[test]
fn l004_os_randomness_except_rng_module() {
    assert_eq!(ids("devices/x.rs", "let s = RandomState::new();\n"), vec![("ESF-L004", 1)]);
    assert_eq!(ids("util/json.rs", "let h = DefaultHasher::new();\n"), vec![("ESF-L004", 1)]);
    // The seeded-PRNG home is the one sanctioned module.
    assert!(ids("util/rng.rs", "let s = RandomState::new();\n").is_empty());
}

#[test]
fn l005_thread_identity() {
    assert_eq!(ids("sweep/x.rs", "let id = std::thread::current().id();\n"), vec![("ESF-L005", 1)]);
    assert!(ids("sweep/x.rs", "let h = std::thread::spawn(f);\n").is_empty());
}

#[test]
fn l006_float_time_outside_converters() {
    let bad = "let deadline = (x * 1.5) as Ps;\n";
    assert_eq!(ids("devices/x.rs", bad), vec![("ESF-L006", 1)]);
    // The sanctioned converter module is exempt.
    assert!(ids("engine/time.rs", bad).is_empty());
    // Integer arithmetic cast to Ps is fine.
    assert!(ids("devices/x.rs", "let t = (a + b) as Ps;\n").is_empty());
}

#[test]
fn l007_narrow_cast_of_timey_value() {
    assert_eq!(ids("engine/x.rs", "let s = txn_id as u32;\n"), vec![("ESF-L007", 1)]);
    assert_eq!(ids("interconnect/x.rs", "queue.push(deadline as u16);\n"), vec![("ESF-L007", 1)]);
    // Non-timey identifiers and het widths are fine.
    assert!(ids("engine/x.rs", "let w = width as u32;\n").is_empty());
    assert!(ids("engine/x.rs", "let g = gbps as u32;\n").is_empty());
    // u64 widening of a timey value is not a truncation.
    assert!(ids("engine/x.rs", "let t = time_ps as u64;\n").is_empty());
}

#[test]
fn waiver_accounting_is_reported() {
    let src = "// det-ok: keyed lookup only\nlet m: HashMap<u8, u8> = HashMap::new();\n";
    let r = lint_source("engine/x.rs", src);
    assert!(r.ok());
    assert_eq!(r.waivers_used, 1);
    // An unused waiver is not counted.
    let r = lint_source("engine/x.rs", "// det-ok: nothing here needs it\nlet x = 1;\n");
    assert_eq!(r.waivers_used, 0);
}

/// THE acceptance gate: the simulator's own sources carry zero findings.
/// CI runs the same scan via `esf lint --json`; this keeps `cargo test`
/// failing locally before CI ever sees a violation.
#[test]
fn repo_sources_lint_clean() {
    // Integration tests run with CWD = the package root (rust/).
    let report = lint_tree(Path::new("src")).expect("scan src/");
    assert!(report.files_scanned > 30, "scan found too few files — wrong root?");
    assert!(
        report.ok(),
        "determinism lint violations in the tree:\n{}",
        esf::lint::report_table(&report).render()
    );
    assert!(report.waivers_used >= 5, "expected the documented waivers to be live");
}
