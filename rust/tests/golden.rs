//! Golden determinism: the ladder-queue scheduler must produce results
//! byte-identical to the seed's binary-heap ordering on full mid-size
//! scenarios, and identical across repeat runs. Every observable the
//! paper's experiments report — per-requester latency sums/maxima, hop
//! histograms, link bus utility, DCOH snoop traffic — is folded into one
//! digest, so any silent reordering of event ties fails loudly here.

use esf::config::{build_system, BackendKind, System, SystemCfg};
use esf::devices::{MemDev, Pattern, Requester, VictimPolicy};
use esf::engine::EventQueue;
use esf::interconnect::{Duplex, Strategy, TopologyKind};

/// FNV-1a over a stream of u64 words.
struct Digest(u64);

impl Digest {
    fn new() -> Digest {
        Digest(0xcbf2_9ce4_8422_2325)
    }
    fn word(&mut self, w: u64) {
        for b in w.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
}

/// Fold every reported observable of a finished system into one digest.
fn digest(sys: &System, events: u64) -> u64 {
    let mut d = Digest::new();
    d.word(events);
    d.word(sys.engine.shared.dropped);
    d.word(sys.engine.shared.net.epoch_start);
    d.word(sys.engine.shared.net.epoch_end);
    for &r in &sys.requesters {
        let rq: &Requester = sys.engine.component(r).unwrap();
        d.word(rq.stats.completed);
        d.word(rq.stats.reads);
        d.word(rq.stats.writes);
        d.word(rq.stats.lat_sum as u64);
        d.word((rq.stats.lat_sum >> 64) as u64);
        d.word(rq.stats.lat_max);
        d.word(rq.stats.bytes);
        for (&hops, h) in &rq.stats.by_hops {
            d.word(hops as u64);
            d.word(h.count);
            d.word(h.lat_sum as u64);
            d.word(h.queue_sum as u64);
            d.word(h.switch_sum as u64);
            d.word(h.bus_sum as u64);
            d.word(h.device_sum as u64);
        }
    }
    for &m in &sys.memories {
        let md: &MemDev = sys.engine.component(m).unwrap();
        d.word(md.stats.received);
        d.word(md.stats.reads);
        d.word(md.stats.writes);
        d.word(md.stats.bisnp_sent);
        d.word(md.stats.birsp_received);
        d.word(md.stats.dirty_flushes);
        d.word(md.stats.inv_waits);
        d.word(md.stats.inv_wait_sum as u64);
    }
    let n_links = sys.engine.shared.topo.links.len();
    for link in 0..n_links {
        d.word(sys.engine.shared.net.payload_bytes(link));
        d.word(sys.engine.shared.net.bus_utility(link).to_bits());
    }
    d.0
}

/// Run `cfg` with the default (ladder) scheduler or the seed's
/// binary-heap reference, returning the full result digest.
fn run_digest(cfg: &SystemCfg, reference_heap: bool) -> u64 {
    let mut sys = build_system(cfg);
    if reference_heap {
        // Swap before the first run() — no events are pending yet.
        assert!(sys.engine.shared.queue.is_empty());
        sys.engine.shared.queue = EventQueue::reference_heap();
    }
    let events = sys.engine.run(u64::MAX);
    digest(&sys, events)
}

/// Mid-size spine-leaf scenario: mixed read/write, adaptive routing,
/// half-duplex links with turnaround — the queueing-heavy configuration
/// where event-tie ordering matters most.
fn spine_leaf_cfg() -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 6);
    cfg.seed = 1234;
    cfg.strategy = Strategy::Adaptive;
    cfg.pattern = Pattern::Random;
    cfg.read_ratio = 0.7;
    cfg.queue_capacity = 32;
    cfg.issue_interval = esf::engine::time::ns(2.0);
    cfg.requests_per_endpoint = 400;
    cfg.warmup_fraction = 0.25;
    cfg.link.duplex = Duplex::Half;
    cfg.link.turnaround = esf::engine::time::ns(2.0);
    cfg.backend = BackendKind::Fixed(30.0);
    cfg
}

/// Coherent scenario exercising the DCOH slab: skewed traffic, small
/// snoop filters, back-invalidations in flight.
fn coherent_cfg(policy: VictimPolicy) -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 4);
    cfg.seed = 77;
    cfg.pattern = Pattern::Skewed {
        hot_frac: 0.1,
        hot_prob: 0.9,
    };
    cfg.footprint_lines = 4000;
    cfg.cache_lines = 800;
    cfg.snoop_filter = Some((100, policy));
    cfg.requests_per_endpoint = 300;
    cfg.warmup_fraction = 0.5;
    cfg
}

#[test]
fn golden_ladder_matches_heap_reference_spine_leaf() {
    let cfg = spine_leaf_cfg();
    let ladder = run_digest(&cfg, false);
    let heap = run_digest(&cfg, true);
    assert_eq!(
        ladder, heap,
        "ladder queue reordered events vs the seed's heap semantics"
    );
}

#[test]
fn golden_ladder_matches_heap_reference_coherent() {
    for policy in [
        VictimPolicy::Fifo,
        VictimPolicy::Lfi,
        VictimPolicy::BlockLen { max_len: 4 },
    ] {
        let cfg = coherent_cfg(policy);
        let ladder = run_digest(&cfg, false);
        let heap = run_digest(&cfg, true);
        assert_eq!(ladder, heap, "diverged under {policy:?}");
    }
}

#[test]
fn golden_repeat_runs_are_identical() {
    let cfg = spine_leaf_cfg();
    assert_eq!(run_digest(&cfg, false), run_digest(&cfg, false));
    let cfg = coherent_cfg(VictimPolicy::Lifo);
    assert_eq!(run_digest(&cfg, false), run_digest(&cfg, false));
}

/// The digest itself must be sensitive: different seeds produce different
/// event interleavings, so their digests must differ (guards against a
/// degenerate digest that always collides).
#[test]
fn golden_digest_is_sensitive_to_seed() {
    let mut a = spine_leaf_cfg();
    let mut b = spine_leaf_cfg();
    a.seed = 1;
    b.seed = 2;
    assert_ne!(run_digest(&a, false), run_digest(&b, false));
}

/// The `(time, seq)` ordering contract pinned as hand-computed constants,
/// for BOTH queue implementations. The A/B tests above cannot catch a
/// change that reorders ladder and heap in lockstep (e.g. editing `Ev`'s
/// `Ord` impl or the seq assignment); this one can — the expected pop
/// order below is written out by hand from the contract, not computed.
#[test]
fn golden_event_order_contract_is_pinned() {
    for mut q in [EventQueue::default(), EventQueue::reference_heap()] {
        // tag:       0        1       2        3
        q.schedule(10, 0, esf::engine::Payload::Timer(0, 0)); // seq 0
        q.schedule(5, 0, esf::engine::Payload::Timer(1, 0)); //  seq 1
        q.schedule(10, 0, esf::engine::Payload::Timer(2, 0)); // seq 2
        q.schedule(7, 0, esf::engine::Payload::Timer(3, 0)); //  seq 3
        let mut order: Vec<(u64, u64, u64)> = Vec::new();
        let mut injected = false;
        while let Some(ev) = q.pop() {
            let tag = match ev.payload {
                esf::engine::Payload::Timer(t, _) => t,
                _ => unreachable!(),
            };
            order.push((ev.time, ev.seq, tag));
            if !injected {
                injected = true;
                // Mid-drain same-time tie: seq 4, must pop after nothing
                // else at t=5 remains but before t=7.
                q.schedule(5, 0, esf::engine::Payload::Timer(4, 0));
            }
        }
        // Hand-computed: (5,seq1,tag1) first; injected (5,seq4,tag4)
        // next (same time, larger seq than everything at t=5); then
        // (7,seq3,tag3); then FIFO among the t=10 tie: seq0 before seq2.
        assert_eq!(
            order,
            vec![(5, 1, 1), (5, 4, 4), (7, 3, 3), (10, 0, 0), (10, 2, 2)],
            "the (time, seq) ordering contract changed"
        );
    }
}

/// Recorded-constant digest: once `tests/golden_digest.txt` is committed
/// (generated on a machine with a toolchain by running this test, which
/// prints the current values when the file is absent), any change to the
/// simulation's observable output — including a lockstep reordering of
/// both queue implementations — fails here. Absent the file, the A/B and
/// contract tests above are the guard.
#[test]
fn golden_digest_matches_recorded_constant() {
    let spine = run_digest(&spine_leaf_cfg(), false);
    let coherent = run_digest(&coherent_cfg(VictimPolicy::Lifo), false);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_digest.txt");
    match std::fs::read_to_string(path) {
        Ok(text) => {
            for line in text.lines() {
                let Some((key, val)) = line.split_once('=') else {
                    continue;
                };
                let val = val.trim().trim_start_matches("0x");
                let want = u64::from_str_radix(val, 16).expect("hex digest");
                let got = match key.trim() {
                    "spine_leaf" => spine,
                    "coherent_lifo" => coherent,
                    other => panic!("unknown digest key '{other}'"),
                };
                assert_eq!(
                    got, want,
                    "digest '{}' changed vs recorded constant — simulation \
                     output is no longer byte-identical to the recorded run",
                    key.trim()
                );
            }
        }
        Err(_) => {
            // Bootstrap: no recorded constants yet. Print them so a
            // toolchain-equipped run can commit the file.
            println!("golden_digest.txt not found; current digests:");
            println!("spine_leaf=0x{spine:016x}");
            println!("coherent_lifo=0x{coherent:016x}");
        }
    }
}
