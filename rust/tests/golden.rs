//! Golden determinism: the ladder-queue scheduler must produce results
//! byte-identical to the seed's binary-heap ordering on full mid-size
//! scenarios, and identical across repeat runs. Every observable the
//! paper's experiments report — per-requester latency sums/maxima, hop
//! histograms, link bus utility, DCOH snoop traffic — is folded into one
//! digest, so any silent reordering of event ties fails loudly here.

mod common;

use common::{check_recorded, run_digest};
use esf::config::{BackendKind, SystemCfg};
use esf::devices::{Pattern, VictimPolicy};
use esf::engine::EventQueue;
use esf::interconnect::{Duplex, Strategy, TopologyKind};

/// Mid-size spine-leaf scenario: mixed read/write, adaptive routing,
/// half-duplex links with turnaround — the queueing-heavy configuration
/// where event-tie ordering matters most.
fn spine_leaf_cfg() -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 6);
    cfg.seed = 1234;
    cfg.strategy = Strategy::Adaptive;
    cfg.pattern = Pattern::Random;
    cfg.read_ratio = 0.7;
    cfg.queue_capacity = 32;
    cfg.issue_interval = esf::engine::time::ns(2.0);
    cfg.requests_per_endpoint = 400;
    cfg.warmup_fraction = 0.25;
    cfg.link.duplex = Duplex::Half;
    cfg.link.turnaround = esf::engine::time::ns(2.0);
    cfg.backend = BackendKind::Fixed(30.0);
    cfg
}

/// Coherent scenario exercising the DCOH slab: skewed traffic, small
/// snoop filters, back-invalidations in flight.
fn coherent_cfg(policy: VictimPolicy) -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 4);
    cfg.seed = 77;
    cfg.pattern = Pattern::Skewed {
        hot_frac: 0.1,
        hot_prob: 0.9,
    };
    cfg.footprint_lines = 4000;
    cfg.cache_lines = 800;
    cfg.snoop_filter = Some((100, policy));
    cfg.requests_per_endpoint = 300;
    cfg.warmup_fraction = 0.5;
    cfg
}

#[test]
fn golden_ladder_matches_heap_reference_spine_leaf() {
    let cfg = spine_leaf_cfg();
    let ladder = run_digest(&cfg, false);
    let heap = run_digest(&cfg, true);
    assert_eq!(
        ladder, heap,
        "ladder queue reordered events vs the seed's heap semantics"
    );
}

#[test]
fn golden_ladder_matches_heap_reference_coherent() {
    for policy in [
        VictimPolicy::Fifo,
        VictimPolicy::Lfi,
        VictimPolicy::BlockLen { max_len: 4 },
    ] {
        let cfg = coherent_cfg(policy);
        let ladder = run_digest(&cfg, false);
        let heap = run_digest(&cfg, true);
        assert_eq!(ladder, heap, "diverged under {policy:?}");
    }
}

#[test]
fn golden_repeat_runs_are_identical() {
    let cfg = spine_leaf_cfg();
    assert_eq!(run_digest(&cfg, false), run_digest(&cfg, false));
    let cfg = coherent_cfg(VictimPolicy::Lifo);
    assert_eq!(run_digest(&cfg, false), run_digest(&cfg, false));
}

/// The digest itself must be sensitive: different seeds produce different
/// event interleavings, so their digests must differ (guards against a
/// degenerate digest that always collides).
#[test]
fn golden_digest_is_sensitive_to_seed() {
    let mut a = spine_leaf_cfg();
    let mut b = spine_leaf_cfg();
    a.seed = 1;
    b.seed = 2;
    assert_ne!(run_digest(&a, false), run_digest(&b, false));
}

/// The compat-path `(time, seq)` ordering contract pinned as hand-computed
/// constants, for BOTH queue implementations. The A/B tests above cannot
/// catch a change that reorders ladder and heap in lockstep (e.g. editing
/// `Ev`'s `Ord` impl or the seq assignment); this one can — the expected
/// pop order below is written out by hand from the contract, not computed.
/// (`EventQueue::schedule` assigns `src = u32::MAX` + a queue-global seq,
/// so the canonical `(time, src, seq)` key degenerates to the seed's
/// `(time, seq)` here; the keyed engine-path contract is pinned below.)
#[test]
fn golden_event_order_contract_is_pinned() {
    for mut q in [EventQueue::default(), EventQueue::reference_heap()] {
        // tag:       0        1       2        3
        q.schedule(10, 0, esf::engine::Payload::Timer(0, 0)); // seq 0
        q.schedule(5, 0, esf::engine::Payload::Timer(1, 0)); //  seq 1
        q.schedule(10, 0, esf::engine::Payload::Timer(2, 0)); // seq 2
        q.schedule(7, 0, esf::engine::Payload::Timer(3, 0)); //  seq 3
        let mut order: Vec<(u64, u64, u64)> = Vec::new();
        let mut injected = false;
        while let Some(ev) = q.pop() {
            let tag = match ev.payload {
                esf::engine::Payload::Timer(t, _) => t,
                _ => unreachable!(),
            };
            order.push((ev.time, ev.seq, tag));
            if !injected {
                injected = true;
                // Mid-drain same-time tie: seq 4, must pop after nothing
                // else at t=5 remains but before t=7.
                q.schedule(5, 0, esf::engine::Payload::Timer(4, 0));
            }
        }
        // Hand-computed: (5,seq1,tag1) first; injected (5,seq4,tag4)
        // next (same time, larger seq than everything at t=5); then
        // (7,seq3,tag3); then FIFO among the t=10 tie: seq0 before seq2.
        assert_eq!(
            order,
            vec![(5, 1, 1), (5, 4, 4), (7, 3, 3), (10, 0, 0), (10, 2, 2)],
            "the (time, seq) ordering contract changed"
        );
    }
}

/// The engine-path canonical key `(time, src, seq)` pinned by hand: ties
/// at one timestamp order by scheduling node first, then that node's own
/// schedule order — the location-independent tie-break that makes the
/// partitioned engine byte-identical to the sequential one.
#[test]
fn golden_keyed_order_contract_is_pinned() {
    use esf::engine::Ev;
    for mut q in [EventQueue::default(), EventQueue::reference_heap()] {
        let mk = |time: u64, src: u32, seq: u64, tag: u64| Ev {
            time,
            src,
            seq,
            target: 0,
            payload: esf::engine::Payload::Timer(tag, 0),
        };
        q.push(mk(10, 2, 0, 3)); // same time, src 2
        q.push(mk(10, 0, 7, 0)); // same time, src 0 -> first of the t=10 tie
        q.push(mk(4, 9, 1, 9)); //  earliest time wins regardless of src
        q.push(mk(10, 0, 8, 1)); // src 0 again, later seq
        q.push(mk(10, 1, 0, 2));
        let tags: Vec<u64> = std::iter::from_fn(|| q.pop())
            .map(|ev| match ev.payload {
                esf::engine::Payload::Timer(t, _) => t,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(
            tags,
            vec![9, 0, 1, 2, 3],
            "the canonical (time, src, seq) ordering contract changed"
        );
    }
}

/// Recorded-constant digest: once `tests/golden_digest.txt` is recorded
/// (ESF_GOLDEN=record on a toolchain machine — CI does this and enforces
/// with ESF_GOLDEN=require), any change to the simulation's observable
/// output — including a lockstep reordering of both queue implementations
/// — fails here. Absent the file, the A/B and contract tests above are
/// the guard and unrecorded values are printed for pinning.
#[test]
fn golden_digest_matches_recorded_constant() {
    check_recorded(&[
        ("spine_leaf", run_digest(&spine_leaf_cfg(), false)),
        ("coherent_lifo", run_digest(&coherent_cfg(VictimPolicy::Lifo), false)),
    ]);
}
