//! Shared golden-test machinery: the full-system result digest and the
//! recorded-constant store (`tests/golden_digest.txt`), used by both
//! `tests/golden.rs` (scheduler determinism) and `tests/sweep_axes.rs`
//! (scenario-axis pinning).
//!
//! ## Recorded-constant modes (`ESF_GOLDEN` env var)
//!
//!  * unset — keys present in `golden_digest.txt` are enforced; missing
//!    keys print their current value (bootstrap-friendly: a toolchain-less
//!    checkout still passes tier-1).
//!  * `ESF_GOLDEN=record` — compute digests and (re)write the file,
//!    merging with any keys other test binaries recorded.
//!  * `ESF_GOLDEN=require` — CI mode: a missing file or key is a hard
//!    failure, so the recorded-digest check cannot silently degrade to
//!    print-and-skip.

#![allow(dead_code)]

use esf::config::{build_system, System, SystemCfg};
use esf::devices::{MemDev, Requester};
use esf::engine::EventQueue;
use esf::util::Fnv64;
use std::collections::BTreeMap;

/// Fold every reported observable of a finished system into one digest:
/// per-requester counters, latency sums/extremes, the exact latency
/// histogram, hop breakdowns, DCOH snoop traffic, and per-link bytes +
/// bus utility. Any silent change to simulation output moves this value.
pub fn digest(sys: &System, events: u64) -> u64 {
    let mut d = Fnv64::new();
    d.word(events);
    d.word(sys.engine.shared.dropped);
    d.word(sys.engine.shared.net.epoch_start);
    d.word(sys.engine.shared.net.epoch_end);
    for &r in &sys.requesters {
        let rq: &Requester = sys.engine.component(r).unwrap();
        d.word(rq.stats.completed);
        d.word(rq.stats.reads);
        d.word(rq.stats.writes);
        d.word(rq.stats.lat_sum as u64);
        d.word((rq.stats.lat_sum >> 64) as u64);
        d.word(rq.stats.lat_max);
        d.word(rq.stats.bytes);
        for (&lat, &count) in &rq.stats.lat_hist {
            d.word(lat);
            d.word(count);
        }
        for (&hops, h) in &rq.stats.by_hops {
            d.word(hops as u64);
            d.word(h.count);
            d.word(h.lat_sum as u64);
            d.word(h.queue_sum as u64);
            d.word(h.switch_sum as u64);
            d.word(h.bus_sum as u64);
            d.word(h.device_sum as u64);
        }
    }
    for &m in &sys.memories {
        let md: &MemDev = sys.engine.component(m).unwrap();
        d.word(md.stats.received);
        d.word(md.stats.reads);
        d.word(md.stats.writes);
        d.word(md.stats.bisnp_sent);
        d.word(md.stats.birsp_received);
        d.word(md.stats.dirty_flushes);
        d.word(md.stats.inv_waits);
        d.word(md.stats.inv_wait_sum as u64);
    }
    let n_links = sys.engine.shared.topo.links.len();
    for link in 0..n_links {
        d.word(sys.engine.shared.net.payload_bytes(link));
        d.word(sys.engine.shared.net.bus_utility(link).to_bits());
    }
    d.finish()
}

/// Run `cfg` with the default (ladder) scheduler or the seed's
/// binary-heap reference, returning the full result digest.
pub fn run_digest(cfg: &SystemCfg, reference_heap: bool) -> u64 {
    let mut sys = build_system(cfg);
    if reference_heap {
        // Swap before the first run() — no events are pending yet.
        assert!(sys.engine.shared.queue.is_empty());
        sys.engine.shared.queue = EventQueue::reference_heap();
    }
    let events = sys.engine.run(u64::MAX);
    digest(&sys, events)
}

/// Run `cfg` through the partitioned event-domain engine on `jobs`
/// worker threads; the digest must be byte-identical to `run_digest` —
/// the `--intra-jobs` determinism contract (`tests/partition.rs`).
/// Delegates to the model-explicit variant with the engine's default
/// weighting so there is exactly one digest recipe to keep in sync.
pub fn run_digest_partitioned(cfg: &SystemCfg, jobs: usize) -> u64 {
    run_digest_partitioned_model(cfg, jobs, esf::interconnect::WeightModel::Traffic)
}

/// [`run_digest_partitioned`] under an explicit domain weighting — the
/// traffic-vs-node-count A/B surface: every weighting must reproduce the
/// sequential digest bit-for-bit (only the domain shapes may differ).
pub fn run_digest_partitioned_model(
    cfg: &SystemCfg,
    jobs: usize,
    model: esf::interconnect::WeightModel,
) -> u64 {
    let mut sys = build_system(cfg);
    let events = sys.engine.run_partitioned_model(jobs, model);
    digest(&sys, events)
}

/// [`run_digest_partitioned_model`] under an explicit barrier mode — the
/// adaptive-vs-fixed-window A/B surface: both window protocols must
/// reproduce the sequential digest bit-for-bit (only the exchange
/// accounting may differ).
pub fn run_digest_partitioned_opts(
    cfg: &SystemCfg,
    jobs: usize,
    model: esf::interconnect::WeightModel,
    mode: esf::engine::parallel::BarrierMode,
) -> u64 {
    let mut sys = build_system(cfg);
    let events = sys.engine.run_partitioned_opts(jobs, model, mode);
    digest(&sys, events)
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GoldenMode {
    /// Enforce recorded keys, print unrecorded ones.
    Check,
    /// Rewrite the recorded file (merging other binaries' keys).
    Record,
    /// Enforce; missing file/key fails (CI).
    Require,
}

pub fn golden_mode() -> GoldenMode {
    match std::env::var("ESF_GOLDEN").as_deref() {
        Ok("record") => GoldenMode::Record,
        Ok("require") => GoldenMode::Require,
        _ => GoldenMode::Check,
    }
}

fn digest_path() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden_digest.txt")
}

fn read_recorded() -> BTreeMap<String, u64> {
    let mut out = BTreeMap::new();
    let Ok(text) = std::fs::read_to_string(digest_path()) else {
        return out;
    };
    for line in text.lines() {
        let Some((key, val)) = line.split_once('=') else {
            continue; // comments / blank lines
        };
        let val = val.trim().trim_start_matches("0x");
        if let Ok(v) = u64::from_str_radix(val, 16) {
            out.insert(key.trim().to_string(), v);
        }
    }
    out
}

/// Compare (or record) this binary's digest entries against the recorded
/// constants. See the module docs for the `ESF_GOLDEN` modes.
pub fn check_recorded(entries: &[(&str, u64)]) {
    let mut recorded = read_recorded();
    match golden_mode() {
        GoldenMode::Record => {
            for &(key, val) in entries {
                recorded.insert(key.to_string(), val);
            }
            let mut out = String::from(
                "# Recorded golden digests — generated by running the golden test\n\
                 # binaries with ESF_GOLDEN=record on a toolchain machine. Any change\n\
                 # to simulation output (even a lockstep reordering of both event\n\
                 # queue implementations) fails the recorded-constant tests.\n",
            );
            for (key, val) in &recorded {
                out.push_str(&format!("{key}=0x{val:016x}\n"));
            }
            std::fs::write(digest_path(), out).expect("write golden_digest.txt");
            println!("golden: recorded {} digest(s) into {}", entries.len(), digest_path());
        }
        mode => {
            let require = mode == GoldenMode::Require;
            for &(key, val) in entries {
                match recorded.get(key) {
                    Some(&want) => assert_eq!(
                        val, want,
                        "digest '{key}' changed vs recorded constant — simulation \
                         output is no longer byte-identical to the recorded run"
                    ),
                    None if require => panic!(
                        "digest '{key}' is not recorded in golden_digest.txt and \
                         ESF_GOLDEN=require is set; run the golden tests once with \
                         ESF_GOLDEN=record and commit the file"
                    ),
                    None => println!(
                        "golden: '{key}' not recorded yet; current value \
                         {key}=0x{val:016x} (run with ESF_GOLDEN=record to pin)"
                    ),
                }
            }
        }
    }
}
