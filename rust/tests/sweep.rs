//! Sweep subsystem integration: `esf`-level determinism across job
//! counts. The driver collects results in submission order, so the same
//! grid must render byte-identical output for `--jobs 1` and `--jobs 8`.

use esf::sweep::{results_table, run_scenarios, GridSpec};

/// A 16-scenario grid small enough for CI: 4 topologies x 2 scales x
/// 2 R:W mixes, light request budget.
fn grid_16() -> GridSpec {
    GridSpec::from_json_str(
        r#"{
            "base": {
                "link": {"bandwidth_gbps": 32, "header_bytes": 0},
                "requester": {"requests_per_endpoint": 60,
                              "issue_interval_ns": 2,
                              "queue_capacity": 32},
                "memory": {"backend": "fixed", "latency_ns": 20}
            },
            "sweep": {
                "topology": ["chain", "ring", "spine-leaf", "fc"],
                "scale": [4, 8],
                "read_ratio": [1.0, 0.5]
            }
        }"#,
    )
    .expect("valid grid")
}

#[test]
fn sweep_results_byte_identical_for_jobs_1_and_8() {
    let g1 = grid_16();
    let g8 = grid_16();
    assert_eq!(g1.scenarios.len(), 16);
    let r1 = run_scenarios(g1.scenarios, 1);
    let r8 = run_scenarios(g8.scenarios, 8);
    let c1 = results_table(&r1).to_csv();
    let c8 = results_table(&r8).to_csv();
    assert_eq!(c1, c8, "sweep output must not depend on worker count");
    assert!(r1.iter().all(|r| r.completed > 0));
}

#[test]
fn sweep_results_arrive_in_submission_order() {
    let g = grid_16();
    let labels: Vec<String> = g.scenarios.iter().map(|s| s.label.clone()).collect();
    let got: Vec<String> = run_scenarios(g.scenarios, 8)
        .into_iter()
        .map(|r| r.label)
        .collect();
    assert_eq!(got, labels);
}

#[test]
fn experiment_harness_identical_across_job_counts() {
    // fig10 exercises the (topology x scale) grid through the same
    // driver `esf exp fig10 --jobs N` uses.
    let a = esf::experiments::run_jobs("fig10", true, 1).expect("known id");
    let b = esf::experiments::run_jobs("fig10", true, 8).expect("known id");
    let ra: Vec<String> = a.iter().map(|t| t.render()).collect();
    let rb: Vec<String> = b.iter().map(|t| t.render()).collect();
    assert_eq!(ra, rb, "fig10 tables must be identical for any --jobs");
}
