//! `esf check` acceptance: every model-check rule must reject its
//! known-bad fixture with the exact rule id and error locus, and every
//! shipped example config/grid must pass clean (the CLI runs these checks
//! as a pre-pass, so a regression here would brick `esf run`/`esf sweep`).

use esf::check::grid::check_grid_str;
use esf::check::{check_config, check_links, check_partition, check_routing, check_system};
use esf::config::SystemCfg;
use esf::engine::time::Ps;
use esf::interconnect::{
    build, Duplex, LinkCfg, NodeKind, Partition, Routing, Topology, TopologyKind,
};

fn two_node() -> Topology {
    let mut t = Topology::new();
    let r = t.add_node("r0", NodeKind::Requester);
    let m = t.add_node("m0", NodeKind::Memory);
    t.add_link(r, m, LinkCfg::default());
    t
}

#[test]
fn presets_and_examples_pass_clean() {
    for kind in [
        TopologyKind::FullyConnected,
        TopologyKind::SpineLeaf,
        TopologyKind::Chain,
    ] {
        for intra in [1usize, 4] {
            let mut cfg = SystemCfg::new(kind, 8);
            cfg.intra_jobs = intra;
            let r = check_system(&cfg);
            assert!(r.ok(), "{kind:?} intra={intra}: {:?}", r.errors);
        }
    }
    // The example grids gate CI's sweep smoke job through the pre-pass.
    for path in ["../examples/sweep_grid.json", "../examples/sweep_grid_full.json"] {
        let text = std::fs::read_to_string(path).unwrap();
        let r = check_grid_str(&text);
        assert!(r.ok(), "{path}: {:?}", r.errors);
    }
}

#[test]
fn cyclic_routing_table_fails_c001() {
    // Corrupt distance matrix: dist(1,0)=2 in a 2-node fabric, so node 1
    // has no distance-decreasing candidate toward 0 — the exact shape a
    // buggy APSP kernel would produce (packets would bounce forever).
    let t = two_node();
    let routing = Routing::from_distances(&t, &[0.0, 1.0, 2.0, 0.0], 1e9);
    let errs = check_routing(&t, &routing);
    assert_eq!(errs.len(), 1, "{errs:?}");
    assert_eq!(errs[0].rule, "ESF-C001");
    assert_eq!(errs[0].path, "route[1->0]");
}

#[test]
fn unreachable_memory_fails_c002() {
    // Distance matrix claims no path either way despite the link.
    let t = two_node();
    let routing = Routing::from_distances(&t, &[0.0, 1e9, 1e9, 0.0], 1e9);
    let errs = check_routing(&t, &routing);
    assert_eq!(errs.len(), 1, "{errs:?}");
    assert_eq!(errs[0].rule, "ESF-C002");
    assert_eq!(errs[0].path, "route[0->1]");
}

#[test]
fn healthy_bfs_routing_passes() {
    let fabric = build(TopologyKind::SpineLeaf, 8, LinkCfg::default());
    let routing = Routing::build_bfs(&fabric.topo);
    assert!(check_routing(&fabric.topo, &routing).is_empty());
}

#[test]
fn mismatched_duplex_pair_fails_c003() {
    let mut t = Topology::new();
    let r = t.add_node("r0", NodeKind::Requester);
    let m = t.add_node("m0", NodeKind::Memory);
    t.add_link(r, m, LinkCfg::default());
    t.add_link(r, m, LinkCfg { duplex: Duplex::Half, ..LinkCfg::default() });
    let errs = check_links(&t);
    assert_eq!(errs.len(), 1, "{errs:?}");
    assert_eq!(errs[0].rule, "ESF-C003");
    assert_eq!(errs[0].path, "link[1]");
}

#[test]
fn turnaround_on_full_duplex_fails_c004() {
    let mut t = two_node();
    t.links[0].cfg.turnaround = 500;
    let errs = check_links(&t);
    assert_eq!(errs.len(), 1, "{errs:?}");
    assert_eq!(errs[0].rule, "ESF-C004");
    assert_eq!(errs[0].path, "link[0]");
}

#[test]
fn corrupted_domain_map_fails_c005_and_c006() {
    let t = two_node();
    let mut part = Partition::single(&t);
    // Node 1 claims domain 1 while membership says domain 0: the cover
    // is inconsistent AND the 0-1 link now "crosses" without being cut.
    part.domain_of[1] = 1;
    let errs = check_partition(&t, &part);
    let rules: Vec<_> = errs.iter().map(|e| e.rule).collect();
    assert!(rules.contains(&"ESF-C005"), "{errs:?}");
    assert!(rules.contains(&"ESF-C006"), "{errs:?}");
}

#[test]
fn bogus_cut_link_fails_c006_and_c007() {
    let t = two_node();
    let mut part = Partition::single(&t);
    // Cut a link that does not cross domains; lookahead (Ps::MAX for the
    // single partition) then also disagrees with the cut's min latency.
    part.cut_links.push(0);
    let errs = check_partition(&t, &part);
    let rules: Vec<_> = errs.iter().map(|e| e.rule).collect();
    assert!(rules.contains(&"ESF-C006"), "{errs:?}");
    assert!(rules.contains(&"ESF-C007"), "{errs:?}");
}

#[test]
fn zero_lookahead_fails_c007() {
    let fabric = build(TopologyKind::Chain, 4, LinkCfg::default());
    let mut part = Partition::compute(&fabric.topo, 2);
    assert!(
        check_partition(&fabric.topo, &part).is_empty(),
        "healthy computed partition must pass"
    );
    part.lookahead = 0;
    let errs = check_partition(&fabric.topo, &part);
    assert_eq!(errs.len(), 1, "{errs:?}");
    assert_eq!(errs[0].rule, "ESF-C007");
    assert_eq!(errs[0].path, "partition.lookahead");
}

#[test]
fn wrong_lookahead_value_fails_c007() {
    let fabric = build(TopologyKind::Chain, 4, LinkCfg::default());
    let mut part = Partition::compute(&fabric.topo, 2);
    part.lookahead = Ps::MAX;
    let errs = check_partition(&fabric.topo, &part);
    assert_eq!(errs.len(), 1, "{errs:?}");
    assert_eq!(errs[0].rule, "ESF-C007");
}

#[test]
fn txn_capacity_overflow_fails_c008() {
    let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 2);
    cfg.requests_per_endpoint = 1 << 37;
    let errs = check_config(&cfg);
    assert!(errs.iter().any(|e| e.rule == "ESF-C008"), "{errs:?}");
    // ...and the full pre-pass surfaces it too.
    let r = check_system(&cfg);
    assert!(r.errors.iter().any(|e| e.rule == "ESF-C008"));
}

#[test]
fn out_of_range_values_fail_c012_with_paths() {
    let cfg = SystemCfg::from_json_str(
        r#"{"requester": {"read_ratio": 1.5, "warmup_fraction": 1.0, "queue_capacity": 0}}"#,
    )
    .unwrap();
    let errs = check_config(&cfg);
    let got: Vec<_> = errs.iter().map(|e| (e.rule, e.path.as_str())).collect();
    assert!(got.contains(&("ESF-C012", "$.requester.read_ratio")), "{got:?}");
    assert!(got.contains(&("ESF-C012", "$.requester.warmup_fraction")), "{got:?}");
    assert!(got.contains(&("ESF-C012", "$.requester.queue_capacity")), "{got:?}");
}

#[test]
fn malformed_grids_fail_with_exact_paths() {
    // Unparseable text: ESF-C000 with a byte offset.
    let r = check_grid_str("{\"sweep\": [1,");
    assert_eq!(r.errors[0].rule, "ESF-C000");

    // Bad axis value: located to the element.
    let r = check_grid_str(r#"{"sweep": {"topology": ["ring", "mobius"]}}"#);
    assert_eq!(r.errors.len(), 1, "{:?}", r.errors);
    assert_eq!(r.errors[0].rule, "ESF-C010");
    assert_eq!(r.errors[0].path, "$.sweep.topology[1]");

    // Unknown axis, empty axis, non-array axis: all collected in one pass.
    let r = check_grid_str(r#"{"sweep": {"warp": [1], "scale": [], "seed": 3}}"#);
    let got: Vec<_> = r.errors.iter().map(|e| (e.rule, e.path.as_str())).collect();
    assert!(got.contains(&("ESF-C010", "$.sweep.warp")), "{got:?}");
    assert!(got.contains(&("ESF-C010", "$.sweep.scale")), "{got:?}");
    assert!(got.contains(&("ESF-C010", "$.sweep.seed")), "{got:?}");
}

#[test]
fn report_renders_table_and_json() {
    let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 2);
    cfg.requests_per_endpoint = 1 << 37;
    let r = check_system(&cfg);
    assert!(!r.ok());
    let table = r.to_table().render();
    assert!(table.contains("ESF-C008"), "{table}");
    let json = r.to_json().to_string();
    assert!(json.contains("\"ok\":false") || json.contains("\"ok\": false"), "{json}");
}
