//! Engine checkpoint/restore contract tests.
//!
//! The pinned contract is *restore-then-run is byte-identical to
//! straight-through*: an engine restored from a snapshot and run to
//! completion must reproduce the full result digest (`tests/common`)
//! of a run that never stopped — across topologies, media backends,
//! coherence, and intra-scenario widths — and taking a snapshot must
//! never perturb the donor run. On top of that sit the warm-start
//! guarantees: configs sharing a warm-up prefix projection fork from
//! one quiescent snapshot (byte-equal prefixes, cold-identical
//! results), and `check::check_snapshot` rejects corrupt or
//! incompatible files with located ESF-C014 errors before a restore
//! can go wrong.

mod common;

use common::{digest, run_digest};
use esf::check::check_snapshot;
use esf::config::{build_system, BackendKind, SystemCfg};
use esf::devices::VictimPolicy;
use esf::dram::DramCfg;
use esf::engine::snapshot::SnapMeta;
use esf::engine::time::ns;
use esf::interconnect::TopologyKind;
use esf::ssd::SsdCfg;

fn meta_for(cfg: &SystemCfg, quiescent: bool) -> SnapMeta {
    SnapMeta {
        cfg_fingerprint: cfg.fingerprint(),
        prefix_fingerprint: cfg.prefix_fingerprint(),
        prefix_canon: cfg.prefix_canon(),
        quiescent,
    }
}

/// Simulate `cfg`'s warm-up prefix and snapshot at the quiescent
/// (collection-flip) boundary — what the sweep warm-start path does.
fn quiescent_snapshot(cfg: &SystemCfg) -> Vec<u8> {
    let mut sys = build_system(cfg);
    sys.engine.run_until_collecting();
    sys.engine.snapshot(&meta_for(cfg, true))
}

/// Restore `snap` into a freshly built `cfg` system, run to completion
/// (sequential or partitioned), and digest the result. Events are the
/// engine's cumulative count — the snapshot carries the prefix's share.
fn restore_digest(cfg: &SystemCfg, snap: &[u8], intra: usize) -> u64 {
    let mut sys = build_system(cfg);
    let hdr = sys.engine.restore(snap).expect("restore");
    if intra == 1 {
        sys.engine.run(u64::MAX);
    } else {
        assert!(hdr.quiescent, "partitioned resume needs a quiescent snapshot");
        sys.engine.run_partitioned(intra);
    }
    digest(&sys, sys.engine.events_processed)
}

/// The coverage grid: plain fabrics, a generated large-fabric kind, a
/// coherent system (requester caches + LFI snoop filter + BISnp
/// traffic), and both media backends with internal dynamic state (DRAM
/// bank/row registers, SSD FTL map + placement RNG).
fn checkpoint_cfgs() -> Vec<(&'static str, SystemCfg)> {
    let mut spine = SystemCfg::new(TopologyKind::SpineLeaf, 8);
    spine.requests_per_endpoint = 300;
    spine.read_ratio = 0.7;
    let mut drag = SystemCfg::new(TopologyKind::Dragonfly, 8);
    drag.requests_per_endpoint = 200;
    drag.seed = 7;
    let mut coherent = SystemCfg::new(TopologyKind::Ring, 4);
    coherent.requests_per_endpoint = 250;
    coherent.cache_lines = 64;
    coherent.snoop_filter = Some((128, VictimPolicy::Lfi));
    coherent.read_ratio = 0.5;
    let mut dram = SystemCfg::new(TopologyKind::FullyConnected, 4);
    dram.requests_per_endpoint = 200;
    dram.backend = BackendKind::Dram(DramCfg::ddr5_4800());
    dram.read_ratio = 0.8;
    let mut ssd = SystemCfg::new(TopologyKind::Chain, 4);
    ssd.requests_per_endpoint = 120;
    ssd.backend = BackendKind::Ssd(SsdCfg::default());
    ssd.read_ratio = 0.6;
    vec![
        ("spine-leaf", spine),
        ("dragonfly", drag),
        ("coherent-ring", coherent),
        ("dram-fc", dram),
        ("ssd-chain", ssd),
    ]
}

#[test]
fn quiescent_restore_is_byte_identical_across_topologies_and_widths() {
    for (name, cfg) in checkpoint_cfgs() {
        let straight = run_digest(&cfg, false);
        let snap = quiescent_snapshot(&cfg);
        for intra in [1usize, 2, 4] {
            assert_eq!(
                restore_digest(&cfg, &snap, intra),
                straight,
                "{name}: restore-then-run diverged at intra_jobs={intra}"
            );
        }
    }
}

#[test]
fn mid_run_checkpoints_resume_byte_identically_and_never_perturb_the_donor() {
    let cfgs = checkpoint_cfgs();
    let (_, cfg) = &cfgs[0];
    let straight = run_digest(cfg, false);
    // Donor: step in simulated-time slices, snapshotting between slices
    // (the `esf run --checkpoint-every` loop), then finish.
    let mut sys = build_system(cfg);
    let every = ns(50_000.0);
    let mut bound = every;
    let mut snaps = Vec::new();
    loop {
        sys.engine.run_until(bound);
        bound += every;
        if sys.engine.shared.queue.is_empty() {
            break;
        }
        snaps.push(sys.engine.snapshot(&meta_for(cfg, false)));
    }
    // Stepping + snapshotting must not perturb the donor's results.
    assert_eq!(
        digest(&sys, sys.engine.events_processed),
        straight,
        "snapshotting perturbed the donor run"
    );
    assert!(
        !snaps.is_empty(),
        "slice width produced no mid-run checkpoints; shrink `every`"
    );
    // Every checkpoint resumes to the same bytes ("kill at any slice").
    for (i, snap) in snaps.iter().enumerate() {
        assert_eq!(
            restore_digest(cfg, snap, 1),
            straight,
            "resume from checkpoint {i} diverged"
        );
    }
}

#[test]
fn prefix_sharing_forks_are_cold_identical_and_prefixes_are_byte_equal() {
    let mut a = SystemCfg::new(TopologyKind::SpineLeaf, 6);
    a.requests_per_endpoint = 240;
    a.read_ratio = 0.35;
    let mut b = a.clone();
    b.read_ratio = 0.75;
    // Same warm-up prefix projection, different full configs.
    assert_eq!(a.prefix_fingerprint(), b.prefix_fingerprint());
    assert_ne!(a.fingerprint(), b.fingerprint());

    // The forced-read warm-up gate makes the prefix literally invariant:
    // snapshotting either full config's warm-up under one fixed meta
    // yields the same bytes.
    let prefix = a.prefix_cfg();
    let pmeta = meta_for(&prefix, true);
    let snap_of = |cfg: &SystemCfg| {
        let mut sys = build_system(cfg);
        sys.engine.run_until_collecting();
        sys.engine.snapshot(&pmeta)
    };
    let snap_a = snap_of(&a);
    let snap_b = snap_of(&b);
    assert_eq!(snap_a, snap_b, "warm-up prefix depends on read_ratio");

    // The sweep warm-start donor (built from the projection itself) is
    // fork-compatible with both members and reproduces their cold runs.
    let donor = quiescent_snapshot(&prefix);
    for cfg in [&a, &b] {
        assert!(
            check_snapshot(&donor, Some(cfg)).is_empty(),
            "donor rejected for a prefix-compatible config"
        );
        assert_eq!(restore_digest(cfg, &donor, 1), run_digest(cfg, false));
    }
    assert_eq!(restore_digest(&b, &donor, 2), run_digest(&b, false));
}

#[test]
fn check_snapshot_locates_every_rejection_class() {
    let cfgs = checkpoint_cfgs();
    let (_, cfg) = &cfgs[2]; // coherent: densest body
    let snap = quiescent_snapshot(cfg);
    assert!(check_snapshot(&snap, Some(cfg)).is_empty());
    let locus_of = |bytes: &[u8], cfg: Option<&SystemCfg>| {
        let errs = check_snapshot(bytes, cfg);
        assert_eq!(errs.len(), 1, "expected exactly one ESF-C014 error");
        assert_eq!(errs[0].rule, "ESF-C014");
        errs[0].path.clone()
    };

    let mut bad = snap.clone();
    bad[0] ^= 0xff;
    assert_eq!(locus_of(&bad, None), "snapshot.magic");

    let mut bad = snap.clone();
    bad[8] = bad[8].wrapping_add(1); // version word, little-endian low byte
    assert_eq!(locus_of(&bad, None), "snapshot.version");

    let mut bad = snap.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x10;
    assert_eq!(locus_of(&bad, None), "snapshot.digest");
    assert_eq!(locus_of(&snap[..snap.len() - 3], None), "snapshot.digest");

    // Unrelated config: neither exact resume nor prefix fork is sound.
    let mut other = cfg.clone();
    other.seed = 999;
    assert_eq!(locus_of(&snap, Some(&other)), "snapshot.config");

    // Mid-run checkpoints carry post-warm-up state: resumable by the
    // exact config, never forkable by a prefix sibling.
    let mut sys = build_system(cfg);
    sys.engine.run_until(ns(50_000.0));
    let midrun = sys.engine.snapshot(&meta_for(cfg, false));
    assert!(check_snapshot(&midrun, Some(cfg)).is_empty());
    let mut sibling = cfg.clone();
    sibling.read_ratio = 0.123;
    assert_eq!(sibling.prefix_fingerprint(), cfg.prefix_fingerprint());
    assert_eq!(locus_of(&midrun, Some(&sibling)), "snapshot.prefix");

    // Engine::restore refuses what check refuses — and also a
    // structurally different system (wrong fabric for the body).
    assert!(build_system(cfg).engine.restore(&bad).is_err());
    let mismatched = SystemCfg::new(TopologyKind::Chain, 8);
    assert!(build_system(&mismatched).engine.restore(&snap).is_err());
}

#[test]
fn warm_sweep_output_is_byte_identical_to_cold() {
    use esf::sweep::{
        results_json, run_scenarios_cached_opts, run_scenarios_opts, GridSpec, SweepCache,
    };
    let grid = || {
        GridSpec::from_json_str(
            r#"{
                "base": {"scale": 8,
                         "requester": {"requests_per_endpoint": 120}},
                "sweep": {"read_ratio": [1.0, 0.6, 0.3]}
            }"#,
        )
        .unwrap()
    };
    let dir = std::env::temp_dir().join(format!("esf-ckpt-warm-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cache = SweepCache::open(&dir).unwrap();
    let dump = |rs: &[esf::sweep::ScenarioResult]| results_json(rs).to_string();
    let cold = dump(&run_scenarios_opts(grid().scenarios, 2, 1));
    // Cold cache: all three cells fork from one shared prefix snapshot,
    // exercised at intra width 2 as well.
    let warm = dump(&run_scenarios_cached_opts(grid().scenarios, 2, 2, &cache));
    assert_eq!(cold, warm, "warm-start forking changed sweep output");
    let snaps = std::fs::read_dir(&dir)
        .unwrap()
        .filter(|e| {
            e.as_ref()
                .unwrap()
                .path()
                .extension()
                .is_some_and(|x| x == "snap")
        })
        .count();
    assert_eq!(snaps, 1, "one prefix group must persist exactly one snapshot");
    // Resume: cells hit, snapshot stays valid, output still identical.
    let resumed = dump(&run_scenarios_cached_opts(grid().scenarios, 1, 1, &cache));
    assert_eq!(cold, resumed);
    let _ = std::fs::remove_dir_all(&dir);
}
