//! The real PJRT executor (requires the `xla` bindings crate; `pjrt`
//! cargo feature). Loads the artifact manifest, lazily compiles HLO-text
//! artifacts, and executes the AOT Pallas kernels on the CPU client.

use super::{artifacts_dir, UNREACH};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT-backed executor for the AOT kernels.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    /// APSP sizes available (sorted) -> artifact path.
    apsp_sizes: Vec<(usize, String)>,
    /// tracestats shapes available: (windows, window_len) -> path.
    tracestats_shapes: Vec<((usize, usize), String)>,
    compiled: HashMap<String, Executable>,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client. Compilation of
    /// individual artifacts is lazy (first use).
    pub fn load(dir: &Path) -> Result<Runtime> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest =
            Json::parse(&text).map_err(|e| anyhow!("parsing manifest.json: {e}"))?;
        let mut apsp_sizes = Vec::new();
        if let Some(apsp) = manifest.get("apsp").and_then(Json::as_obj) {
            for entry in apsp.values() {
                let n = entry.u64_or("n", 0) as usize;
                let path = entry.str_or("path", "").to_string();
                if n > 0 && !path.is_empty() {
                    apsp_sizes.push((n, path));
                }
            }
        }
        apsp_sizes.sort_unstable();
        let mut tracestats_shapes = Vec::new();
        if let Some(ts) = manifest.get("tracestats").and_then(Json::as_obj) {
            for entry in ts.values() {
                let w = entry.u64_or("windows", 0) as usize;
                let l = entry.u64_or("window_len", 0) as usize;
                let path = entry.str_or("path", "").to_string();
                if w > 0 && l > 0 && !path.is_empty() {
                    tracestats_shapes.push(((w, l), path));
                }
            }
        }
        tracestats_shapes.sort_unstable();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            apsp_sizes,
            tracestats_shapes,
            compiled: HashMap::new(),
        })
    }

    /// Try the default artifact locations.
    pub fn load_default() -> Result<Runtime> {
        let dir = artifacts_dir().ok_or_else(|| {
            anyhow!("no artifacts directory found (run `make artifacts` or set ESF_ARTIFACTS)")
        })?;
        Self::load(&dir)
    }

    pub fn apsp_sizes(&self) -> Vec<usize> {
        self.apsp_sizes.iter().map(|(n, _)| *n).collect()
    }

    /// Largest pre-lowered APSP size.
    pub fn max_apsp(&self) -> usize {
        self.apsp_sizes.last().map(|(n, _)| *n).unwrap_or(0)
    }

    fn compile(&mut self, path: &str) -> Result<&Executable> {
        if !self.compiled.contains_key(path) {
            let full = self.dir.join(path);
            let proto = xla::HloModuleProto::from_text_file(
                full.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("loading HLO text {}: {e:?}", full.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", full.display()))?;
            self.compiled.insert(path.to_string(), Executable { exe });
        }
        Ok(&self.compiled[path])
    }

    /// All-pairs shortest path for an `n x n` hop-count adjacency matrix
    /// (row-major, 0 diagonal, 1.0 per link, >= UNREACH/2 for no edge).
    /// The matrix is padded up to the nearest pre-lowered kernel size;
    /// fails if the fabric is larger than the largest artifact.
    pub fn apsp(&mut self, adj: &[f32], n: usize) -> Result<Vec<f32>> {
        assert_eq!(adj.len(), n * n);
        let (size, path) = self
            .apsp_sizes
            .iter()
            .find(|(s, _)| *s >= n)
            .cloned()
            .ok_or_else(|| anyhow!("no APSP artifact for fabric of {n} nodes"))?;
        // Pad: extra nodes are isolated (0 self-distance, UNREACH edges),
        // so they cannot create shortcuts.
        let mut padded = vec![UNREACH; size * size];
        for i in 0..size {
            padded[i * size + i] = 0.0;
        }
        for i in 0..n {
            padded[i * size..i * size + n].copy_from_slice(&adj[i * n..(i + 1) * n]);
            padded[i * size + i] = 0.0;
        }
        let exe = self.compile(&path)?;
        let input = xla::Literal::vec1(&padded)
            .reshape(&[size as i64, size as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = exe
            .exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| anyhow!("execute apsp_{size}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let tup = lit.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
        let full: Vec<f32> = tup.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        // Un-pad.
        let mut out = vec![0f32; n * n];
        for i in 0..n {
            out[i * n..(i + 1) * n].copy_from_slice(&full[i * size..i * size + n]);
        }
        Ok(out)
    }

    /// Windowed trace statistics: per window [reads, writes, total_bytes].
    /// `is_write` and `nbytes` are (windows x window_len) row-major.
    pub fn tracestats(
        &mut self,
        is_write: &[f32],
        nbytes: &[f32],
        windows: usize,
        window_len: usize,
    ) -> Result<Vec<[f32; 3]>> {
        assert_eq!(is_write.len(), windows * window_len);
        assert_eq!(nbytes.len(), windows * window_len);
        let ((w, l), path) = self
            .tracestats_shapes
            .iter()
            .find(|((w, l), _)| *w >= windows && *l == window_len)
            .cloned()
            .ok_or_else(|| {
                anyhow!("no tracestats artifact for {windows}x{window_len} windows")
            })?;
        let mut a = vec![0f32; w * l];
        let mut b = vec![0f32; w * l];
        for i in 0..windows {
            a[i * l..i * l + window_len]
                .copy_from_slice(&is_write[i * window_len..(i + 1) * window_len]);
            b[i * l..i * l + window_len]
                .copy_from_slice(&nbytes[i * window_len..(i + 1) * window_len]);
        }
        let exe = self.compile(&path)?;
        let mk = |v: &[f32]| -> Result<xla::Literal> {
            xla::Literal::vec1(v)
                .reshape(&[w as i64, l as i64])
                .map_err(|e| anyhow!("reshape: {e:?}"))
        };
        let (xa, xb) = (mk(&a)?, mk(&b)?);
        let result = exe
            .exe
            .execute::<xla::Literal>(&[xa, xb])
            .map_err(|e| anyhow!("execute tracestats: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let tup = lit.to_tuple1().map_err(|e| anyhow!("to_tuple1: {e:?}"))?;
        let flat: Vec<f32> = tup.to_vec().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if flat.len() < windows * 3 {
            bail!("tracestats output too small: {}", flat.len());
        }
        Ok((0..windows)
            .map(|i| [flat[i * 3], flat[i * 3 + 1], flat[i * 3 + 2]])
            .collect())
    }
}
