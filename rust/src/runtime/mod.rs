//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts (HLO text) and
//! execute them from Rust. Python never runs on the simulation path — this
//! module is the only bridge, and it degrades gracefully to the native
//! Rust implementations when `artifacts/` is absent.
//!
//! The executor itself needs the `xla` bindings crate, which the offline
//! vendored crate set does not ship; it is therefore compiled only under
//! the `pjrt` cargo feature (see `pjrt.rs`). Without the feature,
//! [`Runtime`] is an uninhabited stub whose `load`/`load_default` report
//! PJRT as unavailable, so every caller takes its native fallback path —
//! the same behavior as a missing `artifacts/` directory.
//!
//! Interchange contract (see `python/compile/aot.py`): HLO **text**, not
//! serialized `HloModuleProto` — jax >= 0.5 emits 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

/// The "no edge" value in the APSP interchange (kernels/minplus.py).
pub const UNREACH: f32 = 1.0e9;

/// Locate the artifacts directory: `$ESF_ARTIFACTS`, `./artifacts`, or the
/// crate-relative default.
pub fn artifacts_dir() -> Option<PathBuf> {
    if let Ok(d) = std::env::var("ESF_ARTIFACTS") {
        let p = PathBuf::from(d);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}

/// Native Rust APSP (same min-plus repeated-squaring contraction as the
/// Pallas kernel) — the fallback when artifacts are missing, and the
/// cross-check oracle for the PJRT path.
pub fn apsp_native(adj: &[f32], n: usize) -> Vec<f32> {
    let mut d = adj.to_vec();
    let mut steps = 1usize;
    while (1usize << steps) < n.max(2) - 1 {
        steps += 1;
    }
    for _ in 0..steps.max(1) {
        let mut next = d.clone();
        for i in 0..n {
            for k in 0..n {
                let dik = d[i * n + k];
                if dik >= UNREACH / 2.0 {
                    continue;
                }
                let (row_k, row_o) = (&d[k * n..(k + 1) * n], &mut next[i * n..(i + 1) * n]);
                for j in 0..n {
                    let v = dik + row_k[j];
                    if v < row_o[j] {
                        row_o[j] = v;
                    }
                }
            }
        }
        d = next;
    }
    for v in d.iter_mut() {
        if *v >= UNREACH / 2.0 {
            *v = UNREACH;
        }
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring_adj(n: usize) -> Vec<f32> {
        let mut m = vec![UNREACH; n * n];
        for i in 0..n {
            m[i * n + i] = 0.0;
            let j = (i + 1) % n;
            m[i * n + j] = 1.0;
            m[j * n + i] = 1.0;
        }
        m
    }

    #[test]
    fn native_apsp_on_ring() {
        let n = 8;
        let d = apsp_native(&ring_adj(n), n);
        for i in 0..n {
            for j in 0..n {
                let fwd = (j + n - i) % n;
                let want = fwd.min(n - fwd) as f32;
                assert_eq!(d[i * n + j], want, "d[{i}][{j}]");
            }
        }
    }

    #[test]
    fn native_apsp_disconnected() {
        let adj = vec![0.0, UNREACH, UNREACH, 0.0];
        let d = apsp_native(&adj, 2);
        assert_eq!(d, vec![0.0, UNREACH, UNREACH, 0.0]);
    }

    #[test]
    fn stub_or_real_runtime_reports_cleanly() {
        // Whether or not the pjrt feature (and artifacts/) is present,
        // load_default() must either work or return a printable error —
        // callers branch on it to pick the native fallback.
        match Runtime::load_default() {
            Ok(rt) => assert!(rt.max_apsp() > 0 || rt.apsp_sizes().is_empty()),
            Err(e) => assert!(!e.to_string().is_empty()),
        }
    }

    // PJRT integration tests live in rust/tests/ (they require artifacts/).
}
