//! Uninhabited stand-in for the PJRT executor, compiled when the `pjrt`
//! feature is off. `load`/`load_default` always fail with a clear message,
//! which routes every caller onto its native Rust fallback; the value
//! methods are statically unreachable (no `Runtime` can exist).

use anyhow::{anyhow, Result};
use std::path::Path;

pub enum Runtime {}

impl Runtime {
    pub fn load(_dir: &Path) -> Result<Runtime> {
        Err(anyhow!(
            "PJRT support not compiled in (enable the `pjrt` cargo feature)"
        ))
    }

    pub fn load_default() -> Result<Runtime> {
        Err(anyhow!(
            "PJRT support not compiled in (enable the `pjrt` cargo feature)"
        ))
    }

    pub fn apsp_sizes(&self) -> Vec<usize> {
        match *self {}
    }

    pub fn max_apsp(&self) -> usize {
        match *self {}
    }

    pub fn apsp(&mut self, _adj: &[f32], _n: usize) -> Result<Vec<f32>> {
        match *self {}
    }

    pub fn tracestats(
        &mut self,
        _is_write: &[f32],
        _nbytes: &[f32],
        _windows: usize,
        _window_len: usize,
    ) -> Result<Vec<[f32; 3]>> {
        match *self {}
    }
}
