//! Workload generation: synthetic equivalents of the paper's traces.
//!
//! The paper replays five real-world memory traces (BTree, liblinear,
//! redis, silo, XSBench — one million accesses each, collected with the
//! tool of Yang et al. [61]) and two SPEC CPU2017 workloads (gcc, mcf)
//! traced with Intel PIN. Neither the traces nor PIN are available here,
//! so each generator below synthesizes an address/op stream matching the
//! workload's published characteristics — footprint, locality structure,
//! and read/write mix (the two properties Figs 18-20 and Table IV are
//! sensitive to). See DESIGN.md §Substitutions.

pub mod spec;
pub mod trace;

pub use trace::{mix_degree, Trace};

use crate::proto::TraceOp;
use crate::util::rng::Pcg32;

/// A named real-world workload profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RealWorkload {
    /// In-memory B-tree index (Mitosis): pointer-chasing reads over a
    /// large pool, few writes.
    BTree,
    /// liblinear training: streaming sweeps over the feature matrix with
    /// periodic model-vector writes.
    Liblinear,
    /// redis under YCSB: zipf-skewed key access, balanced read/update mix.
    Redis,
    /// silo OLTP: write-heavy transactions over warehouse records.
    Silo,
    /// XSBench: random cross-section table lookups, read-dominated.
    XsBench,
}

impl RealWorkload {
    pub const ALL: [RealWorkload; 5] = [
        RealWorkload::BTree,
        RealWorkload::Liblinear,
        RealWorkload::Redis,
        RealWorkload::Silo,
        RealWorkload::XsBench,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RealWorkload::BTree => "btree",
            RealWorkload::Liblinear => "liblinear",
            RealWorkload::Redis => "redis",
            RealWorkload::Silo => "silo",
            RealWorkload::XsBench => "xsbench",
        }
    }

    /// Write fraction of the generated stream (mix degree = min(r, w)).
    pub fn write_ratio(&self) -> f64 {
        match self {
            RealWorkload::BTree => 0.05,
            RealWorkload::Liblinear => 0.15,
            RealWorkload::Redis => 0.32,
            RealWorkload::Silo => 0.46,
            RealWorkload::XsBench => 0.10,
        }
    }

    /// Generate `n` accesses.
    pub fn generate(&self, n: usize, seed: u64) -> Trace {
        let mut rng = Pcg32::new(seed, *self as u64);
        let ops = match self {
            RealWorkload::BTree => btree(n, &mut rng),
            RealWorkload::Liblinear => liblinear(n, &mut rng),
            RealWorkload::Redis => redis(n, &mut rng),
            RealWorkload::Silo => silo(n, &mut rng),
            RealWorkload::XsBench => xsbench(n, &mut rng),
        };
        Trace {
            name: self.name().to_string(),
            ops,
        }
    }
}

fn op(addr: u64, is_write: bool) -> TraceOp {
    TraceOp {
        addr: addr & !63,
        is_write,
        gap_ps: 0,
    }
}

/// Pointer-chasing over a tree arena: each lookup touches a root-to-leaf
/// path of ~depth nodes at pseudo-random arena offsets; some inserts write
/// the leaf.
fn btree(n: usize, rng: &mut Pcg32) -> Vec<TraceOp> {
    let arena_lines: u64 = 1 << 20; // 64 MiB arena
    let depth = 6;
    let mut ops = Vec::with_capacity(n);
    while ops.len() < n {
        // Derive the path deterministically from the key so repeated keys
        // re-walk the same upper levels (natural hot top-of-tree).
        let key = rng.next_u64();
        let mut node = key % 64; // small hot root region
        for lvl in 0..depth {
            if ops.len() >= n {
                break;
            }
            ops.push(op(node * 64, false));
            let fan = key.rotate_right(lvl * 8) & 0xff;
            node = (node * 131 + fan + 1) % arena_lines;
        }
        if rng.chance(0.30) && ops.len() < n {
            // insert: write the leaf
            ops.push(op(node * 64, true));
        }
    }
    ops
}

/// Streaming sweep over a feature matrix with a hot model vector that is
/// read-modify-written each step.
fn liblinear(n: usize, rng: &mut Pcg32) -> Vec<TraceOp> {
    let matrix_lines: u64 = 1 << 19; // 32 MiB
    let model_lines: u64 = 1 << 10; // 64 KiB hot vector
    let mut ops = Vec::with_capacity(n);
    let mut pos = 0u64;
    while ops.len() < n {
        // ~5 streaming reads ...
        for _ in 0..5 {
            if ops.len() >= n {
                break;
            }
            ops.push(op((pos % matrix_lines) * 64, false));
            pos += 1;
        }
        // ... then a model read-modify-write (write_ratio ~0.15 emerges).
        if ops.len() < n {
            let m = rng.gen_range(model_lines);
            ops.push(op((matrix_lines + m) * 64, rng.chance(0.9)));
        }
    }
    ops
}

/// Zipf-skewed keyspace (YCSB-style), ~32% updates on average. The write
/// share breathes over time (read-heavy serving alternating with
/// write-heavy persistence/flush phases), so per-window mix degree varies
/// — the structure Fig 20b correlates against bandwidth.
fn redis(n: usize, rng: &mut Pcg32) -> Vec<TraceOp> {
    let keys: u64 = 1 << 16;
    let zipf = ZipfTable::new(keys, 0.99);
    (0..n)
        .map(|i| {
            let k = zipf.sample(rng);
            // value spans 4 lines; touch one
            let line = k * 4 + rng.gen_range(4);
            let phase = (i as f64) / 4000.0 * std::f64::consts::TAU;
            let w = 0.32 + 0.22 * phase.sin();
            op(line * 64, rng.chance(w))
        })
        .collect()
}

/// OLTP transactions: short bursts touching a warehouse row then writing
/// order records (write-heavy, moderate locality).
fn silo(n: usize, rng: &mut Pcg32) -> Vec<TraceOp> {
    let rows: u64 = 1 << 18;
    let mut ops = Vec::with_capacity(n);
    while ops.len() < n {
        let row = rng.gen_range(rows);
        // read the row (2 lines), write back 2 lines
        for i in 0..2 {
            if ops.len() < n {
                ops.push(op((row * 4 + i) * 64, false));
            }
        }
        for i in 0..2 {
            if ops.len() < n {
                ops.push(op((row * 4 + i) * 64, true));
            }
        }
    }
    ops
}

/// Monte-Carlo cross-section lookups: uniform random reads over large
/// nuclide grids with occasional tally writes.
fn xsbench(n: usize, rng: &mut Pcg32) -> Vec<TraceOp> {
    let grid_lines: u64 = 1 << 21; // 128 MiB
    (0..n)
        .map(|_| {
            let line = rng.gen_range(grid_lines);
            op(line * 64, rng.chance(0.10))
        })
        .collect()
}

/// Cumulative-table Zipf sampler (small keyspaces).
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: u64, theta: f64) -> ZipfTable {
        let n = n.min(1 << 20) as usize;
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 1..=n {
            acc += 1.0 / (i as f64).powf(theta);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg32) -> u64 {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) | Err(i) => i.min(self.cdf.len() - 1) as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_ratios_match_profiles() {
        for w in RealWorkload::ALL {
            let t = w.generate(50_000, 7);
            let writes = t.ops.iter().filter(|o| o.is_write).count() as f64;
            let ratio = writes / t.ops.len() as f64;
            let want = w.write_ratio();
            assert!(
                (ratio - want).abs() < 0.05,
                "{}: write ratio {ratio:.3} vs profile {want}",
                w.name()
            );
        }
    }

    #[test]
    fn traces_have_requested_length_and_alignment() {
        for w in RealWorkload::ALL {
            let t = w.generate(10_000, 1);
            assert_eq!(t.ops.len(), 10_000);
            assert!(t.ops.iter().all(|o| o.addr % 64 == 0));
        }
    }

    #[test]
    fn mix_degrees_are_distinct_across_workloads() {
        // Fig 20a needs a spread of mix degrees.
        let mut degrees: Vec<f64> = RealWorkload::ALL
            .iter()
            .map(|w| {
                let t = w.generate(20_000, 3);
                mix_degree(&t.ops)
            })
            .collect();
        degrees.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(degrees.windows(2).all(|w| w[1] - w[0] > 0.02));
        assert!(degrees[0] < 0.1 && degrees[4] > 0.4);
    }

    #[test]
    fn zipf_is_skewed() {
        let z = ZipfTable::new(1000, 0.99);
        let mut rng = Pcg32::new(5, 0);
        let mut head = 0;
        let n = 20_000;
        for _ in 0..n {
            if z.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        let frac = head as f64 / n as f64;
        assert!(frac > 0.25, "top-10 keys got {frac}");
    }

    #[test]
    fn redis_is_hotter_than_xsbench() {
        let r = RealWorkload::Redis.generate(30_000, 2);
        let x = RealWorkload::XsBench.generate(30_000, 2);
        let distinct = |t: &Trace| {
            let mut s: Vec<u64> = t.ops.iter().map(|o| o.addr).collect();
            s.sort_unstable();
            s.dedup();
            s.len()
        };
        assert!(distinct(&r) < distinct(&x) / 2);
    }

    #[test]
    fn generation_is_deterministic() {
        let a = RealWorkload::Silo.generate(1000, 9);
        let b = RealWorkload::Silo.generate(1000, 9);
        assert_eq!(a.ops, b.ops);
    }
}
