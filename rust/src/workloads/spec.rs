//! SPEC CPU2017-like instruction/memory traces — the substitute for the
//! paper's PIN-collected gcc and mcf traces (Table IV/V).
//!
//! Table IV's metric is the *relative execution-time overhead* incurred by
//! placing the workload's memory on CXL instead of local DRAM, which is a
//! function of the post-cache miss traffic (MPKI and its burstiness), not
//! of the exact instruction stream. The generators below reproduce each
//! workload's published memory character:
//!
//!  * `gcc`  — compiler: strong locality (AST/IR walks re-touch a small
//!    working set), moderate memory intensity, low LLC MPKI.
//!  * `mcf`  — network simplex: pointer chasing over a huge arena, very
//!    poor locality, high LLC MPKI (the classic memory-bound SPEC case).

use crate::cpu::CpuOp;
use crate::util::rng::Pcg32;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpecWorkload {
    Gcc,
    Mcf,
}

impl SpecWorkload {
    pub const ALL: [SpecWorkload; 2] = [SpecWorkload::Gcc, SpecWorkload::Mcf];

    pub fn name(&self) -> &'static str {
        match self {
            SpecWorkload::Gcc => "gcc",
            SpecWorkload::Mcf => "mcf",
        }
    }

    /// Generate `n` memory references with instruction-count gaps.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<CpuOp> {
        let mut rng = Pcg32::new(seed, 0x5bec ^ *self as u64);
        match self {
            SpecWorkload::Gcc => gcc(n, &mut rng),
            SpecWorkload::Mcf => mcf(n, &mut rng),
        }
    }
}

/// gcc: hot stack + medium heap with phase-local reuse.
fn gcc(n: usize, rng: &mut Pcg32) -> Vec<CpuOp> {
    let stack_lines: u64 = 1 << 7; // 8 KiB, extremely hot
    let heap_lines: u64 = 1 << 18; // 16 MiB total heap
    let phase_lines: u64 = 1 << 12; // 256 KiB phase-local working set
    let mut ops = Vec::with_capacity(n);
    let mut phase_base = 0u64;
    for i in 0..n {
        if i % 50_000 == 0 {
            // new compilation phase: the working set *slides* (heavy
            // overlap with the previous phase, like successive passes
            // over the same IR) rather than teleporting.
            phase_base = (phase_base + 256) % (heap_lines - phase_lines);
        }
        let icount = 3 + rng.gen_range(5) as u32; // mem ref every ~5 insts
        let r = rng.f64();
        let (line, is_write) = if r < 0.45 {
            // stack traffic, half writes
            (rng.gen_range(stack_lines), rng.chance(0.5))
        } else if r < 0.997 {
            // phase-local heap (fits in L2/L3 -> low LLC MPKI, gcc-like)
            (
                (1 << 8) + phase_base + rng.gen_range(phase_lines),
                rng.chance(0.25),
            )
        } else {
            // rare cold heap wander (~0.3% of refs)
            ((1 << 8) + rng.gen_range(heap_lines), rng.chance(0.1))
        };
        ops.push(CpuOp {
            icount,
            addr: line * 64,
            is_write,
        });
    }
    ops
}

/// mcf: pointer chasing over the arc/node arena. The simplex hot set
/// (~512 KiB of active arcs) chases inside L1/L2; ~2.8% of the walks wander
/// the full 256 MiB arena with no locality (the classic mcf LLC misses,
/// each also a DRAM row conflict).
fn mcf(n: usize, rng: &mut Pcg32) -> Vec<CpuOp> {
    let arena_lines: u64 = 1 << 22; // 256 MiB arena
    let hot_lines: u64 = 1 << 13; // 512 KiB active arc set
    let mut ops = Vec::with_capacity(n);
    let mut node = 1u64;
    for _ in 0..n {
        let icount = 2 + rng.gen_range(3) as u32; // memory-bound
        // Pseudo pointer-chase: next node depends on current (defeats
        // prefetch/stride locality like real mcf).
        node = node
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let line = if rng.chance(0.972) {
            node % hot_lines
        } else {
            hot_lines + node % (arena_lines - hot_lines)
        };
        let is_write = rng.chance(0.12);
        ops.push(CpuOp {
            icount,
            addr: line * 64,
            is_write,
        });
    }
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(
            SpecWorkload::Mcf.generate(1000, 1),
            SpecWorkload::Mcf.generate(1000, 1)
        );
    }

    #[test]
    fn gcc_has_much_better_locality_than_mcf() {
        let distinct = |ops: &[CpuOp]| {
            let mut a: Vec<u64> = ops.iter().map(|o| o.addr).collect();
            a.sort_unstable();
            a.dedup();
            a.len()
        };
        let g = SpecWorkload::Gcc.generate(100_000, 3);
        let m = SpecWorkload::Mcf.generate(100_000, 3);
        assert!(
            distinct(&g) * 2 < distinct(&m),
            "gcc {} vs mcf {}",
            distinct(&g),
            distinct(&m)
        );
    }

    #[test]
    fn icount_gaps_positive() {
        for w in SpecWorkload::ALL {
            let ops = w.generate(1000, 5);
            assert!(ops.iter().all(|o| o.icount > 0));
            assert_eq!(ops.len(), 1000);
        }
    }
}
