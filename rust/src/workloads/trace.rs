//! Trace container + file I/O + mix-degree analytics.
//!
//! File format (CSV, one op per line): `addr_hex,rw,gap_ps` — e.g.
//! `0x7f001040,R,0`. Chosen over a binary format so traces from other
//! tools (e.g. converted PIN output) can be dropped in with `awk`.

use crate::proto::TraceOp;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub name: String,
    pub ops: Vec<TraceOp>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    pub fn write_ratio(&self) -> f64 {
        if self.ops.is_empty() {
            return 0.0;
        }
        self.ops.iter().filter(|o| o.is_write).count() as f64 / self.ops.len() as f64
    }

    pub fn mix_degree(&self) -> f64 {
        mix_degree(&self.ops)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        for op in &self.ops {
            writeln!(
                w,
                "{:#x},{},{}",
                op.addr,
                if op.is_write { 'W' } else { 'R' },
                op.gap_ps
            )?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Trace> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut ops = Vec::new();
        for (lineno, line) in std::io::BufReader::new(f).lines().enumerate() {
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split(',');
            let (a, rw, gap) = (parts.next(), parts.next(), parts.next());
            let (Some(a), Some(rw)) = (a, rw) else {
                bail!("{}:{}: malformed line", path.display(), lineno + 1);
            };
            let addr = if let Some(hex) = a.trim().strip_prefix("0x") {
                u64::from_str_radix(hex, 16)
            } else {
                a.trim().parse()
            }
            .with_context(|| format!("{}:{}: bad address", path.display(), lineno + 1))?;
            let is_write = match rw.trim() {
                "W" | "w" | "1" => true,
                "R" | "r" | "0" => false,
                other => bail!("{}:{}: bad op '{other}'", path.display(), lineno + 1),
            };
            let gap_ps = gap.map(|g| g.trim().parse().unwrap_or(0)).unwrap_or(0);
            ops.push(TraceOp {
                addr,
                is_write,
                gap_ps,
            });
        }
        let name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "trace".to_string());
        Ok(Trace { name, ops })
    }

    /// Split into fixed-length windows, returning per-window
    /// (reads, writes, bytes) — the native equivalent of the AOT
    /// tracestats kernel, used as its cross-check oracle.
    pub fn windowed_stats(&self, window_len: usize) -> Vec<(u64, u64, u64)> {
        self.ops
            .chunks(window_len)
            .filter(|c| c.len() == window_len)
            .map(|c| {
                let w = c.iter().filter(|o| o.is_write).count() as u64;
                let r = c.len() as u64 - w;
                (r, w, c.len() as u64 * 64)
            })
            .collect()
    }
}

/// Mix degree = min(read_ratio, write_ratio) (paper §V-E).
pub fn mix_degree(ops: &[TraceOp]) -> f64 {
    if ops.is_empty() {
        return 0.0;
    }
    let w = ops.iter().filter(|o| o.is_write).count() as f64 / ops.len() as f64;
    w.min(1.0 - w)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ops: &[(u64, bool)]) -> Trace {
        Trace {
            name: "t".into(),
            ops: ops
                .iter()
                .map(|&(addr, is_write)| TraceOp {
                    addr,
                    is_write,
                    gap_ps: 0,
                })
                .collect(),
        }
    }

    #[test]
    fn mix_degree_symmetric() {
        let a = t(&[(0, true), (0, false), (0, false), (0, false)]);
        let b = t(&[(0, false), (0, true), (0, true), (0, true)]);
        assert_eq!(a.mix_degree(), 0.25);
        assert_eq!(b.mix_degree(), 0.25);
        let even = t(&[(0, true), (0, false)]);
        assert_eq!(even.mix_degree(), 0.5);
    }

    #[test]
    fn file_roundtrip() {
        let tr = t(&[(0x1000, false), (0x2040, true), (0x3080, false)]);
        let dir = std::env::temp_dir().join("esf_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("roundtrip.csv");
        tr.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.ops, tr.ops);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("esf_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "0x10,X,0\n").unwrap();
        assert!(Trace::load(&path).is_err());
        std::fs::write(&path, "zz,R,0\n").unwrap();
        assert!(Trace::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("esf_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("comments.csv");
        std::fs::write(&path, "# header\n\n0x40,R,10\n64,W\n").unwrap();
        let tr = Trace::load(&path).unwrap();
        assert_eq!(tr.ops.len(), 2);
        assert_eq!(tr.ops[0].gap_ps, 10);
        assert_eq!(tr.ops[1].addr, 64);
        assert!(tr.ops[1].is_write);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn windowed_stats_counts() {
        let mut ops = Vec::new();
        for i in 0..250u64 {
            ops.push((i * 64, i % 4 == 0));
        }
        let tr = t(&ops);
        let w = tr.windowed_stats(100);
        assert_eq!(w.len(), 2); // trailing partial window dropped
        assert_eq!(w[0].0 + w[0].1, 100);
        assert_eq!(w[0].1, 25);
        assert_eq!(w[0].2, 6400);
    }
}
