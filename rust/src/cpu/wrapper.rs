//! Memory-system integration wrappers (paper §III-E, Fig 5).
//!
//! `CxlMemWrapper` mirrors the paper's gem5 integration: a wrapper object
//! with an `UpInterface` (where the core-side memory packet enters) and a
//! `DownInterface` (the underlying memory), connected through a *nested,
//! persistent ESF simulation* that models the CXL interconnect between
//! them. Every LLC miss becomes a packet injected into the nested engine;
//! the engine runs until the response drains back. Link/bank state
//! persists across misses, so back-to-back misses observe queueing.
//!
//! `GarnetLikeWrapper` is the comparison integration (gem5-garnet in
//! Tables IV/V): an on-chip-network-style flit-level model with no PCIe
//! serialization or duplex semantics — finer-grained events (slower to
//! simulate, Table V) and structurally unable to see full-duplex effects
//! (less accurate, Table IV).
//!
//! `NumaEmulator` is the NUMA-emulation baseline: a flat remote-socket
//! latency plus a bandwidth cap, the method most prior CXL studies used.

use crate::config::BackendKind;
use crate::devices::{MemDev, MemDevCfg};
use crate::engine::time::{ns, Ps};
use crate::engine::{Component, Engine, Payload, Shared};
use crate::interconnect::{LinkCfg, NodeKind, Routing, Strategy, Topology};
use crate::proto::{NodeId, Opcode, Packet};
use std::any::Any;
use std::collections::BinaryHeap;

/// Core-side terminus of the nested simulation (the paper's UpInterface):
/// receives responses and records the round-trip latency.
struct UpInterface {
    /// (txn id, latency) of responses since last drain.
    done: Vec<(u64, Ps)>,
}

impl Component for UpInterface {
    fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
        if let Payload::Packet(pkt) = payload {
            if matches!(pkt.op, Opcode::MemRdData | Opcode::MemWrCmp) {
                self.done
                    .push((pkt.id, ctx.now.saturating_sub(pkt.issued_at)));
            }
        }
    }
    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

/// gem5-ESF style wrapper: nested persistent ESF engine between the cache
/// hierarchy and the memory device (the DownInterface is the `MemDev`).
pub struct CxlMemWrapper {
    engine: Engine,
    up: NodeId,
    down: NodeId,
    egress_delay: Ps,
    pub misses_served: u64,
}

impl CxlMemWrapper {
    /// `backend` is the media under the DownInterface; `link` the CXL/PCIe
    /// link between socket and device.
    pub fn new(backend: &BackendKind, link: LinkCfg, seed: u64) -> CxlMemWrapper {
        // Up -- shared CXL/PCIe bus -- root port -- DownInterface: the
        // same path composition as the validation platform, so the
        // wrapper's latency matches the system the paper calibrates.
        let mut topo = Topology::new();
        let up = topo.add_node("UpInterface", NodeKind::Requester);
        let hub = topo.add_node("rootport", NodeKind::Switch);
        let down = topo.add_node("DownInterface", NodeKind::Memory);
        topo.add_link(up, hub, link);
        let stub = LinkCfg {
            bandwidth_gbps: 0.0,
            latency: 0,
            duplex: crate::interconnect::Duplex::Full,
            turnaround: 0,
            header_bytes: 0,
        };
        topo.add_link(hub, down, stub);
        let routing = Routing::build_bfs(&topo);
        let shared = Shared::new(topo, routing, Strategy::Oblivious);
        let mut engine = Engine::new(shared);
        engine.register(Box::new(UpInterface { done: Vec::new() }));
        engine.register(Box::new(crate::devices::Switch::new(
            crate::devices::SwitchCfg::new(hub),
        )));
        let mut mc = MemDevCfg::new(down);
        mc.ctrl_time = ns(40.0);
        mc.port_delay = ns(25.0);
        engine.register(Box::new(MemDev::new(mc, backend.instantiate(seed))));
        CxlMemWrapper {
            engine,
            up,
            down,
            // requester process + egress port; ingress port folded into
            // the returned latency (see access()).
            egress_delay: ns(10.0) + ns(25.0),
            misses_served: 0,
        }
    }

    /// Service one LLC miss at simulated CPU time `at`; returns latency.
    pub fn access(&mut self, addr: u64, is_write: bool, at: Ps) -> Ps {
        self.misses_served += 1;
        let now = self.engine.shared.now.max(at);
        self.engine.shared.now = now;
        // Injected from outside any handler: mint keys/ids from the
        // external-origin slot explicitly.
        let ext = self.engine.shared.topo.n();
        self.engine.shared.set_origin(ext);
        let id = self.engine.shared.txn_id();
        let op = if is_write { Opcode::MemWr } else { Opcode::MemRd };
        let pkt = Packet::request(id, op, self.up, self.down, addr, now);
        self.engine.shared.forward(pkt, self.egress_delay);
        self.engine.run(u64::MAX); // drain: single outstanding transaction
        let up = self
            .engine
            .component_mut::<UpInterface>(self.up)
            .expect("up interface");
        let lat = up.done.pop().map(|(_, l)| l).unwrap_or(0);
        up.done.clear();
        lat + self.egress_delay + ns(25.0) // + ingress port
    }

    /// Inject a burst of concurrent misses (models the MSHR-level overlap
    /// the gem5 integration exposes); returns each miss's latency.
    pub fn access_batch(&mut self, reqs: &[(u64, bool)], at: Ps) -> Vec<Ps> {
        let now = self.engine.shared.now.max(at);
        self.engine.shared.now = now;
        let ext = self.engine.shared.topo.n();
        self.engine.shared.set_origin(ext);
        let mut ids = Vec::with_capacity(reqs.len());
        for &(addr, is_write) in reqs {
            self.misses_served += 1;
            let id = self.engine.shared.txn_id();
            let op = if is_write { Opcode::MemWr } else { Opcode::MemRd };
            let pkt = Packet::request(id, op, self.up, self.down, addr, now);
            self.engine.shared.forward(pkt, self.egress_delay);
            ids.push(id);
        }
        self.engine.run(u64::MAX);
        let egress = self.egress_delay;
        let up = self
            .engine
            .component_mut::<UpInterface>(self.up)
            .expect("up interface");
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            let lat = up
                .done
                .iter()
                .find(|(i, _)| *i == id)
                .map(|(_, l)| *l)
                .unwrap_or(0);
            out.push(lat + egress + ns(25.0));
        }
        up.done.clear();
        out
    }
}

/// Flit-level on-chip-network-style integration (gem5-garnet stand-in).
/// Each access is broken into flits routed hop-by-hop through a private
/// event heap — the per-flit event churn is the integration overhead the
/// paper measures in Table V, and the model has no notion of PCIe
/// serialization, headers, or duplex (its Table IV inaccuracy).
pub struct GarnetLikeWrapper {
    heap: BinaryHeap<std::cmp::Reverse<(Ps, u32)>>,
    hops: u32,
    per_hop: Ps,
    flits_per_packet: u32,
    mem_latency: Ps,
    link_free: Ps,
    pub flit_events: u64,
}

impl GarnetLikeWrapper {
    pub fn new() -> GarnetLikeWrapper {
        GarnetLikeWrapper {
            heap: BinaryHeap::new(),
            hops: 4,
            per_hop: ns(15.0), // router pipeline per hop
            flits_per_packet: 5,
            mem_latency: ns(95.0), // flat DRAM estimate, no bank model
            link_free: 0,
            flit_events: 0,
        }
    }

    pub fn access(&mut self, _addr: u64, _is_write: bool, at: Ps) -> Ps {
        // Request flits traverse the mesh one hop at a time.
        let start = at.max(self.link_free);
        for f in 0..self.flits_per_packet {
            let mut t = start + (f as Ps) * ns(1.0);
            for h in 0..self.hops {
                t += self.per_hop;
                self.heap.push(std::cmp::Reverse((t, f * self.hops + h)));
            }
        }
        // Drain the private event heap (the simulation work).
        let mut last = start;
        while let Some(std::cmp::Reverse((t, _))) = self.heap.pop() {
            last = last.max(t);
            self.flit_events += 1;
        }
        self.link_free = start + ns(2.0); // mild serialization
        // memory + response path (same cost back)
        last + self.mem_latency + (self.hops as Ps) * self.per_hop
            - at
    }
}

/// NUMA remote-socket emulation: flat latency + bandwidth cap. No PCIe
/// header/duplex modelling, no device-managed coherence — the method's
/// structural limits per the paper's §II-C.
pub struct NumaEmulator {
    pub base_latency: Ps,
    /// UPI-class bandwidth cap.
    pub bw_gbps: f64,
    next_free: Ps,
}

impl NumaEmulator {
    pub fn new(base_latency: Ps, bw_gbps: f64) -> NumaEmulator {
        NumaEmulator {
            base_latency,
            bw_gbps,
            next_free: 0,
        }
    }

    pub fn access(&mut self, _addr: u64, _is_write: bool, at: Ps) -> Ps {
        let start = at.max(self.next_free);
        self.next_free = start + crate::engine::time::ser_time(64, self.bw_gbps);
        (start - at) + self.base_latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wrapper() -> CxlMemWrapper {
        CxlMemWrapper::new(&BackendKind::Fixed(45.0), LinkCfg::default(), 1)
    }

    #[test]
    fn wrapper_roundtrip_latency_is_composed() {
        let mut w = wrapper();
        let lat = w.access(0x1000, false, 0);
        // full validation-platform composition (~242ns path + 45 media)
        assert!(lat > ns(220.0) && lat < ns(340.0), "latency {lat}");
    }

    #[test]
    fn wrapper_batch_shows_queueing() {
        let mut w = wrapper();
        let idle = w.access(0, false, 0);
        // A burst of concurrent misses queues on the link/device.
        let reqs: Vec<(u64, bool)> = (0..50).map(|i| (i * 64, false)).collect();
        let lats = w.access_batch(&reqs, 10_000);
        let max = *lats.iter().max().unwrap();
        assert!(max > idle, "loaded {max} should exceed idle {idle}");
        assert_eq!(w.misses_served, 51);
    }

    #[test]
    fn wrapper_dram_state_persists_across_misses() {
        use crate::dram::DramCfg;
        let mut w = CxlMemWrapper::new(
            &BackendKind::Dram(DramCfg::ddr5_4800()),
            LinkCfg::default(),
            1,
        );
        let cold = w.access(0, false, 0);
        let t = w.engine.shared.now;
        let hot = w.access(64, false, t); // same DRAM row: row-buffer hit
        assert!(hot < cold, "row hit {hot} should beat cold {cold}");
    }

    #[test]
    fn wrapper_writes_complete() {
        let mut w = wrapper();
        let lat = w.access(0x40, true, 0);
        assert!(lat > 0);
    }

    #[test]
    fn numa_emulator_flat_plus_bandwidth() {
        let mut n = NumaEmulator::new(ns(130.0), 20.0);
        let idle = n.access(0, false, 0);
        assert_eq!(idle, ns(130.0));
        // saturate: 64B at 20GB/s = 3.2ns per access
        let mut last = 0;
        for _ in 0..100 {
            last = n.access(0, false, 0);
        }
        assert!(last > idle);
    }

    #[test]
    fn garnet_like_produces_flit_events() {
        let mut g = GarnetLikeWrapper::new();
        let lat = g.access(0, false, 0);
        assert!(lat > 0);
        assert_eq!(g.flit_events, 20); // 5 flits x 4 hops
    }
}
