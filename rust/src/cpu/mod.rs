//! Trace-driven CPU + cache hierarchy — the gem5 substitute.
//!
//! The paper evaluates real applications two ways: "standalone" (PIN
//! traces filtered through a simulated cache hierarchy, misses fed to
//! ESF) and "gem5-integrated" (gem5 SE mode with ESF spliced into the
//! memory controller via Up/DownInterface wrappers). This module provides
//! both: a set-associative L1/L2/L3 hierarchy + in-order core model here,
//! and the memory-wrapper integration in [`wrapper`].

pub mod wrapper;

use crate::engine::time::Ps;

/// One instruction-stream memory reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CpuOp {
    /// Instructions executed since the previous memory reference.
    pub icount: u32,
    pub addr: u64,
    pub is_write: bool,
}

/// Set-associative cache with per-set LRU (distinct from the
/// fully-associative device cache: hierarchy levels are index-structured).
pub struct CacheSA {
    sets: usize,
    ways: usize,
    /// tags[set] = [(tag, stamp)] (ways entries max)
    tags: Vec<Vec<(u64, u64)>>,
    stamp: u64,
    pub hits: u64,
    pub misses: u64,
}

impl CacheSA {
    /// `size_bytes` total, 64B lines.
    pub fn new(size_bytes: u64, ways: usize) -> CacheSA {
        let lines = (size_bytes / 64).max(1) as usize;
        let ways = ways.min(lines).max(1);
        let sets = (lines / ways).max(1);
        CacheSA {
            sets,
            ways,
            tags: vec![Vec::new(); sets],
            stamp: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Access; allocate on miss; true = hit.
    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr / 64;
        let set = (line as usize) % self.sets;
        let tag = line / self.sets as u64;
        self.stamp += 1;
        let stamp = self.stamp;
        let ways = self.ways;
        let entries = &mut self.tags[set];
        if let Some(e) = entries.iter_mut().find(|(t, _)| *t == tag) {
            e.1 = stamp;
            self.hits += 1;
            return true;
        }
        self.misses += 1;
        if entries.len() >= ways {
            // evict LRU way
            let (idx, _) = entries
                .iter()
                .enumerate()
                .min_by_key(|(_, (_, s))| *s)
                .unwrap();
            entries.swap_remove(idx);
        }
        entries.push((tag, stamp));
        false
    }
}

/// Which level serviced an access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HitLevel {
    L1,
    L2,
    L3,
    Memory,
}

/// Three-level hierarchy; latencies in CPU cycles.
pub struct Hierarchy {
    pub l1: CacheSA,
    pub l2: CacheSA,
    pub l3: CacheSA,
    pub l1_cycles: u64,
    pub l2_cycles: u64,
    pub l3_cycles: u64,
}

impl Hierarchy {
    /// The paper's validation platform config: 1.7MB L1D, 72MB L2, 96MB
    /// L3 (socket totals of the Xeon Gold 6416H).
    pub fn xeon_6416h() -> Hierarchy {
        Hierarchy {
            l1: CacheSA::new(1_700_000 / 4, 8), // scale: single-core slice
            l2: CacheSA::new(72_000_000 / 18, 16),
            l3: CacheSA::new(96_000_000 / 18, 16),
            l1_cycles: 4,
            l2_cycles: 14,
            l3_cycles: 40,
        }
    }

    /// Small hierarchy for tests.
    pub fn tiny() -> Hierarchy {
        Hierarchy {
            l1: CacheSA::new(4 * 1024, 4),
            l2: CacheSA::new(32 * 1024, 8),
            l3: CacheSA::new(256 * 1024, 8),
            l1_cycles: 4,
            l2_cycles: 14,
            l3_cycles: 40,
        }
    }

    /// Access the hierarchy; returns the servicing level and the cycles
    /// spent in caches (memory time is added by the caller's model).
    pub fn access(&mut self, addr: u64) -> (HitLevel, u64) {
        if self.l1.access(addr) {
            return (HitLevel::L1, self.l1_cycles);
        }
        if self.l2.access(addr) {
            return (HitLevel::L2, self.l1_cycles + self.l2_cycles);
        }
        if self.l3.access(addr) {
            return (
                HitLevel::L3,
                self.l1_cycles + self.l2_cycles + self.l3_cycles,
            );
        }
        (
            HitLevel::Memory,
            self.l1_cycles + self.l2_cycles + self.l3_cycles,
        )
    }
}

/// Result of executing a trace.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub instructions: u64,
    pub cycles: u64,
    pub llc_misses: u64,
    pub mem_lat_sum_ps: u128,
    pub wall_ns: f64,
}

impl ExecStats {
    pub fn exec_time_ns(&self, freq_ghz: f64) -> f64 {
        self.cycles as f64 / freq_ghz
    }

    pub fn mpki(&self) -> f64 {
        if self.instructions == 0 {
            0.0
        } else {
            self.llc_misses as f64 * 1000.0 / self.instructions as f64
        }
    }
}

/// In-order core model: base IPC 1, plus cache cycles, plus memory stalls.
/// `mlp` is the memory-level-parallelism divisor applied to consecutive
/// miss stalls (1.0 = fully serialized misses — the standalone trace mode;
/// >1 models the overlap an OoO core / gem5 exposes).
pub struct TraceCore {
    pub hierarchy: Hierarchy,
    pub freq_ghz: f64,
    pub mlp: f64,
    /// Simulated time, persistent across `run` calls so stateful memory
    /// models (DRAM banks, nested engines) see monotone timestamps.
    pub now_ps: Ps,
}

impl TraceCore {
    pub fn new(hierarchy: Hierarchy) -> TraceCore {
        TraceCore {
            hierarchy,
            freq_ghz: 2.2, // Xeon Gold 6416H base clock
            mlp: 1.0,
            now_ps: 0,
        }
    }

    /// Execute `ops` against a memory model: `mem(addr, is_write, now_ps)
    /// -> latency_ps` for LLC misses. Returns aggregate stats; also
    /// measures host wallclock (Table V's simulation-speed metric).
    pub fn run(
        &mut self,
        ops: &[CpuOp],
        mut mem: impl FnMut(u64, bool, Ps) -> Ps,
    ) -> ExecStats {
        // det-ok: Table V's simulation-speed metric is host wall-clock by
        // definition; it feeds reporting only, never simulated time.
        #[allow(clippy::disallowed_methods)]
        let wall_start = std::time::Instant::now();
        let mut st = ExecStats::default();
        let ps_per_cycle = (1000.0 / self.freq_ghz) as u64;
        let mut now_ps: Ps = self.now_ps;
        for op in ops {
            st.instructions += op.icount as u64;
            let mut cycles = op.icount as u64;
            let (level, cache_cycles) = self.hierarchy.access(op.addr);
            cycles += cache_cycles;
            if level == HitLevel::Memory {
                st.llc_misses += 1;
                let lat_ps = mem(op.addr, op.is_write, now_ps);
                st.mem_lat_sum_ps += lat_ps as u128;
                let stall = (lat_ps as f64 / self.mlp) as u64;
                cycles += stall / ps_per_cycle;
            }
            st.cycles += cycles;
            now_ps += cycles * ps_per_cycle;
        }
        self.now_ps = now_ps;
        st.wall_ns = wall_start.elapsed().as_nanos() as f64;
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_sa_hits_after_fill() {
        let mut c = CacheSA::new(4096, 4);
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(32)); // same line
        assert!(!c.access(64));
    }

    #[test]
    fn cache_sa_set_conflict_eviction() {
        let mut c = CacheSA::new(64 * 2, 1); // 2 sets, direct-mapped
        assert!(!c.access(0));
        assert!(!c.access(128)); // same set (line 2, set 0), evicts 0
        assert!(!c.access(0)); // miss again
    }

    #[test]
    fn hierarchy_levels_in_order() {
        let mut h = Hierarchy::tiny();
        assert_eq!(h.access(0).0, HitLevel::Memory);
        assert_eq!(h.access(0).0, HitLevel::L1);
        // Evict from L1 (4KiB / 64 = 64 lines) but stay in L2.
        for i in 1..=64u64 {
            h.access(i * 64);
        }
        let (lvl, _) = h.access(0);
        assert!(lvl == HitLevel::L2 || lvl == HitLevel::L1);
    }

    #[test]
    fn core_stalls_on_memory() {
        let ops: Vec<CpuOp> = (0..1000)
            .map(|i| CpuOp {
                icount: 5,
                addr: (i as u64) * 4096 * 64, // all distinct sets -> misses
                is_write: false,
            })
            .collect();
        let mut fast = TraceCore::new(Hierarchy::tiny());
        let sf = fast.run(&ops, |_, _, _| 100_000); // 100ns memory
        let mut slow = TraceCore::new(Hierarchy::tiny());
        let ss = slow.run(&ops, |_, _, _| 300_000); // 300ns memory
        assert!(ss.cycles > sf.cycles);
        assert_eq!(sf.llc_misses, 1000);
        // overhead ratio roughly tracks latency ratio on a fully
        // memory-bound trace
        let ratio = ss.cycles as f64 / sf.cycles as f64;
        assert!(ratio > 1.5, "ratio {ratio}");
    }

    #[test]
    fn mlp_reduces_stall() {
        let ops: Vec<CpuOp> = (0..500)
            .map(|i| CpuOp {
                icount: 2,
                addr: (i as u64) * 8192 * 64,
                is_write: false,
            })
            .collect();
        let mut serial = TraceCore::new(Hierarchy::tiny());
        let a = serial.run(&ops, |_, _, _| 200_000);
        let mut overlapped = TraceCore::new(Hierarchy::tiny());
        overlapped.mlp = 2.0;
        let b = overlapped.run(&ops, |_, _, _| 200_000);
        assert!(b.cycles < a.cycles);
    }

    #[test]
    fn gcc_mpki_lower_than_mcf() {
        use crate::workloads::spec::SpecWorkload;
        let run = |w: SpecWorkload| {
            let ops = w.generate(200_000, 11);
            let mut core = TraceCore::new(Hierarchy::tiny());
            core.run(&ops, |_, _, _| 100_000).mpki()
        };
        let (g, m) = (run(SpecWorkload::Gcc), run(SpecWorkload::Mcf));
        assert!(g < m, "gcc mpki {g:.1} should be below mcf {m:.1}");
    }
}
