//! Configuration and system building.
//!
//! ESF is configured either programmatically (the experiments construct
//! `SystemCfg` values directly) or from a JSON file (`esf run --config`).
//! `build_system` assembles the full simulator: fabric topology, routing
//! (native BFS or the PJRT-executed Pallas APSP kernel), and one device
//! component per node.

use crate::devices::{
    FixedBackend, Interleave, MemBackend, MemDev, MemDevCfg, Pattern, Requester, RequesterCfg,
    Switch, SwitchCfg, VictimPolicy,
};
use crate::dram::{DramBackend, DramCfg};
use crate::engine::time::{ns, Ps};
use crate::engine::{Engine, Shared};
use crate::interconnect::{
    build, Duplex, Fabric, LinkCfg, NodeKind, Routing, Strategy, TopologyKind,
};
use crate::proto::NodeId;
use crate::ssd::{SsdBackend, SsdCfg};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// Latency constants of critical components (paper Table III).
#[derive(Clone, Copy, Debug)]
pub struct LatencyCfg {
    pub requester_process: Ps,
    pub cache_access: Ps,
    pub device_ctrl: Ps,
    pub pcie_port: Ps,
    pub bus_time: Ps,
    pub switching: Ps,
}

impl Default for LatencyCfg {
    fn default() -> Self {
        LatencyCfg {
            requester_process: ns(10.0),
            cache_access: ns(12.0),
            device_ctrl: ns(40.0),
            pcie_port: ns(25.0),
            bus_time: ns(1.0),
            switching: ns(20.0),
        }
    }
}

/// Media backend selection for memory endpoints.
#[derive(Clone, Debug)]
pub enum BackendKind {
    /// Fixed media latency (ns), fully pipelined.
    Fixed(f64),
    /// DRAMsim3-substitute bank/row timing model.
    Dram(DramCfg),
    /// SimpleSSD-substitute NAND/FTL model.
    Ssd(SsdCfg),
}

impl BackendKind {
    pub fn instantiate(&self, seed: u64) -> Box<dyn MemBackend> {
        match self {
            BackendKind::Fixed(l) => Box::new(FixedBackend { latency: ns(*l) }),
            BackendKind::Dram(cfg) => Box::new(DramBackend::new(cfg.clone())),
            BackendKind::Ssd(cfg) => Box::new(SsdBackend::new(cfg.clone(), seed)),
        }
    }
}

/// Full system configuration.
#[derive(Clone, Debug)]
pub struct SystemCfg {
    pub topology: TopologyKind,
    /// N requesters and N memory endpoints ("system scale = 2N").
    pub n: usize,
    pub link: LinkCfg,
    pub strategy: Strategy,
    pub latency: LatencyCfg,
    pub seed: u64,
    // Requester template (applied to every requester unless overridden
    // via `build_system_with`).
    pub pattern: Pattern,
    pub read_ratio: f64,
    pub queue_capacity: usize,
    pub issue_interval: Ps,
    pub requests_per_endpoint: u64,
    pub warmup_fraction: f64,
    pub footprint_lines: u64,
    pub cache_lines: usize,
    pub interleave: Interleave,
    // Memory endpoint template.
    pub backend: BackendKind,
    pub snoop_filter: Option<(usize, VictimPolicy)>,
    /// Intra-scenario parallelism: worker threads for the partitioned
    /// event-domain engine (1 = sequential loop, 0 = all cores). Results
    /// are byte-identical for every value, so this is deliberately NOT
    /// part of [`SystemCfg::to_json`] / [`SystemCfg::fingerprint`] — the
    /// sweep result cache must hit across differently-threaded runs.
    pub intra_jobs: usize,
}

impl SystemCfg {
    pub fn new(topology: TopologyKind, n: usize) -> SystemCfg {
        SystemCfg {
            topology,
            n,
            link: LinkCfg::default(),
            strategy: Strategy::Oblivious,
            latency: LatencyCfg::default(),
            seed: 42,
            pattern: Pattern::Random,
            read_ratio: 1.0,
            queue_capacity: 16,
            issue_interval: ns(4.0),
            requests_per_endpoint: 1000,
            warmup_fraction: 0.25,
            footprint_lines: 1 << 16,
            cache_lines: 0,
            interleave: Interleave::Line,
            backend: BackendKind::Fixed(45.0),
            snoop_filter: None,
            intra_jobs: 1,
        }
    }
}

/// A built, ready-to-run system.
pub struct System {
    pub engine: Engine,
    pub requesters: Vec<NodeId>,
    pub memories: Vec<NodeId>,
    pub switches: Vec<NodeId>,
}

/// How to compute the routing tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingSource {
    /// Native Rust BFS.
    Native,
    /// AOT Pallas APSP kernel through PJRT; falls back to native if the
    /// artifacts are missing or the fabric exceeds the largest artifact.
    Pjrt,
}

/// Build with the default per-requester template.
pub fn build_system(cfg: &SystemCfg) -> System {
    build_system_with(cfg, RoutingSource::Native, |_idx, r| r)
}

/// Build, customizing each requester's config (`idx` is the requester
/// index, not the node id).
pub fn build_system_with(
    cfg: &SystemCfg,
    routing_src: RoutingSource,
    mut customize: impl FnMut(usize, RequesterCfg) -> RequesterCfg,
) -> System {
    let fabric = build(cfg.topology, cfg.n, cfg.link);
    let routing = make_routing(&fabric, routing_src);
    build_on_fabric(cfg, fabric, routing, &mut customize)
}

/// Routing table construction, optionally through the PJRT APSP kernel.
pub fn make_routing(fabric: &Fabric, src: RoutingSource) -> Routing {
    match src {
        RoutingSource::Native => Routing::build_bfs(&fabric.topo),
        RoutingSource::Pjrt => {
            let n = fabric.topo.n();
            let unreach = crate::runtime::UNREACH;
            match crate::runtime::Runtime::load_default() {
                Ok(mut rt) if rt.max_apsp() >= n => {
                    let adj = fabric.topo.adjacency_matrix(unreach);
                    match rt.apsp(&adj, n) {
                        Ok(d) => Routing::from_distances(&fabric.topo, &d, unreach),
                        Err(e) => {
                            eprintln!("esf: PJRT APSP failed ({e}); using native BFS");
                            Routing::build_bfs(&fabric.topo)
                        }
                    }
                }
                Ok(_) => Routing::build_bfs(&fabric.topo),
                Err(e) => {
                    eprintln!("esf: PJRT unavailable ({e}); using native BFS");
                    Routing::build_bfs(&fabric.topo)
                }
            }
        }
    }
}

/// Assemble engine + components over an already-built fabric.
pub fn build_on_fabric(
    cfg: &SystemCfg,
    fabric: Fabric,
    routing: Routing,
    customize: &mut dyn FnMut(usize, RequesterCfg) -> RequesterCfg,
) -> System {
    let Fabric {
        topo,
        requesters,
        memories,
        switches,
    } = fabric;
    let shared = Shared::new(topo, routing, cfg.strategy);
    let mut engine = Engine::new(shared);

    let total = cfg.requests_per_endpoint * memories.len() as u64;
    let warmup = (total as f64 * cfg.warmup_fraction) as u64;
    let mut req_idx = 0usize;
    for node in 0..engine.shared.topo.n() {
        match engine.shared.topo.kind(node) {
            NodeKind::Requester => {
                let mut rc = RequesterCfg::new(node, memories.clone());
                rc.queue_capacity = cfg.queue_capacity;
                rc.issue_interval = cfg.issue_interval;
                rc.process_time = cfg.latency.requester_process;
                rc.cache_access = cfg.latency.cache_access;
                rc.port_delay = cfg.latency.pcie_port;
                rc.pattern = cfg.pattern.clone();
                rc.read_ratio = cfg.read_ratio;
                rc.total_requests = total;
                rc.warmup_requests = warmup;
                rc.footprint_lines = cfg.footprint_lines;
                rc.cache_lines = cfg.cache_lines;
                rc.interleave = cfg.interleave.clone();
                rc.seed = cfg.seed;
                let rc = customize(req_idx, rc);
                req_idx += 1;
                engine.register(Box::new(Requester::new(rc)));
            }
            NodeKind::Switch => {
                let mut sc = SwitchCfg::new(node);
                sc.switching_time = cfg.latency.switching;
                sc.port_delay = cfg.latency.pcie_port;
                engine.register(Box::new(Switch::new(sc)));
            }
            NodeKind::Memory => {
                let mut mc = MemDevCfg::new(node);
                mc.ctrl_time = cfg.latency.device_ctrl;
                mc.port_delay = cfg.latency.pcie_port;
                mc.snoop_filter = cfg.snoop_filter;
                let backend = cfg.backend.instantiate(cfg.seed ^ node as u64);
                engine.register(Box::new(MemDev::new(mc, backend)));
            }
        }
    }
    System {
        engine,
        requesters,
        memories,
        switches,
    }
}

// ------------------------------------------------------------- JSON I/O

impl SystemCfg {
    /// Parse from the JSON config format (see `examples/config.json` and
    /// README §Configuration).
    pub fn from_json(j: &Json) -> Result<SystemCfg> {
        let topo_name = j.str_or("topology", "fully-connected");
        let topology = TopologyKind::parse(topo_name)
            .ok_or_else(|| anyhow!("unknown topology '{topo_name}'"))?;
        let n = j.u64_or("scale", 8).max(2) as usize / 2;
        let mut cfg = SystemCfg::new(topology, n.max(1));
        cfg.seed = j.u64_or("seed", 42);
        // Worker threads for the partitioned engine (0 = all cores);
        // byte-identical output at any value (tests/partition.rs).
        cfg.intra_jobs = j.u64_or("intra_jobs", 1) as usize;
        if let Some(link) = j.get("link") {
            cfg.link = LinkCfg {
                bandwidth_gbps: link.f64_or("bandwidth_gbps", 64.0),
                latency: ns(link.f64_or("latency_ns", 1.0)),
                duplex: match link.str_or("duplex", "full") {
                    "half" => Duplex::Half,
                    _ => Duplex::Full,
                },
                turnaround: ns(link.f64_or("turnaround_ns", 0.0)),
                header_bytes: link.u64_or("header_bytes", 16),
            };
        }
        cfg.strategy = match j.str_or("routing", "oblivious") {
            "adaptive" => Strategy::Adaptive,
            _ => Strategy::Oblivious,
        };
        if let Some(r) = j.get("requester") {
            cfg.queue_capacity = r.u64_or("queue_capacity", 16) as usize;
            cfg.issue_interval = ns(r.f64_or("issue_interval_ns", 4.0));
            cfg.read_ratio = r.f64_or("read_ratio", 1.0);
            cfg.requests_per_endpoint = r.u64_or("requests_per_endpoint", 1000);
            cfg.warmup_fraction = r.f64_or("warmup_fraction", 0.25);
            cfg.footprint_lines = r.u64_or("footprint_lines", 1 << 16);
            cfg.cache_lines = r.u64_or("cache_lines", 0) as usize;
            cfg.pattern = match r.str_or("pattern", "random") {
                "random" | "uniform" | "uniform-random" => Pattern::Random,
                "stream" | "sequential" => Pattern::Stream,
                "skewed" => Pattern::Skewed {
                    hot_frac: r.f64_or("hot_frac", 0.1),
                    hot_prob: r.f64_or("hot_prob", 0.9),
                },
                "zipfian" | "zipf" => Pattern::Zipf {
                    theta: r.f64_or("theta", 0.99),
                },
                "pointer-chase" | "chase" => Pattern::PointerChase,
                other => bail!("unknown pattern '{other}' (trace replay is CLI-only)"),
            };
            cfg.interleave = match r.str_or("interleave", "line") {
                "line" => Interleave::Line,
                "page" => Interleave::Page(r.u64_or("lines_per_page", 64)),
                "fixed" => Interleave::Fixed(r.u64_or("endpoint", 0) as usize),
                other => bail!("unknown interleave '{other}'"),
            };
        }
        if let Some(m) = j.get("memory") {
            cfg.backend = match m.str_or("backend", "fixed") {
                "fixed" => BackendKind::Fixed(m.f64_or("latency_ns", 45.0)),
                "dram" | "ddr5" => BackendKind::Dram(DramCfg::ddr5_4800()),
                "hbm" | "hbm2" => BackendKind::Dram(DramCfg::hbm2()),
                "ssd" => BackendKind::Ssd(SsdCfg::default()),
                other => bail!("unknown backend '{other}'"),
            };
            if let Some(sf) = m.get("snoop_filter") {
                let cap = sf.u64_or("capacity", 1024) as usize;
                let policy = match sf.str_or("policy", "fifo") {
                    "fifo" => VictimPolicy::Fifo,
                    "lru" => VictimPolicy::Lru,
                    "lfi" => VictimPolicy::Lfi,
                    "lifo" => VictimPolicy::Lifo,
                    "mru" => VictimPolicy::Mru,
                    "blocklen" => VictimPolicy::BlockLen {
                        max_len: sf.u64_or("max_len", 4) as u8,
                    },
                    other => bail!("unknown snoop filter policy '{other}'"),
                };
                cfg.snoop_filter = Some((cap, policy));
            }
        }
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> Result<SystemCfg> {
        let j = Json::parse(s).map_err(|e| anyhow!("config parse: {e}"))?;
        Self::from_json(&j)
    }

    /// Canonical JSON of every simulation-relevant field. Two configs
    /// produce the same string iff they describe the same simulation, so
    /// this is the content identity the sweep result cache keys on
    /// (`fingerprint()` hashes it). Keys serialize sorted (`Json::Obj` is
    /// a `BTreeMap`) and floats print shortest-roundtrip, so the string
    /// is byte-stable across runs and platforms.
    pub fn to_json(&self) -> Json {
        let pattern = match &self.pattern {
            Pattern::Random => Json::obj(vec![("kind", Json::Str("random".into()))]),
            Pattern::Stream => Json::obj(vec![("kind", Json::Str("stream".into()))]),
            Pattern::Skewed { hot_frac, hot_prob } => Json::obj(vec![
                ("kind", Json::Str("skewed".into())),
                ("hot_frac", Json::Num(*hot_frac)),
                ("hot_prob", Json::Num(*hot_prob)),
            ]),
            Pattern::Zipf { theta } => Json::obj(vec![
                ("kind", Json::Str("zipf".into())),
                ("theta", Json::Num(*theta)),
            ]),
            Pattern::PointerChase => {
                Json::obj(vec![("kind", Json::Str("pointer-chase".into()))])
            }
            Pattern::Trace(ops) => {
                // A trace is identified by a content hash (hex string —
                // u64 doesn't fit losslessly in a JSON number).
                let mut h = crate::util::Fnv64::new();
                for op in ops.iter() {
                    h.word(op.addr);
                    h.byte(op.is_write as u8);
                    h.word(op.gap_ps);
                }
                Json::obj(vec![
                    ("kind", Json::Str("trace".into())),
                    ("len", Json::Num(ops.len() as f64)),
                    ("fnv", Json::Str(format!("{:016x}", h.finish()))),
                ])
            }
        };
        let interleave = match &self.interleave {
            Interleave::Line => Json::obj(vec![("kind", Json::Str("line".into()))]),
            Interleave::Page(lines) => Json::obj(vec![
                ("kind", Json::Str("page".into())),
                ("lines_per_page", Json::Num(*lines as f64)),
            ]),
            Interleave::Fixed(i) => Json::obj(vec![
                ("kind", Json::Str("fixed".into())),
                ("endpoint", Json::Num(*i as f64)),
            ]),
        };
        let backend = match &self.backend {
            BackendKind::Fixed(lat_ns) => Json::obj(vec![
                ("kind", Json::Str("fixed".into())),
                ("latency_ns", Json::Num(*lat_ns)),
            ]),
            BackendKind::Dram(d) => Json::obj(vec![
                ("kind", Json::Str("dram".into())),
                ("banks", Json::Num(d.banks as f64)),
                ("row_bytes", Json::Num(d.row_bytes as f64)),
                ("t_rcd_ps", Json::Num(d.t_rcd as f64)),
                ("t_rp_ps", Json::Num(d.t_rp as f64)),
                ("t_cl_ps", Json::Num(d.t_cl as f64)),
                ("t_burst_ps", Json::Num(d.t_burst as f64)),
                ("t_wr_ps", Json::Num(d.t_wr as f64)),
            ]),
            BackendKind::Ssd(s) => Json::obj(vec![
                ("kind", Json::Str("ssd".into())),
                ("channels", Json::Num(s.channels as f64)),
                ("dies_per_channel", Json::Num(s.dies_per_channel as f64)),
                ("page_bytes", Json::Num(s.page_bytes as f64)),
                ("read_lat_ps", Json::Num(s.read_lat as f64)),
                ("program_lat_ps", Json::Num(s.program_lat as f64)),
                ("xfer_lat_ps", Json::Num(s.xfer_lat as f64)),
                ("ftl_lat_ps", Json::Num(s.ftl_lat as f64)),
            ]),
        };
        let snoop_filter = match &self.snoop_filter {
            None => Json::Null,
            Some((cap, policy)) => {
                let mut fields = vec![
                    ("capacity", Json::Num(*cap as f64)),
                    ("policy", Json::Str(policy.name().to_lowercase())),
                ];
                if let VictimPolicy::BlockLen { max_len } = policy {
                    fields.push(("max_len", Json::Num(*max_len as f64)));
                }
                Json::obj(fields)
            }
        };
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("topology", Json::Str(self.topology.name().into())),
            ("n", Json::Num(self.n as f64)),
            (
                "link",
                Json::obj(vec![
                    ("bandwidth_gbps", Json::Num(self.link.bandwidth_gbps)),
                    ("latency_ps", Json::Num(self.link.latency as f64)),
                    (
                        "duplex",
                        Json::Str(
                            match self.link.duplex {
                                Duplex::Full => "full",
                                Duplex::Half => "half",
                            }
                            .into(),
                        ),
                    ),
                    ("turnaround_ps", Json::Num(self.link.turnaround as f64)),
                    ("header_bytes", Json::Num(self.link.header_bytes as f64)),
                ]),
            ),
            (
                "strategy",
                Json::Str(
                    match self.strategy {
                        Strategy::Oblivious => "oblivious",
                        Strategy::Adaptive => "adaptive",
                    }
                    .into(),
                ),
            ),
            (
                "latency",
                Json::obj(vec![
                    ("requester_process_ps", Json::Num(self.latency.requester_process as f64)),
                    ("cache_access_ps", Json::Num(self.latency.cache_access as f64)),
                    ("device_ctrl_ps", Json::Num(self.latency.device_ctrl as f64)),
                    ("pcie_port_ps", Json::Num(self.latency.pcie_port as f64)),
                    ("bus_time_ps", Json::Num(self.latency.bus_time as f64)),
                    ("switching_ps", Json::Num(self.latency.switching as f64)),
                ]),
            ),
            // Hex string: an arbitrary u64 seed does not fit losslessly
            // in a JSON number.
            ("seed", Json::Str(format!("{:016x}", self.seed))),
            ("pattern", pattern),
            ("read_ratio", Json::Num(self.read_ratio)),
            ("queue_capacity", Json::Num(self.queue_capacity as f64)),
            ("issue_interval_ps", Json::Num(self.issue_interval as f64)),
            ("requests_per_endpoint", Json::Num(self.requests_per_endpoint as f64)),
            ("warmup_fraction", Json::Num(self.warmup_fraction)),
            ("footprint_lines", Json::Num(self.footprint_lines as f64)),
            ("cache_lines", Json::Num(self.cache_lines as f64)),
            ("interleave", interleave),
            ("backend", backend),
            ("snoop_filter", snoop_filter),
        ])
    }

    /// Content hash of the canonical JSON — the sweep cache key.
    pub fn fingerprint(&self) -> u64 {
        crate::util::fnv1a64(self.to_json().to_string().as_bytes())
    }

    /// The warm-up prefix projection: this config with every knob that
    /// provably cannot influence the warm-up phase normalized to a fixed
    /// value. Two configs with equal projections run byte-identical
    /// warm-up prefixes, so a quiescent snapshot taken at the warm-up
    /// boundary under one of them can seed runs of all of them
    /// (`sweep` warm-start forking; `esf check` rule ESF-C014 verifies
    /// the match before a fork).
    pub fn prefix_cfg(&self) -> SystemCfg {
        let mut p = self.clone();
        let warmup = p.warmup_requests();
        // Warm-up operations are forced to reads (devices::requester
        // draws the write coin but discards the outcome until collection
        // starts), so read_ratio cannot touch the prefix — unless there
        // is no warm-up at all, or the op stream is a recorded trace
        // (trace replay honors the recorded op kinds verbatim).
        if warmup > 0 && !matches!(p.pattern, Pattern::Trace(_)) {
            p.read_ratio = 1.0;
        }
        // Without a requester cache every packet goes out non-coherent,
        // so the device snoop filter never sees a request and its
        // configuration is inert — in the prefix and everywhere else.
        if p.cache_lines == 0 {
            p.snoop_filter = None;
        }
        p
    }

    /// Canonical JSON string of the prefix projection (embedded in
    /// snapshot headers so a fork can prove compatibility).
    pub fn prefix_canon(&self) -> String {
        self.prefix_cfg().to_json().to_string()
    }

    /// Content hash of [`SystemCfg::prefix_canon`] — the warm-start
    /// snapshot cache key.
    pub fn prefix_fingerprint(&self) -> u64 {
        crate::util::fnv1a64(self.prefix_canon().as_bytes())
    }

    /// Per-requester warm-up request count a system built from this
    /// config issues — `build_on_fabric`'s exact computation
    /// (`memories.len() == n` for every preset fabric). Zero means the
    /// measurement epoch opens immediately and there is no prefix to
    /// share.
    pub fn warmup_requests(&self) -> u64 {
        let total = self.requests_per_endpoint * self.n as u64;
        (total as f64 * self.warmup_fraction) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_system_builds_and_runs() {
        let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 2);
        cfg.requests_per_endpoint = 50;
        cfg.warmup_fraction = 0.2;
        let mut sys = build_system(&cfg);
        let events = sys.engine.run(10_000_000);
        assert!(events > 0);
        // All requesters finished their budget.
        for &r in &sys.requesters {
            let rq = sys.engine.component::<Requester>(r).unwrap();
            assert!(rq.done(), "requester {r} not done");
            assert!(rq.stats.completed > 0);
        }
        assert_eq!(sys.engine.shared.dropped, 0);
    }

    #[test]
    fn json_config_roundtrip() {
        let cfg = SystemCfg::from_json_str(
            r#"{
                "topology": "ring", "scale": 8, "seed": 7,
                "link": {"bandwidth_gbps": 32, "duplex": "half",
                         "turnaround_ns": 4, "header_bytes": 32},
                "routing": "adaptive",
                "requester": {"pattern": "skewed", "hot_frac": 0.2,
                              "read_ratio": 0.5, "cache_lines": 128},
                "memory": {"backend": "dram",
                           "snoop_filter": {"capacity": 256, "policy": "lifo"}}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.topology, TopologyKind::Ring);
        assert_eq!(cfg.n, 4);
        assert_eq!(cfg.link.bandwidth_gbps, 32.0);
        assert_eq!(cfg.link.duplex, Duplex::Half);
        assert_eq!(cfg.strategy, Strategy::Adaptive);
        assert_eq!(cfg.cache_lines, 128);
        assert!(matches!(cfg.backend, BackendKind::Dram(_)));
        assert_eq!(cfg.snoop_filter, Some((256, VictimPolicy::Lifo)));
    }

    #[test]
    fn json_config_new_patterns_and_backends() {
        let cfg = SystemCfg::from_json_str(
            r#"{"requester": {"pattern": "zipfian", "theta": 1.2},
                "memory": {"backend": "hbm"}}"#,
        )
        .unwrap();
        assert!(matches!(cfg.pattern, Pattern::Zipf { theta } if theta == 1.2));
        assert!(matches!(cfg.backend, BackendKind::Dram(_)));
        let cfg =
            SystemCfg::from_json_str(r#"{"requester": {"pattern": "pointer-chase"}}"#).unwrap();
        assert!(matches!(cfg.pattern, Pattern::PointerChase));
        let cfg = SystemCfg::from_json_str(r#"{"requester": {"pattern": "sequential"}}"#).unwrap();
        assert!(matches!(cfg.pattern, Pattern::Stream));
    }

    #[test]
    fn canonical_json_is_stable_and_discriminating() {
        let a = SystemCfg::new(TopologyKind::Ring, 4);
        let b = SystemCfg::new(TopologyKind::Ring, 4);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Every axis-relevant field must move the fingerprint.
        let fp = |mutate: &dyn Fn(&mut SystemCfg)| {
            let mut c = SystemCfg::new(TopologyKind::Ring, 4);
            mutate(&mut c);
            c.fingerprint()
        };
        let base = a.fingerprint();
        assert_ne!(base, fp(&|c| c.topology = TopologyKind::Chain));
        assert_ne!(base, fp(&|c| c.seed = 43));
        assert_ne!(base, fp(&|c| c.pattern = Pattern::Zipf { theta: 0.99 }));
        assert_ne!(base, fp(&|c| c.pattern = Pattern::PointerChase));
        assert_ne!(base, fp(&|c| c.backend = BackendKind::Dram(DramCfg::ddr5_4800())));
        assert_ne!(base, fp(&|c| c.backend = BackendKind::Dram(DramCfg::hbm2())));
        assert_ne!(base, fp(&|c| c.backend = BackendKind::Ssd(SsdCfg::default())));
        assert_ne!(base, fp(&|c| c.snoop_filter = Some((64, VictimPolicy::Lfi))));
        assert_ne!(
            fp(&|c| c.snoop_filter = Some((64, VictimPolicy::Lfi))),
            fp(&|c| c.snoop_filter = Some((64, VictimPolicy::Fifo)))
        );
        assert_ne!(
            fp(&|c| c.snoop_filter = Some((64, VictimPolicy::BlockLen { max_len: 2 }))),
            fp(&|c| c.snoop_filter = Some((64, VictimPolicy::BlockLen { max_len: 4 })))
        );
        assert_ne!(base, fp(&|c| c.read_ratio = 0.5));
        assert_ne!(base, fp(&|c| c.cache_lines = 64));
        // intra_jobs is a pure parallelism knob (results byte-identical),
        // so it must NOT fragment the sweep cache key.
        assert_eq!(base, fp(&|c| c.intra_jobs = 8));
        // The canonical string parses back as JSON (cache cells embed it).
        assert!(Json::parse(&a.to_json().to_string()).is_ok());
    }

    #[test]
    fn prefix_projection_normalizes_post_warmup_knobs() {
        let base = SystemCfg::new(TopologyKind::Ring, 4);
        // read_ratio moves the full fingerprint but not the prefix one.
        let mut r = base.clone();
        r.read_ratio = 0.5;
        assert_ne!(base.fingerprint(), r.fingerprint());
        assert_eq!(base.prefix_fingerprint(), r.prefix_fingerprint());
        // A snoop filter is inert only while there is no requester cache.
        let mut s = base.clone();
        s.snoop_filter = Some((64, VictimPolicy::Lfi));
        assert_eq!(base.prefix_fingerprint(), s.prefix_fingerprint());
        let mut sc = s.clone();
        sc.cache_lines = 64;
        let mut bc = base.clone();
        bc.cache_lines = 64;
        assert_ne!(
            bc.prefix_fingerprint(),
            sc.prefix_fingerprint(),
            "a cached requester exercises the filter during warm-up"
        );
        // Without warm-up there is no forced-read phase: read_ratio stays
        // prefix-relevant.
        let mut nw = base.clone();
        nw.warmup_fraction = 0.0;
        let mut nwr = nw.clone();
        nwr.read_ratio = 0.5;
        assert_ne!(nw.prefix_fingerprint(), nwr.prefix_fingerprint());
        // Prefix-relevant knobs keep discriminating.
        let mut seed = base.clone();
        seed.seed = 43;
        assert_ne!(base.prefix_fingerprint(), seed.prefix_fingerprint());
    }

    #[test]
    fn json_config_rejects_unknowns() {
        assert!(SystemCfg::from_json_str(r#"{"topology": "mobius"}"#).is_err());
        assert!(
            SystemCfg::from_json_str(r#"{"requester": {"pattern": "quantum"}}"#).is_err()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed: u64| {
            let mut cfg = SystemCfg::new(TopologyKind::Chain, 2);
            cfg.seed = seed;
            cfg.requests_per_endpoint = 100;
            // Small footprint + cache: hit patterns depend on the seed's
            // address stream, so different seeds must diverge.
            cfg.footprint_lines = 256;
            cfg.cache_lines = 64;
            let mut sys = build_system(&cfg);
            sys.engine.run(u64::MAX);
            let r = sys.engine.component::<Requester>(sys.requesters[0]).unwrap();
            (r.stats.completed, r.stats.lat_sum)
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1).1, run(2).1, "different seeds should differ");
    }
}
