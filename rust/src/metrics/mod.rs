//! Post-run metric extraction: aggregate bandwidth/latency over a built
//! `System`, latency histograms, and per-hop breakdowns. Used by every
//! experiment harness.

use crate::config::System;
use crate::devices::{MemDev, Requester};
use crate::engine::time::{to_ns, Ps};

/// Aggregate results over all requesters for the measurement epoch.
#[derive(Clone, Debug, Default)]
pub struct Aggregate {
    /// Total payload bytes completed during the epoch.
    pub bytes: u64,
    pub completed: u64,
    pub reads: u64,
    pub writes: u64,
    pub lat_sum_ns: f64,
    pub lat_max_ns: f64,
    /// Epoch span in ns.
    pub span_ns: f64,
}

impl Aggregate {
    /// Aggregate bandwidth in GB/s.
    pub fn bandwidth_gbps(&self) -> f64 {
        if self.span_ns <= 0.0 {
            0.0
        } else {
            self.bytes as f64 / self.span_ns
        }
    }

    pub fn avg_latency_ns(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.lat_sum_ns / self.completed as f64
        }
    }

    /// Throughput in million accesses per second of simulated time.
    pub fn throughput_maps(&self) -> f64 {
        if self.span_ns <= 0.0 {
            0.0
        } else {
            self.completed as f64 * 1000.0 / self.span_ns
        }
    }
}

/// Collect the aggregate over every requester in the system.
pub fn aggregate(sys: &System) -> Aggregate {
    let mut a = Aggregate {
        span_ns: to_ns(sys.engine.shared.epoch_span()),
        ..Aggregate::default()
    };
    for &r in &sys.requesters {
        let rq: &Requester = sys
            .engine
            .component(r)
            .expect("requester node holds a Requester");
        a.bytes += rq.stats.bytes;
        a.completed += rq.stats.completed;
        a.reads += rq.stats.reads;
        a.writes += rq.stats.writes;
        a.lat_sum_ns += rq.stats.lat_sum as f64 / 1000.0;
        a.lat_max_ns = a.lat_max_ns.max(to_ns(rq.stats.lat_max));
    }
    a
}

/// Per-hop-count latency decomposition across all requesters (Fig 11):
/// rows of (hops, count, avg_total, avg_queue, avg_switch, avg_bus,
/// avg_device) in ns.
pub fn hop_breakdown(sys: &System) -> Vec<(u32, u64, f64, f64, f64, f64, f64)> {
    use std::collections::BTreeMap;
    let mut agg: BTreeMap<u32, (u64, u128, u128, u128, u128, u128)> = BTreeMap::new();
    for &r in &sys.requesters {
        let rq: &Requester = sys.engine.component(r).unwrap();
        for (&hops, h) in &rq.stats.by_hops {
            let e = agg.entry(hops).or_default();
            e.0 += h.count;
            e.1 += h.lat_sum;
            e.2 += h.queue_sum;
            e.3 += h.switch_sum;
            e.4 += h.bus_sum;
            e.5 += h.device_sum;
        }
    }
    agg.into_iter()
        .map(|(hops, (n, lat, q, sw, bus, dev))| {
            let d = |v: u128| v as f64 / n.max(1) as f64 / 1000.0;
            (hops, n, d(lat), d(q), d(sw), d(bus), d(dev))
        })
        .collect()
}

/// Sum of a metric over all memory endpoints.
pub fn memdev_sum(sys: &System, f: impl Fn(&MemDev) -> u64) -> u64 {
    sys.memories
        .iter()
        .map(|&m| f(sys.engine.component::<MemDev>(m).unwrap()))
        .sum()
}

/// Mean bus utility over the links adjacent to memory endpoints (the
/// measured buses in Fig 17).
pub fn endpoint_bus_utility(sys: &System) -> f64 {
    let net = &sys.engine.shared.net;
    let topo = &sys.engine.shared.topo;
    let mut vals = Vec::new();
    for &m in &sys.memories {
        for &(_, link) in &topo.adj[m] {
            vals.push(net.bus_utility(link));
        }
    }
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

pub fn endpoint_transmission_efficiency(sys: &System) -> f64 {
    let net = &sys.engine.shared.net;
    let topo = &sys.engine.shared.topo;
    let mut vals = Vec::new();
    for &m in &sys.memories {
        for &(_, link) in &topo.adj[m] {
            vals.push(net.transmission_efficiency(link));
        }
    }
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

/// Simple fixed-bucket latency histogram (ns buckets).
#[derive(Clone, Debug)]
pub struct Histogram {
    bucket_ns: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(bucket_ns: f64, buckets: usize) -> Histogram {
        Histogram {
            bucket_ns,
            counts: vec![0; buckets],
            total: 0,
        }
    }

    pub fn add(&mut self, lat: Ps) {
        let ns = to_ns(lat);
        let idx = ((ns / self.bucket_ns) as usize).min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (self.total as f64 * p).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (i as f64 + 0.5) * self.bucket_ns;
            }
        }
        (self.counts.len() as f64) * self.bucket_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new(10.0, 100);
        for i in 0..100u64 {
            h.add(i * 10_000); // 0..990 ns
        }
        let p50 = h.percentile(0.5);
        assert!((p50 - 495.0).abs() < 20.0, "p50 {p50}");
        let p99 = h.percentile(0.99);
        assert!(p99 > 900.0, "p99 {p99}");
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(1.0, 10);
        h.add(1_000_000_000); // 1ms -> last bucket
        assert_eq!(h.percentile(1.0), 9.5);
    }

    #[test]
    fn aggregate_over_small_system() {
        use crate::config::{build_system, SystemCfg};
        use crate::interconnect::TopologyKind;
        let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 2);
        cfg.requests_per_endpoint = 100;
        let mut sys = build_system(&cfg);
        sys.engine.run(u64::MAX);
        let a = aggregate(&sys);
        assert!(a.completed > 0);
        assert!(a.bandwidth_gbps() > 0.0);
        assert!(a.avg_latency_ns() > 50.0);
        let hb = hop_breakdown(&sys);
        assert!(!hb.is_empty());
        // total avg >= component sums can't exceed total
        for &(_, _, lat, q, sw, bus, dev) in &hb {
            assert!(lat + 1.0 >= q + sw + bus + dev * 0.0, "lat {lat} q {q} sw {sw} bus {bus}");
        }
    }
}
