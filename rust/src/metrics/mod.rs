//! Post-run metric extraction: aggregate bandwidth/latency over a built
//! `System`, latency histograms (bucketed and exact), and per-hop
//! breakdowns. Used by every experiment harness and the sweep engine's
//! p50/p95/p99 columns.

use crate::config::System;
use crate::devices::{MemDev, Requester};
use crate::engine::time::{to_ns, Ps};
use std::collections::BTreeMap;

/// Aggregate results over all requesters for the measurement epoch.
#[derive(Clone, Debug, Default)]
pub struct Aggregate {
    /// Total payload bytes completed during the epoch.
    pub bytes: u64,
    pub completed: u64,
    pub reads: u64,
    pub writes: u64,
    pub lat_sum_ns: f64,
    pub lat_max_ns: f64,
    /// Epoch span in ns.
    pub span_ns: f64,
}

impl Aggregate {
    /// Aggregate bandwidth in GB/s.
    pub fn bandwidth_gbps(&self) -> f64 {
        if self.span_ns <= 0.0 {
            0.0
        } else {
            self.bytes as f64 / self.span_ns
        }
    }

    pub fn avg_latency_ns(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.lat_sum_ns / self.completed as f64
        }
    }

    /// Throughput in million accesses per second of simulated time.
    pub fn throughput_maps(&self) -> f64 {
        if self.span_ns <= 0.0 {
            0.0
        } else {
            self.completed as f64 * 1000.0 / self.span_ns
        }
    }
}

/// Collect the aggregate over every requester in the system.
pub fn aggregate(sys: &System) -> Aggregate {
    let mut a = Aggregate {
        span_ns: to_ns(sys.engine.shared.epoch_span()),
        ..Aggregate::default()
    };
    for &r in &sys.requesters {
        let rq: &Requester = sys
            .engine
            .component(r)
            .expect("requester node holds a Requester");
        a.bytes += rq.stats.bytes;
        a.completed += rq.stats.completed;
        a.reads += rq.stats.reads;
        a.writes += rq.stats.writes;
        a.lat_sum_ns += rq.stats.lat_sum as f64 / 1000.0;
        a.lat_max_ns = a.lat_max_ns.max(to_ns(rq.stats.lat_max));
    }
    a
}

/// Per-hop-count latency decomposition across all requesters (Fig 11):
/// rows of (hops, count, avg_total, avg_queue, avg_switch, avg_bus,
/// avg_device) in ns.
pub fn hop_breakdown(sys: &System) -> Vec<(u32, u64, f64, f64, f64, f64, f64)> {
    use std::collections::BTreeMap;
    let mut agg: BTreeMap<u32, (u64, u128, u128, u128, u128, u128)> = BTreeMap::new();
    for &r in &sys.requesters {
        let rq: &Requester = sys.engine.component(r).unwrap();
        for (&hops, h) in &rq.stats.by_hops {
            let e = agg.entry(hops).or_default();
            e.0 += h.count;
            e.1 += h.lat_sum;
            e.2 += h.queue_sum;
            e.3 += h.switch_sum;
            e.4 += h.bus_sum;
            e.5 += h.device_sum;
        }
    }
    agg.into_iter()
        .map(|(hops, (n, lat, q, sw, bus, dev))| {
            let d = |v: u128| v as f64 / n.max(1) as f64 / 1000.0;
            (hops, n, d(lat), d(q), d(sw), d(bus), d(dev))
        })
        .collect()
}

/// Sum of a metric over all memory endpoints.
pub fn memdev_sum(sys: &System, f: impl Fn(&MemDev) -> u64) -> u64 {
    sys.memories
        .iter()
        .map(|&m| f(sys.engine.component::<MemDev>(m).unwrap()))
        .sum()
}

/// Mean bus utility over the links adjacent to memory endpoints (the
/// measured buses in Fig 17).
pub fn endpoint_bus_utility(sys: &System) -> f64 {
    let net = &sys.engine.shared.net;
    let topo = &sys.engine.shared.topo;
    let mut vals = Vec::new();
    for &m in &sys.memories {
        for &(_, link) in &topo.adj[m] {
            vals.push(net.bus_utility(link));
        }
    }
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

pub fn endpoint_transmission_efficiency(sys: &System) -> f64 {
    let net = &sys.engine.shared.net;
    let topo = &sys.engine.shared.topo;
    let mut vals = Vec::new();
    for &m in &sys.memories {
        for &(_, link) in &topo.adj[m] {
            vals.push(net.transmission_efficiency(link));
        }
    }
    if vals.is_empty() {
        0.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

/// Exact latency distribution: a value -> count map over the recorded
/// per-completion latencies (ps granularity, no bucketing).
///
/// Percentiles are **exact nearest-rank**: for `p` in `(0, 1]` the
/// percentile is the `ceil(p * N)`-th smallest recorded sample — i.e.
/// exactly what sorting the raw latency vector and indexing it would
/// return (the property-test oracle), but computed from the compact
/// histogram the requesters record.
#[derive(Clone, Debug, Default)]
pub struct LatencyDist {
    counts: BTreeMap<Ps, u64>,
    total: u64,
}

impl LatencyDist {
    pub fn new() -> LatencyDist {
        LatencyDist::default()
    }

    pub fn add(&mut self, lat: Ps) {
        *self.counts.entry(lat).or_insert(0) += 1;
        self.total += 1;
    }

    /// Fold another value->count map (a requester's `lat_hist`) in.
    pub fn merge_counts(&mut self, counts: &BTreeMap<Ps, u64>) {
        for (&lat, &c) in counts {
            *self.counts.entry(lat).or_insert(0) += c;
            self.total += c;
        }
    }

    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Exact nearest-rank percentile in ps; 0 when no samples were
    /// recorded. `p` is clamped into `(0, 1]` via the rank clamp.
    pub fn percentile(&self, p: f64) -> Ps {
        if self.total == 0 {
            return 0;
        }
        let rank = ((self.total as f64 * p).ceil() as u64).clamp(1, self.total);
        let mut acc = 0u64;
        for (&lat, &c) in &self.counts {
            acc += c;
            if acc >= rank {
                return lat;
            }
        }
        *self.counts.keys().next_back().expect("non-empty dist")
    }

    /// Exact nearest-rank percentile in ns (for reporting).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        to_ns(self.percentile(p))
    }
}

/// Merge every requester's recorded latency histogram into one exact
/// distribution for the whole system (the sweep percentile columns).
pub fn latency_dist(sys: &System) -> LatencyDist {
    let mut d = LatencyDist::new();
    for &r in &sys.requesters {
        let rq: &Requester = sys
            .engine
            .component(r)
            .expect("requester node holds a Requester");
        d.merge_counts(&rq.stats.lat_hist);
    }
    d
}

/// Simple fixed-bucket latency histogram (ns buckets).
#[derive(Clone, Debug)]
pub struct Histogram {
    bucket_ns: f64,
    counts: Vec<u64>,
    total: u64,
}

impl Histogram {
    pub fn new(bucket_ns: f64, buckets: usize) -> Histogram {
        Histogram {
            bucket_ns,
            counts: vec![0; buckets],
            total: 0,
        }
    }

    pub fn add(&mut self, lat: Ps) {
        let ns = to_ns(lat);
        let idx = ((ns / self.bucket_ns) as usize).min(self.counts.len() - 1);
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (self.total as f64 * p).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return (i as f64 + 0.5) * self.bucket_ns;
            }
        }
        (self.counts.len() as f64) * self.bucket_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new(10.0, 100);
        for i in 0..100u64 {
            h.add(i * 10_000); // 0..990 ns
        }
        let p50 = h.percentile(0.5);
        assert!((p50 - 495.0).abs() < 20.0, "p50 {p50}");
        let p99 = h.percentile(0.99);
        assert!(p99 > 900.0, "p99 {p99}");
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(1.0, 10);
        h.add(1_000_000_000); // 1ms -> last bucket
        assert_eq!(h.percentile(1.0), 9.5);
    }

    /// Oracle for the exact percentile: sort the raw samples and take the
    /// nearest-rank index directly.
    fn oracle(samples: &[Ps], p: f64) -> Ps {
        if samples.is_empty() {
            return 0;
        }
        let mut sorted = samples.to_vec();
        sorted.sort_unstable();
        let rank = ((sorted.len() as f64 * p).ceil() as usize).clamp(1, sorted.len());
        sorted[rank - 1]
    }

    #[test]
    fn exact_percentiles_match_sorted_vector_oracle() {
        use crate::util::prop::forall;
        forall(
            "LatencyDist percentiles == sorted-vector oracle",
            300,
            |rng| {
                // Mix distribution shapes: heavy ties, all-equal, wide
                // spread, and tiny sample counts (0, 1, 2...).
                let n = rng.gen_range(400) as usize;
                let mode = rng.gen_range(4);
                (0..n)
                    .map(|_| match mode {
                        0 => rng.gen_range(50),
                        1 => 777,
                        2 => rng.next_u64() >> 20,
                        _ => 1 + rng.gen_range(3),
                    })
                    .collect::<Vec<Ps>>()
            },
            |samples| {
                let mut d = LatencyDist::new();
                for &s in samples {
                    d.add(s);
                }
                if d.total() != samples.len() as u64 {
                    return Err(format!("total {} != {}", d.total(), samples.len()));
                }
                for &p in &[0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
                    let got = d.percentile(p);
                    let want = oracle(samples, p);
                    if got != want {
                        return Err(format!("p{p}: got {got} want {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn percentile_edge_cases() {
        // Empty distribution.
        let d = LatencyDist::new();
        assert!(d.is_empty());
        for p in [0.5, 0.95, 0.99, 1.0] {
            assert_eq!(d.percentile(p), 0);
        }
        // Single sample: every percentile is that sample.
        let mut d = LatencyDist::new();
        d.add(123_456);
        for p in [0.001, 0.5, 0.99, 1.0] {
            assert_eq!(d.percentile(p), 123_456);
        }
        // All-equal samples.
        let mut d = LatencyDist::new();
        for _ in 0..1000 {
            d.add(42);
        }
        for p in [0.01, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(d.percentile(p), 42);
        }
        // Hand-computed nearest rank on [10, 20, 30, 40]:
        // p50 -> rank 2 -> 20; p95/p99/p100 -> rank 4 -> 40; p25 -> 10.
        let mut d = LatencyDist::new();
        for v in [40, 10, 30, 20] {
            d.add(v);
        }
        assert_eq!(d.percentile(0.25), 10);
        assert_eq!(d.percentile(0.5), 20);
        assert_eq!(d.percentile(0.95), 40);
        assert_eq!(d.percentile_ns(0.5), 0.02);
    }

    #[test]
    fn merge_counts_equals_adding_individually() {
        let mut a = LatencyDist::new();
        let mut m = BTreeMap::new();
        for v in [5u64, 5, 9, 1] {
            a.add(v);
            *m.entry(v).or_insert(0) += 1;
        }
        let mut b = LatencyDist::new();
        b.merge_counts(&m);
        for p in [0.25, 0.5, 1.0] {
            assert_eq!(a.percentile(p), b.percentile(p));
        }
        assert_eq!(a.total(), b.total());
    }

    #[test]
    fn aggregate_over_small_system() {
        use crate::config::{build_system, SystemCfg};
        use crate::interconnect::TopologyKind;
        let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 2);
        cfg.requests_per_endpoint = 100;
        let mut sys = build_system(&cfg);
        sys.engine.run(u64::MAX);
        let a = aggregate(&sys);
        assert!(a.completed > 0);
        assert!(a.bandwidth_gbps() > 0.0);
        assert!(a.avg_latency_ns() > 50.0);
        // The exact latency distribution covers every measured completion
        // and its extremes are consistent with the aggregate.
        let d = latency_dist(&sys);
        assert_eq!(d.total(), a.completed);
        assert_eq!(to_ns(d.percentile(1.0)), a.lat_max_ns);
        assert!(d.percentile_ns(0.5) <= d.percentile_ns(0.95));
        assert!(d.percentile_ns(0.95) <= d.percentile_ns(0.99));
        let hb = hop_breakdown(&sys);
        assert!(!hb.is_empty());
        // total avg >= component sums can't exceed total
        for &(_, _, lat, q, sw, bus, dev) in &hb {
            assert!(lat + 1.0 >= q + sw + bus + dev * 0.0, "lat {lat} q {q} sw {sw} bus {bus}");
        }
    }
}
