//! CXL memory endpoint (type-3 device by default): device controller +
//! pluggable media backend + optional device coherency agent (DCOH) built
//! around the inclusive snoop filter.
//!
//! The DCOH is decoupled from the memory device per the paper's §III-A
//! design: the `SnoopFilter` is its own module with its own policy knobs;
//! this component wires it into the request path (allocate on coherent
//! access, BISnp owners on conflict/eviction, block the conflicting
//! request until all BIRsp arrive, write dirty flushes back to media).

use super::snoop_filter::{SnoopFilter, Victim, VictimPolicy};
use crate::devices::cache::Cache;
use crate::engine::time::{ns, Ps};
use crate::engine::{Component, Payload, Shared};
use crate::proto::{NodeId, Opcode, Packet};
use std::any::Any;
use std::collections::VecDeque;

/// Media timing model under the controller. `Send` because the memory
/// endpoint component migrates onto its event domain's worker thread in
/// partitioned runs (`engine::parallel`).
pub trait MemBackend: Send {
    /// Issue an access beginning no earlier than `at`; returns completion
    /// time. Implementations track their own internal resource state
    /// (banks, channels...).
    fn access(&mut self, addr: u64, is_write: bool, at: Ps) -> Ps;
    fn name(&self) -> &'static str;
    /// Serialize internal resource state (banks, channels...). Stateless
    /// backends keep the empty default.
    fn snapshot(&self, _w: &mut crate::util::snap::SnapWriter) {}
    /// Restore the state written by `snapshot`.
    fn restore(&mut self, _r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        Ok(())
    }
}

/// Fixed-latency, fully pipelined media (infinite internal parallelism).
pub struct FixedBackend {
    pub latency: Ps,
}

impl MemBackend for FixedBackend {
    fn access(&mut self, _addr: u64, _is_write: bool, at: Ps) -> Ps {
        at + self.latency
    }
    fn name(&self) -> &'static str {
        "fixed"
    }
}

#[derive(Clone, Debug)]
pub struct MemDevCfg {
    pub id: NodeId,
    /// Device controller process time (Table III: 40 ns).
    pub ctrl_time: Ps,
    /// PCIe port delay at this endpoint (Table III: 25 ns).
    pub port_delay: Ps,
    /// DCOH: snoop-filter capacity and victim policy (None = HDM-H, no
    /// device-managed coherence).
    pub snoop_filter: Option<(usize, VictimPolicy)>,
}

impl MemDevCfg {
    pub fn new(id: NodeId) -> MemDevCfg {
        MemDevCfg {
            id,
            ctrl_time: ns(40.0),
            port_delay: ns(25.0),
            snoop_filter: None,
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct MemStats {
    pub received: u64,
    pub reads: u64,
    pub writes: u64,
    pub bisnp_sent: u64,
    pub birsp_received: u64,
    pub dirty_flushes: u64,
    /// Requests that had to wait for a back-invalidation to finish, and
    /// their total wait (Fig 15's "average waiting time for invalidation").
    pub inv_waits: u64,
    pub inv_wait_sum: u128,
}

struct EvictInFlight {
    victim: Victim,
    birsp_remaining: usize,
    started: Ps,
}

pub struct MemDev {
    cfg: MemDevCfg,
    backend: Box<dyn MemBackend>,
    sf: Option<SnoopFilter>,
    evict: Option<EvictInFlight>,
    /// Coherent requests blocked on the in-flight eviction.
    waitq: VecDeque<(Packet, Ps)>,
    pub stats: MemStats,
}

impl MemDev {
    pub fn new(cfg: MemDevCfg, backend: Box<dyn MemBackend>) -> MemDev {
        let sf = cfg
            .snoop_filter
            .map(|(cap, policy)| SnoopFilter::new(cap, policy));
        MemDev {
            cfg,
            backend,
            sf,
            evict: None,
            waitq: VecDeque::new(),
            stats: MemStats::default(),
        }
    }

    pub fn snoop_filter(&self) -> Option<&SnoopFilter> {
        self.sf.as_ref()
    }

    /// Serve the access from the media and schedule the response.
    fn backend_access(&mut self, pkt: Packet, ctx: &mut Shared) {
        let is_write = pkt.op == Opcode::MemWr;
        if ctx.collecting {
            if is_write {
                self.stats.writes += 1;
            } else {
                self.stats.reads += 1;
            }
        }
        let start = ctx.now + self.cfg.ctrl_time;
        let ready = self.backend.access(pkt.addr, is_write, start);
        if pkt.op == Opcode::MemWr && is_posted(&pkt) {
            return; // posted write-back: no completion message
        }
        let mut rsp = pkt.response(false);
        let delay = (ready - ctx.now) + self.cfg.port_delay;
        rsp.breakdown.device_ps += delay;
        ctx.forward(rsp, delay);
    }

    /// Admit a coherent request through the DCOH.
    fn sf_admit(&mut self, pkt: Packet, ctx: &mut Shared) {
        let line = Cache::line_of(pkt.addr);
        let needs = self
            .sf
            .as_ref()
            .map(|sf| sf.needs_eviction(line))
            .unwrap_or(false);
        if !needs {
            if let Some(sf) = self.sf.as_mut() {
                sf.record(line, pkt.src);
            }
            self.backend_access(pkt, ctx);
        } else {
            self.waitq.push_back((pkt, ctx.now));
            if self.evict.is_none() {
                self.start_eviction(ctx);
            }
        }
    }

    fn start_eviction(&mut self, ctx: &mut Shared) {
        let Some(sf) = self.sf.as_ref() else { return };
        let Some(victim) = sf.select_victim() else {
            return;
        };
        let len = victim.addrs.len() as u8;
        let base = victim.addrs[0];
        // Read the owner list in place (the seed cloned it here), then
        // move the victim into the in-flight record.
        debug_assert!(!victim.owners.is_empty());
        let n_owners = victim.owners.len();
        for &owner in &victim.owners {
            let id = ctx.txn_id();
            let snp = Packet::request(id, Opcode::BISnp { len }, self.cfg.id, owner, base, ctx.now);
            if ctx.collecting {
                self.stats.bisnp_sent += 1;
            }
            ctx.forward(snp, self.cfg.ctrl_time.min(ns(4.0)));
        }
        self.evict = Some(EvictInFlight {
            victim,
            birsp_remaining: n_owners,
            started: ctx.now,
        });
    }

    fn on_birsp(&mut self, pkt: Packet, ctx: &mut Shared) {
        if ctx.collecting {
            self.stats.birsp_received += 1;
        }
        let dirty = matches!(pkt.op, Opcode::BIRsp { dirty: true });
        if dirty {
            // Flush the written-back lines to media.
            let start = ctx.now + self.cfg.ctrl_time;
            self.backend.access(pkt.addr, true, start);
            if ctx.collecting {
                self.stats.dirty_flushes += 1;
            }
        }
        let done = {
            let Some(ev) = self.evict.as_mut() else { return };
            ev.birsp_remaining = ev.birsp_remaining.saturating_sub(1);
            ev.birsp_remaining == 0
        };
        if done {
            let ev = self.evict.take().unwrap();
            if let Some(sf) = self.sf.as_mut() {
                sf.clear(&ev.victim);
            }
            let _ = ev.started;
            self.drain_waitq(ctx);
        }
    }

    /// Retry blocked requests after an eviction completes.
    fn drain_waitq(&mut self, ctx: &mut Shared) {
        while let Some((pkt, enq)) = self.waitq.pop_front() {
            let line = Cache::line_of(pkt.addr);
            let needs = self
                .sf
                .as_ref()
                .map(|sf| sf.needs_eviction(line))
                .unwrap_or(false);
            if needs {
                // Still no room: start the next eviction, keep waiting.
                self.waitq.push_front((pkt, enq));
                if self.evict.is_none() {
                    self.start_eviction(ctx);
                }
                return;
            }
            if ctx.collecting {
                self.stats.inv_waits += 1;
                self.stats.inv_wait_sum += (ctx.now - enq) as u128;
            }
            if let Some(sf) = self.sf.as_mut() {
                sf.record(line, pkt.src);
            }
            self.backend_access(pkt, ctx);
        }
    }
}

/// Posted writes (background write-backs) carry no completion. Encoded via
/// the packet's `coherent == false && op == MemWr && posted bit in id`?
/// No — explicit: the requester marks write-backs by clearing `coherent`
/// and setting `payload_bytes` normally; the convention here is that
/// non-coherent MemWr from a *caching* requester is posted. To keep the
/// protocol unambiguous we use the packet flag below.
fn is_posted(pkt: &Packet) -> bool {
    pkt.posted
}

impl Component for MemDev {
    fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
        match payload {
            Payload::Packet(mut pkt) => {
                // Ingress port delay is charged by delaying the handling
                // via device_ps accounting (the port is not a contention
                // point in this model; its latency is).
                pkt.breakdown.device_ps += self.cfg.port_delay;
                match pkt.op {
                    Opcode::MemRd | Opcode::MemWr => {
                        if ctx.collecting {
                            self.stats.received += 1;
                        }
                        if pkt.coherent && self.sf.is_some() {
                            self.sf_admit(*pkt, ctx);
                        } else {
                            self.backend_access(*pkt, ctx);
                        }
                    }
                    Opcode::BIRsp { .. } => self.on_birsp(*pkt, ctx),
                    _ => {}
                }
            }
            _ => {}
        }
    }

    fn snapshot(&self, w: &mut crate::util::snap::SnapWriter) {
        w.u64(self.stats.received);
        w.u64(self.stats.reads);
        w.u64(self.stats.writes);
        w.u64(self.stats.bisnp_sent);
        w.u64(self.stats.birsp_received);
        w.u64(self.stats.dirty_flushes);
        w.u64(self.stats.inv_waits);
        w.u128(self.stats.inv_wait_sum);
        match &self.evict {
            None => w.u8(0),
            Some(ev) => {
                w.u8(1);
                w.usize(ev.victim.addrs.len());
                for &a in &ev.victim.addrs {
                    w.u64(a);
                }
                w.usize(ev.victim.owners.len());
                for &o in &ev.victim.owners {
                    w.usize(o);
                }
                w.usize(ev.birsp_remaining);
                w.u64(ev.started);
            }
        }
        w.usize(self.waitq.len());
        for (pkt, enq) in &self.waitq {
            crate::engine::snapshot::write_packet(w, pkt);
            w.u64(*enq);
        }
        // Presence tag: lets a prefix-fork restore (donor normalized to
        // sf = None, fork built with a fresh empty filter) leave the
        // fork's filter untouched instead of failing on the mismatch.
        match &self.sf {
            None => w.u8(0),
            Some(sf) => {
                w.u8(1);
                sf.snapshot(w);
            }
        }
        self.backend.snapshot(w);
    }

    fn restore(&mut self, r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        self.stats.received = r.u64()?;
        self.stats.reads = r.u64()?;
        self.stats.writes = r.u64()?;
        self.stats.bisnp_sent = r.u64()?;
        self.stats.birsp_received = r.u64()?;
        self.stats.dirty_flushes = r.u64()?;
        self.stats.inv_waits = r.u64()?;
        self.stats.inv_wait_sum = r.u128()?;
        self.evict = match r.u8()? {
            0 => None,
            1 => {
                let mut addrs = Vec::new();
                for _ in 0..r.usize()? {
                    addrs.push(r.u64()?);
                }
                let mut owners = Vec::new();
                for _ in 0..r.usize()? {
                    owners.push(r.usize()?);
                }
                Some(EvictInFlight {
                    victim: Victim { addrs, owners },
                    birsp_remaining: r.usize()?,
                    started: r.u64()?,
                })
            }
            t => return Err(format!("invalid eviction tag {t}")),
        };
        self.waitq.clear();
        for _ in 0..r.usize()? {
            let pkt = crate::engine::snapshot::read_packet(r)?;
            let enq = r.u64()?;
            self.waitq.push_back((pkt, enq));
        }
        match r.u8()? {
            0 => {} // donor ran without a snoop filter; keep ours fresh
            1 => match self.sf.as_mut() {
                Some(sf) => sf.restore(r)?,
                None => {
                    return Err(
                        "snapshot carries snoop-filter state but this device has none".to_string()
                    )
                }
            },
            t => return Err(format!("invalid snoop-filter tag {t}")),
        }
        self.backend.restore(r)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}
