//! Requester-side coherent cache (the paper's "cache coherence management
//! unit"): records fetched cachelines and their metadata (source endpoint,
//! dirty state), serves BISnp invalidations from device coherency agents,
//! and reports dirty lines for write-back on flush.
//!
//! Fully associative with pluggable replacement (default LRU), because the
//! snoop-filter experiments size the cache relative to the workload
//! footprint and hot-set; associativity conflicts would blur the effect
//! under study.

use crate::proto::{NodeId, CACHELINE};
use std::collections::{BTreeMap, HashMap};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LineMeta {
    pub dirty: bool,
    /// Memory endpoint this line was fetched from.
    pub src: NodeId,
}

#[derive(Clone, Debug)]
struct Entry {
    meta: LineMeta,
    /// LRU stamp (monotone use counter).
    stamp: u64,
}

/// Result of a cache access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Hit,
    Miss,
}

/// A line evicted to make room (dirty lines must be written back).
#[derive(Clone, Copy, Debug)]
pub struct Evicted {
    pub addr: u64,
    pub meta: LineMeta,
}

#[derive(Debug)]
pub struct Cache {
    capacity: usize,
    // det-ok: keyed get/insert/remove only — eviction order comes from the
    // `lru` BTreeMap index, so hash order never picks a victim.
    lines: HashMap<u64, Entry>,
    /// stamp -> addr index for O(log n) LRU eviction.
    lru: BTreeMap<u64, u64>,
    next_stamp: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(capacity_lines: usize) -> Cache {
        Cache {
            capacity: capacity_lines,
            lines: HashMap::with_capacity(capacity_lines.min(1 << 20)), // det-ok: keyed lookup only
            lru: BTreeMap::new(),
            next_stamp: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn line_of(addr: u64) -> u64 {
        addr & !(CACHELINE - 1)
    }

    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn touch(&mut self, line: u64) {
        let e = self.lines.get_mut(&line).expect("touch of absent line");
        self.lru.remove(&e.stamp);
        e.stamp = self.next_stamp;
        self.lru.insert(e.stamp, line);
        self.next_stamp += 1;
    }

    /// Look up `addr`; on hit, refresh LRU and optionally mark dirty.
    pub fn access(&mut self, addr: u64, is_write: bool) -> Access {
        let line = Self::line_of(addr);
        if self.lines.contains_key(&line) {
            self.touch(line);
            if is_write {
                self.lines.get_mut(&line).unwrap().meta.dirty = true;
            }
            self.hits += 1;
            Access::Hit
        } else {
            self.misses += 1;
            Access::Miss
        }
    }

    /// Insert a fetched line; returns the victim if the cache was full.
    pub fn insert(&mut self, addr: u64, meta: LineMeta) -> Option<Evicted> {
        let line = Self::line_of(addr);
        if self.capacity == 0 {
            return None;
        }
        if self.lines.contains_key(&line) {
            self.touch(line);
            if meta.dirty {
                self.lines.get_mut(&line).unwrap().meta.dirty = true;
            }
            return None;
        }
        let evicted = if self.lines.len() >= self.capacity {
            let (&stamp, &victim) = self.lru.iter().next().expect("lru/lines desync");
            self.lru.remove(&stamp);
            let e = self.lines.remove(&victim).unwrap();
            Some(Evicted {
                addr: victim,
                meta: e.meta,
            })
        } else {
            None
        };
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        self.lru.insert(stamp, line);
        self.lines.insert(line, Entry { meta, stamp });
        evicted
    }

    /// Invalidate one line (BISnp); returns its metadata if present.
    pub fn invalidate(&mut self, addr: u64) -> Option<LineMeta> {
        let line = Self::line_of(addr);
        let e = self.lines.remove(&line)?;
        self.lru.remove(&e.stamp);
        Some(e.meta)
    }

    /// Invalidate `len` contiguous lines starting at `addr` (InvBlk).
    /// Returns (lines_invalidated, any_dirty).
    pub fn invalidate_block(&mut self, addr: u64, len: u8) -> (u32, bool) {
        let base = Self::line_of(addr);
        let mut n = 0;
        let mut dirty = false;
        for i in 0..len as u64 {
            if let Some(m) = self.invalidate(base + i * CACHELINE) {
                n += 1;
                dirty |= m.dirty;
            }
        }
        (n, dirty)
    }

    /// Serialize contents in LRU-stamp order (the `lru` BTreeMap is the
    /// deterministic index; the hash map is only consulted by key), plus
    /// the stamp counter and hit/miss counters.
    pub fn snapshot(&self, w: &mut crate::util::snap::SnapWriter) {
        w.u64(self.next_stamp);
        w.u64(self.hits);
        w.u64(self.misses);
        w.usize(self.lru.len());
        for (&stamp, &line) in &self.lru {
            let e = &self.lines[&line];
            w.u64(stamp);
            w.u64(line);
            w.bool(e.meta.dirty);
            w.usize(e.meta.src);
        }
    }

    /// Rebuild the state written by [`Cache::snapshot`] onto a cache of
    /// the same capacity.
    pub fn restore(&mut self, r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        self.next_stamp = r.u64()?;
        self.hits = r.u64()?;
        self.misses = r.u64()?;
        self.lines.clear();
        self.lru.clear();
        for _ in 0..r.usize()? {
            let stamp = r.u64()?;
            let line = r.u64()?;
            let meta = LineMeta {
                dirty: r.bool()?,
                src: r.usize()?,
            };
            self.lru.insert(stamp, line);
            self.lines.insert(line, Entry { meta, stamp });
        }
        if self.lines.len() != self.lru.len() {
            return Err("cache snapshot has duplicate lines or stamps".to_string());
        }
        Ok(())
    }

    pub fn contains(&self, addr: u64) -> bool {
        self.lines.contains_key(&Self::line_of(addr))
    }

    pub fn meta(&self, addr: u64) -> Option<LineMeta> {
        self.lines.get(&Self::line_of(addr)).map(|e| e.meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(src: NodeId) -> LineMeta {
        LineMeta { dirty: false, src }
    }

    #[test]
    fn hit_miss_and_line_granularity() {
        let mut c = Cache::new(4);
        assert_eq!(c.access(0x100, false), Access::Miss);
        c.insert(0x100, meta(9));
        // same line, different byte
        assert_eq!(c.access(0x13F, false), Access::Hit);
        assert_eq!(c.access(0x140, false), Access::Miss);
        assert_eq!((c.hits, c.misses), (1, 2));
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = Cache::new(2);
        c.insert(0x000, meta(1));
        c.insert(0x040, meta(1));
        c.access(0x000, false); // refresh 0x000
        let ev = c.insert(0x080, meta(1)).expect("must evict");
        assert_eq!(ev.addr, 0x040);
        assert!(c.contains(0x000) && c.contains(0x080));
    }

    #[test]
    fn write_marks_dirty_and_eviction_reports_it() {
        let mut c = Cache::new(1);
        c.insert(0x000, meta(2));
        c.access(0x000, true);
        let ev = c.insert(0x040, meta(2)).unwrap();
        assert!(ev.meta.dirty);
        assert_eq!(ev.meta.src, 2);
    }

    #[test]
    fn invalidate_removes_and_reports() {
        let mut c = Cache::new(4);
        c.insert(0x040, meta(3));
        c.access(0x040, true);
        let m = c.invalidate(0x051).expect("same line");
        assert!(m.dirty);
        assert!(!c.contains(0x040));
        assert!(c.invalidate(0x040).is_none());
    }

    #[test]
    fn invalidate_block_contiguous_run() {
        let mut c = Cache::new(8);
        for i in 0..4u64 {
            c.insert(i * 64, meta(1));
        }
        c.access(64, true);
        let (n, dirty) = c.invalidate_block(0, 3);
        assert_eq!(n, 3);
        assert!(dirty);
        assert!(c.contains(192));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn zero_capacity_cache_never_stores() {
        let mut c = Cache::new(0);
        assert!(c.insert(0, meta(0)).is_none());
        assert_eq!(c.access(0, false), Access::Miss);
        assert!(c.is_empty());
    }

    #[test]
    fn reinsert_refreshes_instead_of_duplicating() {
        let mut c = Cache::new(2);
        c.insert(0x000, meta(1));
        c.insert(
            0x000,
            LineMeta {
                dirty: true,
                src: 1,
            },
        );
        assert_eq!(c.len(), 1);
        assert!(c.meta(0x000).unwrap().dirty);
    }

    /// Property: lines+lru stay consistent under a random op mix.
    #[test]
    fn prop_lru_index_consistent() {
        use crate::util::prop::forall;
        use crate::util::rng::Pcg32;
        forall(
            "cache lru consistency",
            50,
            |rng: &mut Pcg32| {
                let ops: Vec<(u8, u64)> = (0..200)
                    .map(|_| (rng.gen_range(3) as u8, rng.gen_range(32) * 64))
                    .collect();
                ops
            },
            |ops| {
                let mut c = Cache::new(8);
                for &(op, addr) in ops {
                    match op {
                        0 => {
                            c.access(addr, false);
                        }
                        1 => {
                            c.insert(addr, LineMeta { dirty: false, src: 0 });
                        }
                        _ => {
                            c.invalidate(addr);
                        }
                    }
                    if c.lines.len() != c.lru.len() {
                        return Err(format!(
                            "desync: {} lines vs {} lru",
                            c.lines.len(),
                            c.lru.len()
                        ));
                    }
                    if c.lines.len() > 8 {
                        return Err("over capacity".to_string());
                    }
                }
                Ok(())
            },
        );
    }
}
