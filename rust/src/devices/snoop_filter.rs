//! Device-side inclusive snoop filter — the example DCOH (device coherency
//! agent) for HDM-DB device-managed coherence (paper §III-D).
//!
//! The filter is a fully-associative buffer recording, for every cacheline
//! of its endpoint that is cached elsewhere, the coherence metadata (owner
//! list, insertion order, recency, insertion frequency). When a new
//! coherent request conflicts with the capacity, a victim entry is chosen
//! by the configured policy and back-invalidate snoops (BISnp) are sent to
//! the owners; the entry is cleared once every BIRsp is collected. Victim
//! selection is modularized so researchers can evaluate policies — exactly
//! the paper's Fig 14/15 study.

use crate::proto::NodeId;
use crate::util::flatmap::FlatCounter;
use crate::util::inline::InlineVec;
use std::collections::{BTreeMap, BTreeSet};

/// Victim selection policies (paper §V-B, plus the block-length-prioritized
/// policy of §V-C used to exercise InvBlk).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VictimPolicy {
    /// First-In First-Out: evict the oldest inserted entry.
    Fifo,
    /// Least Recently Used (touches refresh recency).
    Lru,
    /// Least Frequently Inserted: global per-address insertion counters;
    /// evict the entry whose address was inserted the fewest times.
    Lfi,
    /// Last-In First-Out: evict the newest inserted entry.
    Lifo,
    /// Most Recently Used.
    Mru,
    /// Block-length-prioritized: evict the longest run of contiguous-line
    /// entries (up to `max_len`), LIFO among ties — pairs with InvBlk.
    BlockLen { max_len: u8 },
}

impl VictimPolicy {
    pub const BASIC: [VictimPolicy; 5] = [
        VictimPolicy::Fifo,
        VictimPolicy::Lru,
        VictimPolicy::Lfi,
        VictimPolicy::Lifo,
        VictimPolicy::Mru,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            VictimPolicy::Fifo => "FIFO",
            VictimPolicy::Lru => "LRU",
            VictimPolicy::Lfi => "LFI",
            VictimPolicy::Lifo => "LIFO",
            VictimPolicy::Mru => "MRU",
            VictimPolicy::BlockLen { .. } => "BlockLen",
        }
    }
}

/// Intrusive-list null.
const NIL: u32 = u32::MAX;

/// One slab slot: coherence metadata plus the intrusive links that thread
/// the insertion-order and recency orderings through the slab. Owner
/// lists stay inline (no heap) for up to 4 sharers.
#[derive(Clone, Debug, Default)]
struct Slot {
    addr: u64,
    owners: InlineVec<NodeId, 4>,
    inserted_seq: u64,
    /// Snapshot of the global insertion counter for this address.
    insert_count: u64,
    prev_ins: u32,
    next_ins: u32,
    prev_rec: u32,
    next_rec: u32,
    /// LFI count-bucket list links (only maintained under `Lfi`).
    prev_cnt: u32,
    next_cnt: u32,
}

/// A victim selected for eviction: the lines to clear and who owns them.
#[derive(Clone, Debug)]
pub struct Victim {
    /// Contiguous line addresses to invalidate (len 1 unless BlockLen).
    pub addrs: Vec<u64>,
    /// Union of owners across the victim lines.
    pub owners: Vec<NodeId>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SfStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries cleared by evictions (>= evictions with InvBlk).
    pub entries_cleared: u64,
}

/// Inclusive device-side snoop filter.
///
/// Bookkeeping lives on a slab of [`Slot`]s: the insertion-order
/// (FIFO/LIFO) and recency (LRU/MRU) orderings are intrusive doubly
/// linked lists threaded through the slots — O(1) link/unlink/touch with
/// zero allocation — replacing the seed's three `BTreeMap` indices plus
/// `BTreeSet`/`HashMap` for LFI. One ordered `addr -> slot` index remains
/// (BlockLen needs in-address-order traversal); LFI's global counters sit
/// in a flat open-addressing table.
pub struct SnoopFilter {
    capacity: usize,
    policy: VictimPolicy,
    slots: Vec<Slot>,
    free: Vec<u32>,
    /// addr -> slot. The only ordered structure left; also the canonical
    /// set of live entries.
    index: BTreeMap<u64, u32>,
    /// Insertion-order list: head = oldest inserted, tail = newest.
    ins_head: u32,
    ins_tail: u32,
    /// Recency list: head = least recently touched, tail = most recent.
    rec_head: u32,
    rec_tail: u32,
    /// LFI's global counter table: addr -> times inserted (kept across
    /// evictions — that is the point of the policy).
    counts: FlatCounter,
    /// LFI victim index: insert_count -> (head, tail) of an intrusive
    /// list of live slots holding that count, threaded through
    /// `prev_cnt`/`next_cnt` in insertion (= seq) order. A live slot's
    /// count never changes (it is a snapshot), so membership is static
    /// for the slot's lifetime and the victim — min count, newest seq
    /// among ties — is always the first bucket's tail: amortized O(1)
    /// instead of the former O(capacity) scan per eviction (ROADMAP
    /// item). Only maintained when the policy is `Lfi`.
    lfi_buckets: BTreeMap<u64, (u32, u32)>,
    /// BlockLen run-tracking index (ROADMAP item): maximal runs of
    /// contiguous-line entries, `start addr -> length in lines`. Only
    /// maintained under `BlockLen`.
    blk_runs: BTreeMap<u64, u64>,
    /// Each run's best capped segment, `run start -> (seg_len, seg_max_seq,
    /// seg_start)` — the victim key the linear scan would compute for that
    /// run.
    blk_cand: BTreeMap<u64, (u64, u64, u64)>,
    /// All runs' candidates ordered by victim key; the global victim is
    /// the last element, so `select_victim` is O(log n) instead of the
    /// former O(capacity) index walk per eviction. Updates touch only the
    /// run(s) adjacent to the inserted/cleared line.
    blk_best: BTreeSet<(u64, u64, u64)>,
    seq: u64,
    pub stats: SfStats,
}

impl SnoopFilter {
    pub fn new(capacity: usize, policy: VictimPolicy) -> SnoopFilter {
        SnoopFilter {
            capacity,
            policy,
            slots: Vec::new(),
            free: Vec::new(),
            index: BTreeMap::new(),
            ins_head: NIL,
            ins_tail: NIL,
            rec_head: NIL,
            rec_tail: NIL,
            counts: FlatCounter::new(),
            lfi_buckets: BTreeMap::new(),
            blk_runs: BTreeMap::new(),
            blk_cand: BTreeMap::new(),
            blk_best: BTreeSet::new(),
            seq: 0,
            stats: SfStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn contains(&self, line: u64) -> bool {
        self.index.contains_key(&line)
    }

    pub fn owners(&self, line: u64) -> Option<&[NodeId]> {
        self.index
            .get(&line)
            .map(|&si| self.slots[si as usize].owners.as_slice())
    }

    // ---- intrusive list plumbing

    fn alloc(&mut self) -> u32 {
        if let Some(si) = self.free.pop() {
            si
        } else {
            let si = self.slots.len() as u32;
            self.slots.push(Slot::default());
            si
        }
    }

    fn ins_push_tail(&mut self, si: u32) {
        self.slots[si as usize].prev_ins = self.ins_tail;
        self.slots[si as usize].next_ins = NIL;
        if self.ins_tail != NIL {
            self.slots[self.ins_tail as usize].next_ins = si;
        } else {
            self.ins_head = si;
        }
        self.ins_tail = si;
    }

    fn ins_unlink(&mut self, si: u32) {
        let (p, n) = {
            let s = &self.slots[si as usize];
            (s.prev_ins, s.next_ins)
        };
        if p != NIL {
            self.slots[p as usize].next_ins = n;
        } else {
            self.ins_head = n;
        }
        if n != NIL {
            self.slots[n as usize].prev_ins = p;
        } else {
            self.ins_tail = p;
        }
    }

    fn rec_push_tail(&mut self, si: u32) {
        self.slots[si as usize].prev_rec = self.rec_tail;
        self.slots[si as usize].next_rec = NIL;
        if self.rec_tail != NIL {
            self.slots[self.rec_tail as usize].next_rec = si;
        } else {
            self.rec_head = si;
        }
        self.rec_tail = si;
    }

    fn rec_unlink(&mut self, si: u32) {
        let (p, n) = {
            let s = &self.slots[si as usize];
            (s.prev_rec, s.next_rec)
        };
        if p != NIL {
            self.slots[p as usize].next_rec = n;
        } else {
            self.rec_head = n;
        }
        if n != NIL {
            self.slots[n as usize].prev_rec = p;
        } else {
            self.rec_tail = p;
        }
    }

    /// Append to the tail of the count bucket (inserts arrive in
    /// increasing seq order, so the tail is always the newest).
    fn cnt_push_tail(&mut self, si: u32, count: u64) {
        let entry = self.lfi_buckets.entry(count).or_insert((NIL, NIL));
        let tail = entry.1;
        {
            let s = &mut self.slots[si as usize];
            s.prev_cnt = tail;
            s.next_cnt = NIL;
        }
        if tail != NIL {
            self.slots[tail as usize].next_cnt = si;
        } else {
            entry.0 = si;
        }
        entry.1 = si;
    }

    fn cnt_unlink(&mut self, si: u32) {
        let (count, p, n) = {
            let s = &self.slots[si as usize];
            (s.insert_count, s.prev_cnt, s.next_cnt)
        };
        if p != NIL {
            self.slots[p as usize].next_cnt = n;
        }
        if n != NIL {
            self.slots[n as usize].prev_cnt = p;
        }
        let empty = {
            let entry = self
                .lfi_buckets
                .get_mut(&count)
                .expect("live LFI slot has a count bucket");
            if entry.0 == si {
                entry.0 = n;
            }
            if entry.1 == si {
                entry.1 = p;
            }
            entry.0 == NIL
        };
        if empty {
            self.lfi_buckets.remove(&count);
        }
    }

    // ---- BlockLen run-tracking index

    fn blk_active(&self) -> bool {
        matches!(self.policy, VictimPolicy::BlockLen { .. })
    }

    fn blk_max_len(&self) -> u64 {
        match self.policy {
            VictimPolicy::BlockLen { max_len } => max_len.max(1) as u64,
            _ => 1,
        }
    }

    /// Best capped segment of the run `[start, start + len lines)`. The
    /// linear scan segments every maximal run from its start in
    /// `max_len`-line chunks; the victim key is `(segment length, max
    /// inserted_seq in the segment)` — reproduced here per run so the
    /// index stays equivalent to the scan.
    fn blk_run_candidate(&self, start: u64, len: u64) -> (u64, u64, u64) {
        let max_len = self.blk_max_len();
        let mut best: Option<(u64, u64, u64)> = None;
        let mut off = 0;
        while off < len {
            let seg_len = max_len.min(len - off);
            let seg_start = start + off * crate::proto::CACHELINE;
            let mut seg_seq = 0u64;
            for i in 0..seg_len {
                let addr = seg_start + i * crate::proto::CACHELINE;
                let si = self.index[&addr];
                seg_seq = seg_seq.max(self.slots[si as usize].inserted_seq);
            }
            best = Some(match best {
                Some(b) if (b.0, b.1) >= (seg_len, seg_seq) => b,
                _ => (seg_len, seg_seq, seg_start),
            });
            off += seg_len;
        }
        best.expect("candidate of a non-empty run")
    }

    fn blk_add_run(&mut self, start: u64, len: u64) {
        let cand = self.blk_run_candidate(start, len);
        self.blk_runs.insert(start, len);
        self.blk_cand.insert(start, cand);
        self.blk_best.insert(cand);
    }

    fn blk_remove_run(&mut self, start: u64) -> u64 {
        let len = self.blk_runs.remove(&start).expect("run exists");
        let cand = self.blk_cand.remove(&start).expect("run candidate exists");
        self.blk_best.remove(&cand);
        len
    }

    /// A new entry appeared at `line`: merge with the adjacent runs.
    fn blk_insert(&mut self, line: u64) {
        let cl = crate::proto::CACHELINE;
        let mut start = line;
        let mut len = 1u64;
        if let Some((ls, ll)) = self
            .blk_runs
            .range(..line)
            .next_back()
            .map(|(&s, &l)| (s, l))
        {
            if ls + ll * cl == line {
                self.blk_remove_run(ls);
                start = ls;
                len += ll;
            }
        }
        if let Some(rl) = self.blk_runs.get(&(line + cl)).copied() {
            self.blk_remove_run(line + cl);
            len += rl;
        }
        self.blk_add_run(start, len);
    }

    /// The entry at `addr` was cleared: split its run around the hole.
    fn blk_remove(&mut self, addr: u64) {
        let cl = crate::proto::CACHELINE;
        // The containing run: largest start <= addr whose member set
        // (start + i*CACHELINE) includes addr. The backward scan (not
        // just `next_back`) is defense in depth: with out-of-contract
        // misaligned entries (debug-asserted at insert) run *intervals*
        // can overlap even though member sets stay disjoint (e.g. runs
        // {63,127} and {64} — the predecessor run of 127 starts at 64
        // yet does not contain it), and removal must still find the
        // true owner instead of corrupting a neighbor.
        let (start, len) = self
            .blk_runs
            .range(..=addr)
            .rev()
            .map(|(&s, &l)| (s, l))
            .find(|&(s, l)| addr < s + l * cl && (addr - s) % cl == 0)
            .expect("cleared entry lives in a run");
        self.blk_remove_run(start);
        let left = (addr - start) / cl;
        let right = len - left - 1;
        if left > 0 {
            self.blk_add_run(start, left);
        }
        if right > 0 {
            self.blk_add_run(addr + cl, right);
        }
    }

    // ---- the hot path

    /// Record a coherent access by `owner` to `line`. Returns `true` on a
    /// filter hit (entry existed), `false` when a new entry was allocated.
    /// MUST only be called when there is room (`!needs_eviction()`).
    pub fn record(&mut self, line: u64, owner: NodeId) -> bool {
        self.seq += 1;
        let seq = self.seq;
        if let Some(&si) = self.index.get(&line) {
            // Touch: O(1) move to the most-recent end of the recency list
            // (the seed re-keyed a BTreeMap here).
            self.rec_unlink(si);
            self.rec_push_tail(si);
            let s = &mut self.slots[si as usize];
            if !s.owners.contains(&owner) {
                s.owners.push(owner);
            }
            self.stats.hits += 1;
            true
        } else {
            assert!(
                self.index.len() < self.capacity,
                "record() without room; call select_victim first"
            );
            let count = self.counts.increment(line);
            let si = self.alloc();
            {
                let s = &mut self.slots[si as usize];
                s.addr = line;
                s.owners.clear();
                s.owners.push(owner);
                s.inserted_seq = seq;
                s.insert_count = count;
            }
            self.ins_push_tail(si);
            self.rec_push_tail(si);
            if matches!(self.policy, VictimPolicy::Lfi) {
                self.cnt_push_tail(si, count);
            }
            self.index.insert(line, si);
            if self.blk_active() {
                // The run index mirrors the linear scan only on
                // cacheline-aligned lines (the scan's adjacency is
                // between consecutive *entries*; a misaligned entry
                // between two aligned ones would break a scan run that
                // the interval index cannot see). Every DCOH caller
                // aligns via `Cache::line_of`; enforce the contract.
                debug_assert_eq!(
                    line % crate::proto::CACHELINE,
                    0,
                    "BlockLen run tracking requires cacheline-aligned lines"
                );
                self.blk_insert(line);
            }
            self.stats.misses += 1;
            false
        }
    }

    /// Whether allocating a new entry for `line` requires an eviction.
    pub fn needs_eviction(&self, line: u64) -> bool {
        !self.index.contains_key(&line) && self.index.len() >= self.capacity
    }

    fn victim_of(&self, si: u32) -> Victim {
        let s = &self.slots[si as usize];
        Victim {
            addrs: vec![s.addr],
            owners: s.owners.to_vec(),
        }
    }

    /// Choose the victim entry (or run of entries) per policy. Does not
    /// remove them — the DCOH clears via `clear()` after BIRsp collection.
    /// FIFO/LIFO/LRU/MRU read a list end in O(1); LFI reads the lowest
    /// count bucket's tail (amortized O(1)); BlockLen walks the ordered
    /// index once.
    pub fn select_victim(&self) -> Option<Victim> {
        if self.index.is_empty() {
            return None;
        }
        match self.policy {
            VictimPolicy::Fifo => Some(self.victim_of(self.ins_head)),
            VictimPolicy::Lifo => Some(self.victim_of(self.ins_tail)),
            VictimPolicy::Lru => Some(self.victim_of(self.rec_head)),
            VictimPolicy::Mru => Some(self.victim_of(self.rec_tail)),
            VictimPolicy::Lfi => {
                // Least insertion count first, newest-inserted (max seq)
                // among ties — the same key the seed's BTreeSet ordered
                // by (LIFO tie-break: recency ties would otherwise
                // re-evict hot data). The bucket index keeps lists in
                // seq order, so the min bucket's tail IS that victim;
                // `lfi_victim_linear` is the scan-based oracle.
                self.lfi_buckets
                    .iter()
                    .next()
                    .map(|(_, &(_, tail))| self.victim_of(tail))
            }
            VictimPolicy::BlockLen { .. } => {
                // Global best capped segment straight off the run index —
                // O(log runs) instead of walking the whole ordered index
                // (ROADMAP item); `blocklen_victim_linear` is the
                // scan-based oracle.
                let &(len, _, start) = self
                    .blk_best
                    .iter()
                    .next_back()
                    .expect("non-empty filter has a run");
                Some(self.victim_of_block(start, len))
            }
        }
    }

    /// Materialize a block victim: the segment's line addresses plus the
    /// deduplicated owner union (first-seen order, as the seed built it).
    fn victim_of_block(&self, start: u64, len: u64) -> Victim {
        let addrs: Vec<u64> = (0..len)
            .map(|k| start + k * crate::proto::CACHELINE)
            .collect();
        let mut owners: Vec<NodeId> = Vec::new();
        for a in &addrs {
            let si = self.index[a];
            for &o in &self.slots[si as usize].owners {
                if !owners.contains(&o) {
                    owners.push(o);
                }
            }
        }
        Victim { addrs, owners }
    }

    /// Seed-semantics LFI victim selection: one O(capacity) scan over the
    /// live entries for the (min insert_count, max inserted_seq) key.
    /// Kept as the reference oracle for the bucket-index equivalence
    /// regression test — not used on the hot path.
    pub fn lfi_victim_linear(&self) -> Option<Victim> {
        let mut best: Option<(u64, u64, u32)> = None;
        for &si in self.index.values() {
            let s = &self.slots[si as usize];
            let better = match best {
                None => true,
                Some((bc, bs, _)) => {
                    s.insert_count < bc || (s.insert_count == bc && s.inserted_seq > bs)
                }
            };
            if better {
                best = Some((s.insert_count, s.inserted_seq, si));
            }
        }
        best.map(|(_, _, si)| self.victim_of(si))
    }

    /// Seed-semantics BlockLen victim selection: one ordered O(capacity)
    /// pass over the index — longest capped run segment, LIFO among ties.
    /// Kept as the reference oracle for the incremental run index's
    /// equivalence regression test (like `lfi_victim_linear`) — not used
    /// on the hot path.
    pub fn blocklen_victim_linear(&self) -> Option<Victim> {
        if self.index.is_empty() {
            return None;
        }
        let max_len = self.blk_max_len();
        let mut best: (u64, u64, u64) = (0, 0, 0); // (len, lifo_key, start)
        let mut run_start = 0u64;
        let mut run_len = 0u64;
        let mut run_lifo = 0u64;
        let mut prev_addr = 0u64;
        for (&addr, &si) in &self.index {
            let seq = self.slots[si as usize].inserted_seq;
            if run_len > 0 && addr == prev_addr + crate::proto::CACHELINE && run_len < max_len
            {
                run_len += 1;
                run_lifo = run_lifo.max(seq);
            } else {
                if run_len > best.0 || (run_len == best.0 && run_lifo > best.1) {
                    best = (run_len, run_lifo, run_start);
                }
                run_start = addr;
                run_len = 1;
                run_lifo = seq;
            }
            prev_addr = addr;
        }
        if run_len > best.0 || (run_len == best.0 && run_lifo > best.1) {
            best = (run_len, run_lifo, run_start);
        }
        let (len, _, start) = best;
        Some(self.victim_of_block(start, len))
    }

    /// Clear victim entries after all BIRsp arrived. Slots return to the
    /// free list (owner spill allocations are reused on the next insert).
    pub fn clear(&mut self, victim: &Victim) {
        for addr in &victim.addrs {
            if let Some(si) = self.index.remove(addr) {
                self.ins_unlink(si);
                self.rec_unlink(si);
                if matches!(self.policy, VictimPolicy::Lfi) {
                    self.cnt_unlink(si);
                }
                if self.blk_active() {
                    self.blk_remove(*addr);
                }
                self.slots[si as usize].owners.clear();
                self.free.push(si);
                self.stats.entries_cleared += 1;
            }
        }
        self.stats.evictions += 1;
    }

    /// Internal consistency check (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.index.len() > self.capacity {
            return Err("over capacity".to_string());
        }
        if self.index.len() + self.free.len() != self.slots.len() {
            return Err(format!(
                "slab leak: {} live + {} free != {} slots",
                self.index.len(),
                self.free.len(),
                self.slots.len()
            ));
        }
        let ins = self.walk_list(self.ins_head, |s| s.next_ins)?;
        if ins != self.index.len() {
            return Err(format!("insert list covers {ins} of {}", self.index.len()));
        }
        let rec = self.walk_list(self.rec_head, |s| s.next_rec)?;
        if rec != self.index.len() {
            return Err(format!("recency list covers {rec} of {}", self.index.len()));
        }
        // Insertion order must be strictly increasing along the list.
        let mut si = self.ins_head;
        let mut prev_seq = 0u64;
        while si != NIL {
            let s = &self.slots[si as usize];
            if s.inserted_seq <= prev_seq {
                return Err(format!("insert list out of order at {:#x}", s.addr));
            }
            prev_seq = s.inserted_seq;
            si = s.next_ins;
        }
        for (addr, &si) in &self.index {
            let s = &self.slots[si as usize];
            if s.addr != *addr {
                return Err(format!("slot addr mismatch for {addr:#x}"));
            }
            if s.owners.is_empty() {
                return Err(format!("entry {addr:#x} has no owners"));
            }
            if self.counts.get(*addr) < s.insert_count {
                return Err(format!("global count below snapshot for {addr:#x}"));
            }
        }
        if matches!(self.policy, VictimPolicy::Lfi) {
            // Count buckets partition the live set; each list holds only
            // slots of its count, in strictly increasing seq order.
            let mut covered = 0usize;
            for (&count, &(head, _tail)) in &self.lfi_buckets {
                let mut si = head;
                let mut prev_seq = 0u64;
                let mut len = 0usize;
                while si != NIL {
                    let s = &self.slots[si as usize];
                    if self.index.get(&s.addr) != Some(&si) {
                        return Err(format!("bucket {count} visits stale slot {:#x}", s.addr));
                    }
                    if s.insert_count != count {
                        return Err(format!(
                            "slot {:#x} with count {} in bucket {count}",
                            s.addr, s.insert_count
                        ));
                    }
                    if s.inserted_seq <= prev_seq && len > 0 {
                        return Err(format!("bucket {count} out of seq order at {:#x}", s.addr));
                    }
                    prev_seq = s.inserted_seq;
                    len += 1;
                    if len > self.slots.len() {
                        return Err(format!("bucket {count} cycles"));
                    }
                    si = s.next_cnt;
                }
                if len == 0 {
                    return Err(format!("empty bucket {count} left in the index"));
                }
                covered += len;
            }
            if covered != self.index.len() {
                return Err(format!(
                    "LFI buckets cover {covered} of {} live entries",
                    self.index.len()
                ));
            }
        }
        if self.blk_active() {
            // Runs partition the live set into maximal contiguous runs,
            // and every cached candidate equals a fresh recomputation.
            let cl = crate::proto::CACHELINE;
            let mut covered = 0usize;
            for (&start, &len) in &self.blk_runs {
                if len == 0 {
                    return Err(format!("empty run at {start:#x}"));
                }
                for k in 0..len {
                    if !self.index.contains_key(&(start + k * cl)) {
                        return Err(format!("run {start:#x}+{k} not in index"));
                    }
                }
                if self.index.contains_key(&(start + len * cl))
                    || (start >= cl && self.index.contains_key(&(start - cl)))
                {
                    return Err(format!("run at {start:#x} is not maximal"));
                }
                covered += len as usize;
                let cand = self.blk_run_candidate(start, len);
                if self.blk_cand.get(&start) != Some(&cand) {
                    return Err(format!("stale candidate for run {start:#x}"));
                }
                if !self.blk_best.contains(&cand) {
                    return Err(format!("candidate of run {start:#x} missing from best set"));
                }
            }
            if covered != self.index.len() {
                return Err(format!(
                    "runs cover {covered} of {} live entries",
                    self.index.len()
                ));
            }
            if self.blk_best.len() != self.blk_runs.len() {
                return Err("best set size != run count".to_string());
            }
        }
        Ok(())
    }

    /// Serialize the *logical* filter state: live entries in
    /// insertion-list order (addr, seq, count snapshot, owners) plus the
    /// recency order as an addr sequence, the global LFI counters, the
    /// seq counter and stats. Slot indices and free-list layout are NOT
    /// serialized — they are never observable (victims are chosen via
    /// list ends and the addr index), so restore rebuilds a compact slab
    /// by replaying inserts through the normal link plumbing.
    pub fn snapshot(&self, w: &mut crate::util::snap::SnapWriter) {
        w.u64(self.capacity as u64);
        w.u64(self.seq);
        w.u64(self.stats.hits);
        w.u64(self.stats.misses);
        w.u64(self.stats.evictions);
        w.u64(self.stats.entries_cleared);
        let pairs = self.counts.sorted_pairs();
        w.usize(pairs.len());
        for (k, v) in pairs {
            w.u64(k);
            w.u64(v);
        }
        w.usize(self.index.len());
        let mut si = self.ins_head;
        while si != NIL {
            let s = &self.slots[si as usize];
            w.u64(s.addr);
            w.u64(s.inserted_seq);
            w.u64(s.insert_count);
            w.usize(s.owners.len());
            for &o in &s.owners {
                w.usize(o);
            }
            si = s.next_ins;
        }
        let mut si = self.rec_head;
        while si != NIL {
            let s = &self.slots[si as usize];
            w.u64(s.addr);
            si = s.next_rec;
        }
    }

    /// Rebuild the state written by [`SnoopFilter::snapshot`] onto a
    /// filter of the same capacity and policy.
    pub fn restore(&mut self, r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        let cap = r.u64()? as usize;
        if cap != self.capacity {
            return Err(format!(
                "snapshot is for a snoop filter of capacity {cap}, this one holds {}",
                self.capacity
            ));
        }
        self.slots.clear();
        self.free.clear();
        self.index.clear();
        self.ins_head = NIL;
        self.ins_tail = NIL;
        self.rec_head = NIL;
        self.rec_tail = NIL;
        self.counts = FlatCounter::new();
        self.lfi_buckets.clear();
        self.blk_runs.clear();
        self.blk_cand.clear();
        self.blk_best.clear();
        self.seq = r.u64()?;
        self.stats.hits = r.u64()?;
        self.stats.misses = r.u64()?;
        self.stats.evictions = r.u64()?;
        self.stats.entries_cleared = r.u64()?;
        for _ in 0..r.usize()? {
            let k = r.u64()?;
            let v = r.u64()?;
            self.counts.set(k, v);
        }
        let n = r.usize()?;
        if n > self.capacity {
            return Err(format!("snapshot holds {n} entries, capacity is {cap}"));
        }
        // Entries arrive in insertion order (strictly increasing seq), so
        // pushing each to the tails reproduces the insertion list and —
        // because a bucket's members are threaded in seq order — the LFI
        // bucket lists.
        for _ in 0..n {
            let addr = r.u64()?;
            let inserted_seq = r.u64()?;
            let insert_count = r.u64()?;
            let n_owners = r.usize()?;
            let si = self.alloc();
            {
                let s = &mut self.slots[si as usize];
                s.addr = addr;
                s.owners.clear();
                s.inserted_seq = inserted_seq;
                s.insert_count = insert_count;
            }
            for _ in 0..n_owners {
                let o = r.usize()?;
                self.slots[si as usize].owners.push(o);
            }
            self.ins_push_tail(si);
            if self.index.insert(addr, si).is_some() {
                return Err(format!("snapshot repeats entry {addr:#x}"));
            }
            if matches!(self.policy, VictimPolicy::Lfi) {
                self.cnt_push_tail(si, insert_count);
            }
            if self.blk_active() {
                self.blk_insert(addr);
            }
        }
        let mut seen = BTreeSet::new();
        for _ in 0..n {
            let addr = r.u64()?;
            let &si = self
                .index
                .get(&addr)
                .ok_or_else(|| format!("recency order names unknown entry {addr:#x}"))?;
            if !seen.insert(addr) {
                return Err(format!("recency order repeats entry {addr:#x}"));
            }
            self.rec_push_tail(si);
        }
        self.check_invariants()
            .map_err(|e| format!("restored snoop filter fails invariants: {e}"))
    }

    /// Walk an intrusive list, verifying each slot is live and acyclic.
    fn walk_list(&self, head: u32, next: impl Fn(&Slot) -> u32) -> Result<usize, String> {
        let mut n = 0usize;
        let mut si = head;
        while si != NIL {
            let s = &self.slots[si as usize];
            if self.index.get(&s.addr) != Some(&si) {
                return Err(format!("list visits stale slot for {:#x}", s.addr));
            }
            n += 1;
            if n > self.slots.len() {
                return Err("list cycles".to_string());
            }
            si = next(s);
        }
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::CACHELINE;

    fn filled(policy: VictimPolicy, n: usize) -> SnoopFilter {
        let mut sf = SnoopFilter::new(n, policy);
        for i in 0..n {
            sf.record(i as u64 * CACHELINE, 0);
        }
        sf
    }

    #[test]
    fn fifo_and_lifo_pick_opposite_ends() {
        let sf = filled(VictimPolicy::Fifo, 4);
        assert_eq!(sf.select_victim().unwrap().addrs, vec![0]);
        let sf = filled(VictimPolicy::Lifo, 4);
        assert_eq!(sf.select_victim().unwrap().addrs, vec![3 * CACHELINE]);
    }

    #[test]
    fn lru_mru_follow_touches() {
        let mut sf = filled(VictimPolicy::Lru, 4);
        sf.record(0, 0); // touch line 0 -> most recent
        assert_eq!(sf.select_victim().unwrap().addrs, vec![CACHELINE]);
        let mut sf = filled(VictimPolicy::Mru, 4);
        sf.record(0, 0);
        assert_eq!(sf.select_victim().unwrap().addrs, vec![0]);
    }

    #[test]
    fn lfi_prefers_rarely_inserted() {
        let mut sf = SnoopFilter::new(2, VictimPolicy::Lfi);
        // line A inserted 3 times (evicted in between), line B once.
        for _ in 0..3 {
            sf.record(0, 0);
            let v = Victim {
                addrs: vec![0],
                owners: vec![0],
            };
            sf.clear(&v);
        }
        sf.record(0, 0); // A: count 4
        sf.record(CACHELINE, 0); // B: count 1
        let v = sf.select_victim().unwrap();
        assert_eq!(v.addrs, vec![CACHELINE], "LFI must evict the cold line");
    }

    #[test]
    fn owners_accumulate_and_union_on_block() {
        let mut sf = SnoopFilter::new(4, VictimPolicy::BlockLen { max_len: 4 });
        sf.record(0, 1);
        sf.record(CACHELINE, 2);
        sf.record(2 * CACHELINE, 1);
        let Victim { addrs, mut owners } = sf.select_victim().unwrap();
        assert_eq!(addrs.len(), 3);
        owners.sort_unstable();
        assert_eq!(owners, vec![1, 2]);
    }

    #[test]
    fn blocklen_caps_run_length() {
        let mut sf = SnoopFilter::new(8, VictimPolicy::BlockLen { max_len: 2 });
        for i in 0..6u64 {
            sf.record(i * CACHELINE, 0);
        }
        let v = sf.select_victim().unwrap();
        assert_eq!(v.addrs.len(), 2);
    }

    #[test]
    fn blocklen_prefers_longer_then_lifo() {
        let mut sf = SnoopFilter::new(8, VictimPolicy::BlockLen { max_len: 4 });
        // run A: lines 0,1 ; isolated line 100 ; run B: lines 10,11 (newer)
        sf.record(0, 0);
        sf.record(CACHELINE, 0);
        sf.record(100 * CACHELINE, 0);
        sf.record(10 * CACHELINE, 0);
        sf.record(11 * CACHELINE, 0);
        let v = sf.select_victim().unwrap();
        assert_eq!(v.addrs, vec![10 * CACHELINE, 11 * CACHELINE]);
    }

    #[test]
    fn record_hit_updates_not_allocates() {
        let mut sf = SnoopFilter::new(2, VictimPolicy::Fifo);
        assert!(!sf.record(0, 0));
        assert!(sf.record(0, 5));
        assert_eq!(sf.len(), 1);
        let mut o = sf.owners(0).unwrap().to_vec();
        o.sort_unstable();
        assert_eq!(o, vec![0, 5]);
        assert_eq!((sf.stats.hits, sf.stats.misses), (1, 1));
    }

    #[test]
    fn needs_eviction_only_when_full_and_absent() {
        let sf = filled(VictimPolicy::Fifo, 2);
        assert!(sf.needs_eviction(99 * CACHELINE));
        assert!(!sf.needs_eviction(0)); // present
        let sf2 = SnoopFilter::new(4, VictimPolicy::Fifo);
        assert!(!sf2.needs_eviction(0)); // room available
    }

    #[test]
    fn clear_removes_all_indices() {
        let mut sf = filled(VictimPolicy::Fifo, 4);
        let v = sf.select_victim().unwrap();
        sf.clear(&v);
        assert_eq!(sf.len(), 3);
        sf.check_invariants().unwrap();
        assert!(!sf.contains(v.addrs[0]));
    }

    /// Regression for the ROADMAP O(capacity)-eviction item: the bucket
    /// index must pick exactly the victim the seed-semantics linear scan
    /// picks, across 1k randomized churn sequences (re-insertions drive
    /// the global counters apart, producing deep count-bucket structure).
    #[test]
    fn lfi_bucket_index_victim_matches_linear_scan_oracle() {
        use crate::util::prop::forall;
        forall(
            "LFI bucket-index victim == seed-semantics linear scan",
            1000,
            |rng| {
                let cap = 4 + rng.gen_range(28) as usize;
                let lines = 8 + rng.gen_range(120);
                let ops: Vec<(u64, NodeId)> = (0..200)
                    .map(|_| (rng.gen_range(lines) * CACHELINE, rng.gen_range(4) as NodeId))
                    .collect();
                (cap, ops)
            },
            |(cap, ops)| {
                let mut sf = SnoopFilter::new(*cap, VictimPolicy::Lfi);
                for &(line, owner) in ops {
                    if sf.needs_eviction(line) {
                        let fast = sf.select_victim().ok_or("no bucket-index victim")?;
                        let slow = sf.lfi_victim_linear().ok_or("no linear-scan victim")?;
                        if fast.addrs != slow.addrs {
                            return Err(format!(
                                "victim diverged: bucket {:?} vs linear {:?}",
                                fast.addrs, slow.addrs
                            ));
                        }
                        sf.clear(&fast);
                    }
                    sf.record(line, owner);
                    sf.check_invariants()?;
                }
                Ok(())
            },
        );
    }

    /// Regression for the ROADMAP BlockLen item: the incremental run
    /// index must pick exactly the victim the seed-semantics linear scan
    /// picks, across randomized churn (clustered lines force deep
    /// run merge/split structure; varying max_len exercises the capped
    /// segmentation).
    #[test]
    fn blocklen_run_index_victim_matches_linear_scan_oracle() {
        use crate::util::prop::forall;
        forall(
            "BlockLen run-index victim == seed-semantics linear scan",
            1000,
            |rng| {
                let cap = 4 + rng.gen_range(28) as usize;
                let max_len = 1 + rng.gen_range(6) as u8;
                // Clustered address space: long contiguous runs are likely.
                let lines = 4 + rng.gen_range(40);
                let ops: Vec<(u64, NodeId)> = (0..250)
                    .map(|_| (rng.gen_range(lines) * CACHELINE, rng.gen_range(4) as NodeId))
                    .collect();
                (cap, max_len, ops)
            },
            |(cap, max_len, ops)| {
                let mut sf = SnoopFilter::new(*cap, VictimPolicy::BlockLen { max_len: *max_len });
                for &(line, owner) in ops {
                    if sf.needs_eviction(line) {
                        let fast = sf.select_victim().ok_or("no run-index victim")?;
                        let slow = sf.blocklen_victim_linear().ok_or("no linear victim")?;
                        if fast.addrs != slow.addrs || fast.owners != slow.owners {
                            return Err(format!(
                                "victim diverged: index {:?} vs linear {:?}",
                                fast.addrs, slow.addrs
                            ));
                        }
                        sf.clear(&fast);
                    }
                    sf.record(line, owner);
                    sf.check_invariants()?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_invariants_under_random_workload() {
        use crate::util::prop::forall;
        forall(
            "snoop filter invariants",
            40,
            |rng| {
                let policy = match rng.gen_range(6) {
                    0 => VictimPolicy::Fifo,
                    1 => VictimPolicy::Lru,
                    2 => VictimPolicy::Lfi,
                    3 => VictimPolicy::Lifo,
                    4 => VictimPolicy::Mru,
                    _ => VictimPolicy::BlockLen { max_len: 4 },
                };
                let ops: Vec<(u64, NodeId)> = (0..300)
                    .map(|_| (rng.gen_range(64) * CACHELINE, rng.gen_range(4) as NodeId))
                    .collect();
                (policy, ops)
            },
            |(policy, ops)| {
                let mut sf = SnoopFilter::new(16, *policy);
                for &(line, owner) in ops {
                    if sf.needs_eviction(line) {
                        let v = sf.select_victim().ok_or("no victim when full")?;
                        if v.addrs.is_empty() {
                            return Err("empty victim".into());
                        }
                        sf.clear(&v);
                    }
                    sf.record(line, owner);
                    sf.check_invariants()?;
                }
                Ok(())
            },
        );
    }
}
