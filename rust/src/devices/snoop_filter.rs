//! Device-side inclusive snoop filter — the example DCOH (device coherency
//! agent) for HDM-DB device-managed coherence (paper §III-D).
//!
//! The filter is a fully-associative buffer recording, for every cacheline
//! of its endpoint that is cached elsewhere, the coherence metadata (owner
//! list, insertion order, recency, insertion frequency). When a new
//! coherent request conflicts with the capacity, a victim entry is chosen
//! by the configured policy and back-invalidate snoops (BISnp) are sent to
//! the owners; the entry is cleared once every BIRsp is collected. Victim
//! selection is modularized so researchers can evaluate policies — exactly
//! the paper's Fig 14/15 study.

use crate::proto::NodeId;
use std::collections::{BTreeMap, BTreeSet, HashMap};

/// Victim selection policies (paper §V-B, plus the block-length-prioritized
/// policy of §V-C used to exercise InvBlk).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VictimPolicy {
    /// First-In First-Out: evict the oldest inserted entry.
    Fifo,
    /// Least Recently Used (touches refresh recency).
    Lru,
    /// Least Frequently Inserted: global per-address insertion counters;
    /// evict the entry whose address was inserted the fewest times.
    Lfi,
    /// Last-In First-Out: evict the newest inserted entry.
    Lifo,
    /// Most Recently Used.
    Mru,
    /// Block-length-prioritized: evict the longest run of contiguous-line
    /// entries (up to `max_len`), LIFO among ties — pairs with InvBlk.
    BlockLen { max_len: u8 },
}

impl VictimPolicy {
    pub const BASIC: [VictimPolicy; 5] = [
        VictimPolicy::Fifo,
        VictimPolicy::Lru,
        VictimPolicy::Lfi,
        VictimPolicy::Lifo,
        VictimPolicy::Mru,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            VictimPolicy::Fifo => "FIFO",
            VictimPolicy::Lru => "LRU",
            VictimPolicy::Lfi => "LFI",
            VictimPolicy::Lifo => "LIFO",
            VictimPolicy::Mru => "MRU",
            VictimPolicy::BlockLen { .. } => "BlockLen",
        }
    }
}

#[derive(Clone, Debug)]
struct SfEntry {
    owners: Vec<NodeId>,
    inserted_seq: u64,
    last_touch: u64,
    /// Snapshot of the global insertion counter for this address.
    insert_count: u64,
}

/// A victim selected for eviction: the lines to clear and who owns them.
#[derive(Clone, Debug)]
pub struct Victim {
    /// Contiguous line addresses to invalidate (len 1 unless BlockLen).
    pub addrs: Vec<u64>,
    /// Union of owners across the victim lines.
    pub owners: Vec<NodeId>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SfStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Entries cleared by evictions (>= evictions with InvBlk).
    pub entries_cleared: u64,
}

/// Inclusive device-side snoop filter.
pub struct SnoopFilter {
    capacity: usize,
    policy: VictimPolicy,
    entries: BTreeMap<u64, SfEntry>,
    /// (inserted_seq -> addr) index for FIFO/LIFO.
    by_insert: BTreeMap<u64, u64>,
    /// (last_touch -> addr) index for LRU/MRU.
    by_touch: BTreeMap<u64, u64>,
    /// (insert_count, reversed insertion seq, addr) ordered set for LFI:
    /// least-frequently-inserted first, newest-inserted first among ties
    /// (LIFO tie-break — recency ties would otherwise re-evict hot data).
    by_freq: BTreeSet<(u64, u64, u64)>,
    /// LFI's global counter table: addr -> times inserted (kept across
    /// evictions — that is the point of the policy).
    insert_counts: HashMap<u64, u64>,
    seq: u64,
    pub stats: SfStats,
}

impl SnoopFilter {
    pub fn new(capacity: usize, policy: VictimPolicy) -> SnoopFilter {
        SnoopFilter {
            capacity,
            policy,
            entries: BTreeMap::new(),
            by_insert: BTreeMap::new(),
            by_touch: BTreeMap::new(),
            by_freq: BTreeSet::new(),
            insert_counts: HashMap::new(),
            seq: 0,
            stats: SfStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn contains(&self, line: u64) -> bool {
        self.entries.contains_key(&line)
    }

    pub fn owners(&self, line: u64) -> Option<&[NodeId]> {
        self.entries.get(&line).map(|e| e.owners.as_slice())
    }

    /// Record a coherent access by `owner` to `line`. Returns `true` on a
    /// filter hit (entry existed), `false` when a new entry was allocated.
    /// MUST only be called when there is room (`!needs_eviction()`).
    pub fn record(&mut self, line: u64, owner: NodeId) -> bool {
        self.seq += 1;
        let seq = self.seq;
        if let Some(e) = self.entries.get_mut(&line) {
            self.by_touch.remove(&e.last_touch);
            e.last_touch = seq;
            self.by_touch.insert(seq, line);
            if !e.owners.contains(&owner) {
                e.owners.push(owner);
            }
            self.stats.hits += 1;
            true
        } else {
            assert!(
                self.entries.len() < self.capacity,
                "record() without room; call select_victim first"
            );
            let count = {
                let c = self.insert_counts.entry(line).or_insert(0);
                *c += 1;
                *c
            };
            self.entries.insert(
                line,
                SfEntry {
                    owners: vec![owner],
                    inserted_seq: seq,
                    last_touch: seq,
                    insert_count: count,
                },
            );
            self.by_insert.insert(seq, line);
            self.by_touch.insert(seq, line);
            self.by_freq.insert((count, u64::MAX - seq, line));
            self.stats.misses += 1;
            false
        }
    }

    /// Whether allocating a new entry for `line` requires an eviction.
    pub fn needs_eviction(&self, line: u64) -> bool {
        !self.entries.contains_key(&line) && self.entries.len() >= self.capacity
    }

    /// Choose the victim entry (or run of entries) per policy. Does not
    /// remove them — the DCOH clears via `clear()` after BIRsp collection.
    pub fn select_victim(&self) -> Option<Victim> {
        if self.entries.is_empty() {
            return None;
        }
        let single = |addr: u64| -> Victim {
            Victim {
                addrs: vec![addr],
                owners: self.entries[&addr].owners.clone(),
            }
        };
        match self.policy {
            VictimPolicy::Fifo => self.by_insert.values().next().map(|&a| single(a)),
            VictimPolicy::Lifo => self.by_insert.values().next_back().map(|&a| single(a)),
            VictimPolicy::Lru => self.by_touch.values().next().map(|&a| single(a)),
            VictimPolicy::Mru => self.by_touch.values().next_back().map(|&a| single(a)),
            VictimPolicy::Lfi => self.by_freq.iter().next().map(|&(_, _, a)| single(a)),
            VictimPolicy::BlockLen { max_len } => Some(self.select_block_victim(max_len)),
        }
    }

    /// Longest contiguous run of entries (<= max_len), LIFO among ties.
    fn select_block_victim(&self, max_len: u8) -> Victim {
        let max_len = max_len.max(1) as u64;
        let lines: Vec<u64> = self.entries.keys().copied().collect();
        let mut best: (u64, u64, u64) = (0, 0, 0); // (len, lifo_key, start)
        let mut i = 0;
        while i < lines.len() {
            // Grow the contiguous run starting at i, capped at max_len.
            let mut j = i;
            while j + 1 < lines.len()
                && lines[j + 1] == lines[j] + crate::proto::CACHELINE
                && (j + 1 - i) < (max_len as usize - 1) + 1
                && ((j + 1 - i) as u64) < max_len
            {
                j += 1;
            }
            let len = (j - i + 1) as u64;
            let lifo_key = lines[i..=j]
                .iter()
                .map(|a| self.entries[a].inserted_seq)
                .max()
                .unwrap();
            if len > best.0 || (len == best.0 && lifo_key > best.1) {
                best = (len, lifo_key, lines[i]);
            }
            i = j + 1;
        }
        let (len, _, start) = best;
        let addrs: Vec<u64> = (0..len)
            .map(|k| start + k * crate::proto::CACHELINE)
            .collect();
        let mut owners: Vec<NodeId> = Vec::new();
        for a in &addrs {
            for &o in &self.entries[a].owners {
                if !owners.contains(&o) {
                    owners.push(o);
                }
            }
        }
        Victim { addrs, owners }
    }

    /// Clear victim entries after all BIRsp arrived.
    pub fn clear(&mut self, victim: &Victim) {
        for addr in &victim.addrs {
            if let Some(e) = self.entries.remove(addr) {
                self.by_insert.remove(&e.inserted_seq);
                self.by_touch.remove(&e.last_touch);
                self.by_freq
                    .remove(&(e.insert_count, u64::MAX - e.inserted_seq, *addr));
                self.stats.entries_cleared += 1;
            }
        }
        self.stats.evictions += 1;
    }

    /// Internal consistency check (used by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.entries.len() > self.capacity {
            return Err("over capacity".to_string());
        }
        if self.by_insert.len() != self.entries.len()
            || self.by_touch.len() != self.entries.len()
            || self.by_freq.len() != self.entries.len()
        {
            return Err(format!(
                "index desync: entries={} insert={} touch={} freq={}",
                self.entries.len(),
                self.by_insert.len(),
                self.by_touch.len(),
                self.by_freq.len()
            ));
        }
        for (addr, e) in &self.entries {
            if self.by_insert.get(&e.inserted_seq) != Some(addr) {
                return Err(format!("insert index wrong for {addr:#x}"));
            }
            if self.by_touch.get(&e.last_touch) != Some(addr) {
                return Err(format!("touch index wrong for {addr:#x}"));
            }
            if e.owners.is_empty() {
                return Err(format!("entry {addr:#x} has no owners"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proto::CACHELINE;

    fn filled(policy: VictimPolicy, n: usize) -> SnoopFilter {
        let mut sf = SnoopFilter::new(n, policy);
        for i in 0..n {
            sf.record(i as u64 * CACHELINE, 0);
        }
        sf
    }

    #[test]
    fn fifo_and_lifo_pick_opposite_ends() {
        let sf = filled(VictimPolicy::Fifo, 4);
        assert_eq!(sf.select_victim().unwrap().addrs, vec![0]);
        let sf = filled(VictimPolicy::Lifo, 4);
        assert_eq!(sf.select_victim().unwrap().addrs, vec![3 * CACHELINE]);
    }

    #[test]
    fn lru_mru_follow_touches() {
        let mut sf = filled(VictimPolicy::Lru, 4);
        sf.record(0, 0); // touch line 0 -> most recent
        assert_eq!(sf.select_victim().unwrap().addrs, vec![CACHELINE]);
        let mut sf = filled(VictimPolicy::Mru, 4);
        sf.record(0, 0);
        assert_eq!(sf.select_victim().unwrap().addrs, vec![0]);
    }

    #[test]
    fn lfi_prefers_rarely_inserted() {
        let mut sf = SnoopFilter::new(2, VictimPolicy::Lfi);
        // line A inserted 3 times (evicted in between), line B once.
        for _ in 0..3 {
            sf.record(0, 0);
            let v = Victim {
                addrs: vec![0],
                owners: vec![0],
            };
            sf.clear(&v);
        }
        sf.record(0, 0); // A: count 4
        sf.record(CACHELINE, 0); // B: count 1
        let v = sf.select_victim().unwrap();
        assert_eq!(v.addrs, vec![CACHELINE], "LFI must evict the cold line");
    }

    #[test]
    fn owners_accumulate_and_union_on_block() {
        let mut sf = SnoopFilter::new(4, VictimPolicy::BlockLen { max_len: 4 });
        sf.record(0, 1);
        sf.record(CACHELINE, 2);
        sf.record(2 * CACHELINE, 1);
        let v = sf.select_victim().unwrap();
        assert_eq!(v.addrs.len(), 3);
        let mut o = v.owners.clone();
        o.sort_unstable();
        assert_eq!(o, vec![1, 2]);
    }

    #[test]
    fn blocklen_caps_run_length() {
        let mut sf = SnoopFilter::new(8, VictimPolicy::BlockLen { max_len: 2 });
        for i in 0..6u64 {
            sf.record(i * CACHELINE, 0);
        }
        let v = sf.select_victim().unwrap();
        assert_eq!(v.addrs.len(), 2);
    }

    #[test]
    fn blocklen_prefers_longer_then_lifo() {
        let mut sf = SnoopFilter::new(8, VictimPolicy::BlockLen { max_len: 4 });
        // run A: lines 0,1 ; isolated line 100 ; run B: lines 10,11 (newer)
        sf.record(0, 0);
        sf.record(CACHELINE, 0);
        sf.record(100 * CACHELINE, 0);
        sf.record(10 * CACHELINE, 0);
        sf.record(11 * CACHELINE, 0);
        let v = sf.select_victim().unwrap();
        assert_eq!(v.addrs, vec![10 * CACHELINE, 11 * CACHELINE]);
    }

    #[test]
    fn record_hit_updates_not_allocates() {
        let mut sf = SnoopFilter::new(2, VictimPolicy::Fifo);
        assert!(!sf.record(0, 0));
        assert!(sf.record(0, 5));
        assert_eq!(sf.len(), 1);
        let mut o = sf.owners(0).unwrap().to_vec();
        o.sort_unstable();
        assert_eq!(o, vec![0, 5]);
        assert_eq!((sf.stats.hits, sf.stats.misses), (1, 1));
    }

    #[test]
    fn needs_eviction_only_when_full_and_absent() {
        let sf = filled(VictimPolicy::Fifo, 2);
        assert!(sf.needs_eviction(99 * CACHELINE));
        assert!(!sf.needs_eviction(0)); // present
        let sf2 = SnoopFilter::new(4, VictimPolicy::Fifo);
        assert!(!sf2.needs_eviction(0)); // room available
    }

    #[test]
    fn clear_removes_all_indices() {
        let mut sf = filled(VictimPolicy::Fifo, 4);
        let v = sf.select_victim().unwrap();
        sf.clear(&v);
        assert_eq!(sf.len(), 3);
        sf.check_invariants().unwrap();
        assert!(!sf.contains(v.addrs[0]));
    }

    #[test]
    fn prop_invariants_under_random_workload() {
        use crate::util::prop::forall;
        forall(
            "snoop filter invariants",
            40,
            |rng| {
                let policy = match rng.gen_range(6) {
                    0 => VictimPolicy::Fifo,
                    1 => VictimPolicy::Lru,
                    2 => VictimPolicy::Lfi,
                    3 => VictimPolicy::Lifo,
                    4 => VictimPolicy::Mru,
                    _ => VictimPolicy::BlockLen { max_len: 4 },
                };
                let ops: Vec<(u64, NodeId)> = (0..300)
                    .map(|_| (rng.gen_range(64) * CACHELINE, rng.gen_range(4) as NodeId))
                    .collect();
                (policy, ops)
            },
            |(policy, ops)| {
                let mut sf = SnoopFilter::new(16, *policy);
                for &(line, owner) in ops {
                    if sf.needs_eviction(line) {
                        let v = sf.select_victim().ok_or("no victim when full")?;
                        if v.addrs.is_empty() {
                            return Err("empty victim".into());
                        }
                        sf.clear(&v);
                    }
                    sf.record(line, owner);
                    sf.check_invariants()?;
                }
                Ok(())
            },
        );
    }
}
