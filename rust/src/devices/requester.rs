//! Computational component (paper §III-B): hosts and accelerators.
//!
//! Each requester consists of the paper's three primary units:
//!  * a **request queue** — queue capacity + issue interval model the
//!    component's ability to issue requests;
//!  * an **address translation unit** — interleaving policy mapping the
//!    flat HDM space onto the memory endpoints;
//!  * a **cache coherence management unit** — the coherent local cache
//!    (`cache.rs`), which also answers BISnp from device coherency agents.
//!
//! Supported access patterns: stream (sequential), random (uniform),
//! skewed (hot/cold), zipfian, pointer-chase, and trace-replay of
//! recorded workloads.

use super::cache::{Access, Cache, LineMeta};
use crate::engine::time::Ps;
use crate::engine::{Component, Payload, Shared};
use crate::proto::{NodeId, Opcode, Packet, TraceOp, CACHELINE};
use crate::util::rng::Pcg32;
use crate::workloads::ZipfTable;
use std::any::Any;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Address -> endpoint interleaving policy.
#[derive(Clone, Debug)]
pub enum Interleave {
    /// Consecutive cachelines rotate across endpoints (finest grain).
    Line,
    /// `lines_per_page` consecutive lines per endpoint before rotating.
    Page(u64),
    /// All traffic to one endpoint (index into the endpoint list).
    Fixed(usize),
}

/// Synthetic or replayed access pattern.
#[derive(Clone, Debug)]
pub enum Pattern {
    /// Uniform random lines over the footprint.
    Random,
    /// Sequential lines, wrapping at the footprint.
    Stream,
    /// `hot_prob` of accesses hit the first `hot_frac` of the footprint.
    Skewed { hot_frac: f64, hot_prob: f64 },
    /// Zipf-distributed line popularity over the footprint (rank 0 = line
    /// 0 is hottest); `theta` is the skew exponent (YCSB default 0.99).
    /// The sampling table is capped at 2^20 lines — larger footprints are
    /// addressed only in their first 2^20 lines under this pattern.
    Zipf { theta: f64 },
    /// Dependent pointer-chasing: each address is derived from the
    /// previous one through an LCG (mcf-style — defeats stride locality
    /// and any prefetch-friendliness).
    PointerChase,
    /// Replay a recorded trace (cycles through it if shorter than the
    /// request budget).
    Trace(Arc<Vec<TraceOp>>),
}

#[derive(Clone, Debug)]
pub struct RequesterCfg {
    pub id: NodeId,
    /// Memory endpoints this requester addresses.
    pub endpoints: Vec<NodeId>,
    /// Max outstanding (in-flight) requests.
    pub queue_capacity: usize,
    /// Time between issue attempts (intensity knob).
    pub issue_interval: Ps,
    /// Requester process time per request (Table III: 10 ns).
    pub process_time: Ps,
    /// Local cache access time (Table III: 12 ns).
    pub cache_access: Ps,
    /// PCIe port delay at this endpoint (Table III: 25 ns), charged on
    /// packet egress and folded into completion latency on ingress.
    pub port_delay: Ps,
    pub pattern: Pattern,
    /// reads / (reads + writes); ignored in trace mode.
    pub read_ratio: f64,
    /// Measured requests to issue (after warm-up).
    pub total_requests: u64,
    pub warmup_requests: u64,
    /// Addressable HDM footprint in cachelines.
    pub footprint_lines: u64,
    /// Local cache capacity in lines; 0 disables caching (non-coherent).
    pub cache_lines: usize,
    pub interleave: Interleave,
    pub seed: u64,
    /// Record a timestamp every `window_every` measured completions
    /// (Fig 20b per-window bandwidth; 0 disables).
    pub window_every: u64,
}

impl RequesterCfg {
    /// A reasonable default the experiments override field-wise.
    pub fn new(id: NodeId, endpoints: Vec<NodeId>) -> RequesterCfg {
        RequesterCfg {
            id,
            endpoints,
            queue_capacity: 16,
            issue_interval: crate::engine::time::ns(10.0),
            process_time: crate::engine::time::ns(10.0),
            cache_access: crate::engine::time::ns(12.0),
            port_delay: crate::engine::time::ns(25.0),
            pattern: Pattern::Random,
            read_ratio: 1.0,
            total_requests: 4000,
            warmup_requests: 0,
            footprint_lines: 1 << 16,
            cache_lines: 0,
            interleave: Interleave::Line,
            seed: 1,
            window_every: 0,
        }
    }
}

/// Per-hop-count latency aggregation (Fig 11).
#[derive(Clone, Copy, Debug, Default)]
pub struct HopStats {
    pub count: u64,
    pub lat_sum: u128,
    pub queue_sum: u128,
    pub switch_sum: u128,
    pub bus_sum: u128,
    pub device_sum: u128,
}

#[derive(Clone, Debug, Default)]
pub struct ReqStats {
    pub completed: u64,
    pub reads: u64,
    pub writes: u64,
    pub lat_sum: u128,
    pub lat_max: Ps,
    /// Exact latency histogram of measured completions: completion
    /// latency (ps) -> count. Feeds the exact p50/p95/p99 percentile
    /// columns (`metrics::LatencyDist`).
    pub lat_hist: BTreeMap<Ps, u64>,
    /// Payload bytes moved by completed measured requests.
    pub bytes: u64,
    pub by_hops: BTreeMap<u32, HopStats>,
    pub cache_hit_completions: u64,
    pub bisnp_received: u64,
    pub lines_invalidated: u64,
    pub dirty_writebacks: u64,
    /// Completion timestamps at each `window_every` boundary.
    pub window_marks: Vec<Ps>,
}

impl ReqStats {
    pub fn avg_latency_ns(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.lat_sum as f64 / self.completed as f64 / 1000.0
        }
    }
}

pub struct Requester {
    cfg: RequesterCfg,
    cache: Cache,
    rng: Pcg32,
    issued: u64,
    completed_total: u64,
    outstanding: usize,
    stream_pos: u64,
    trace_pos: usize,
    /// Zipf sampling table, built once when the pattern is `Zipf`.
    zipf: Option<ZipfTable>,
    /// Pointer-chase walk state (seeded per requester).
    chase: u64,
    /// The local cache port is busy serving a BISnp until this time;
    /// issue-path lookups stall behind it (InvBlk cost, paper §V-C).
    cache_busy_until: Ps,
    /// Issue loop parked on a full request queue; re-armed on completion
    /// instead of polling every interval (hot-path event reduction).
    stalled: bool,
    warmed: bool,
    pub stats: ReqStats,
}

impl Requester {
    pub fn new(cfg: RequesterCfg) -> Requester {
        let rng = Pcg32::new(cfg.seed, cfg.id as u64);
        let cache = Cache::new(cfg.cache_lines);
        let zipf = match &cfg.pattern {
            Pattern::Zipf { theta } => Some(ZipfTable::new(cfg.footprint_lines.max(1), *theta)),
            _ => None,
        };
        let mix = cfg.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (cfg.id as u64).rotate_left(17);
        let chase = mix | 1;
        Requester {
            cache,
            rng,
            issued: 0,
            completed_total: 0,
            outstanding: 0,
            stream_pos: 0,
            trace_pos: 0,
            zipf,
            chase,
            cache_busy_until: 0,
            stalled: false,
            warmed: false,
            stats: ReqStats::default(),
            cfg,
        }
    }

    fn budget(&self) -> u64 {
        self.cfg.total_requests + self.cfg.warmup_requests
    }

    /// Next (addr, is_write) according to the configured pattern.
    fn next_op(&mut self) -> (u64, bool) {
        let fp = self.cfg.footprint_lines.max(1);
        match &self.cfg.pattern {
            Pattern::Random => {
                let line = self.rng.gen_range(fp);
                (line * CACHELINE, self.draw_write())
            }
            Pattern::Stream => {
                let line = self.stream_pos % fp;
                self.stream_pos += 1;
                (line * CACHELINE, self.draw_write())
            }
            Pattern::Skewed { hot_frac, hot_prob } => {
                let hot_lines = ((fp as f64) * hot_frac).max(1.0) as u64;
                let line = if self.rng.chance(*hot_prob) {
                    self.rng.gen_range(hot_lines)
                } else {
                    hot_lines + self.rng.gen_range((fp - hot_lines).max(1))
                };
                (line.min(fp - 1) * CACHELINE, self.draw_write())
            }
            Pattern::Zipf { .. } => {
                let line = self
                    .zipf
                    .as_ref()
                    .expect("zipf table is built at construction for Zipf patterns")
                    .sample(&mut self.rng)
                    .min(fp - 1);
                (line * CACHELINE, self.draw_write())
            }
            Pattern::PointerChase => {
                self.chase = self
                    .chase
                    .wrapping_mul(6_364_136_223_846_793_005)
                    .wrapping_add(1_442_695_040_888_963_407);
                let line = self.chase % fp;
                (line * CACHELINE, self.draw_write())
            }
            Pattern::Trace(ops) => {
                let op = ops[self.trace_pos % ops.len()];
                self.trace_pos += 1;
                (op.addr, op.is_write)
            }
        }
    }

    fn draw_write(&mut self) -> bool {
        self.rng.chance(1.0 - self.cfg.read_ratio)
    }

    /// Map an address to its memory endpoint (address translation unit).
    pub fn endpoint_of(&self, addr: u64) -> NodeId {
        let n = self.cfg.endpoints.len();
        debug_assert!(n > 0, "requester with no endpoints");
        let line = addr / CACHELINE;
        let idx = match self.cfg.interleave {
            Interleave::Line => (line as usize) % n,
            Interleave::Page(lines) => ((line / lines.max(1)) as usize) % n,
            Interleave::Fixed(i) => i % n,
        };
        self.cfg.endpoints[idx]
    }

    fn record_completion(&mut self, pkt: &Packet, ctx: &Shared) {
        if !ctx.collecting {
            return;
        }
        // Ingress port delay is not a contention point; fold into latency.
        let lat = ctx.now.saturating_sub(pkt.issued_at) + self.cfg.port_delay;
        self.stats.completed += 1;
        if self.cfg.window_every > 0 && self.stats.completed % self.cfg.window_every == 0 {
            self.stats.window_marks.push(ctx.now);
        }
        self.stats.lat_sum += lat as u128;
        self.stats.lat_max = self.stats.lat_max.max(lat);
        *self.stats.lat_hist.entry(lat).or_insert(0) += 1;
        self.stats.bytes += CACHELINE;
        if pkt.is_write_kind() {
            self.stats.writes += 1;
        } else {
            self.stats.reads += 1;
        }
        // Request + response hop counts are in the packet's breakdown.
        let b = &pkt.breakdown;
        let h = self.stats.by_hops.entry(b.hops).or_default();
        h.count += 1;
        h.lat_sum += lat as u128;
        h.queue_sum += b.queue_ps as u128;
        h.switch_sum += b.switch_ps as u128;
        h.bus_sum += b.bus_ps as u128;
        h.device_sum += b.device_ps as u128;
    }

    fn after_completion(&mut self, ctx: &mut Shared) {
        if self.stalled {
            // a queue slot just freed: resume the parked issue loop
            self.stalled = false;
            ctx.after(self.cfg.issue_interval, self.cfg.id, Payload::IssueTick);
        }
        self.completed_total += 1;
        if !self.warmed && self.completed_total >= self.cfg.warmup_requests {
            self.warmed = true;
            if self.cfg.warmup_requests > 0 {
                ctx.warmup_done();
            }
        }
    }

    /// True when every request in the budget has completed.
    pub fn done(&self) -> bool {
        self.completed_total >= self.budget()
    }

    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits, self.cache.misses)
    }

    /// Start trace replay at an offset (decorrelates requesters sharing
    /// one trace).
    pub fn skip_trace(&mut self, n: usize) {
        self.trace_pos = n;
    }
}

impl Component for Requester {
    fn start(&mut self, ctx: &mut Shared) {
        if self.cfg.warmup_requests > 0 {
            ctx.expect_warmup();
        }
        if self.budget() > 0 {
            ctx.after(0, self.cfg.id, Payload::IssueTick);
        }
    }

    fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
        match payload {
            Payload::IssueTick => {
                if self.issued >= self.budget() {
                    return; // all issued; stop ticking
                }
                if self.cfg.cache_lines > 0 && ctx.now < self.cache_busy_until {
                    // cache port busy flushing a BISnp run: stall the
                    // issue path until it frees
                    ctx.at(self.cache_busy_until, self.cfg.id, Payload::IssueTick);
                    return;
                }
                if self.outstanding >= self.cfg.queue_capacity {
                    // Request queue full: park instead of polling; the
                    // next completion re-arms the issue loop.
                    self.stalled = true;
                    return;
                }
                {
                    let (addr, is_write) = self.next_op();
                    // Warm-up accesses are reads regardless of read_ratio
                    // (trace replay excepted — its ops are the workload).
                    // The RNG draw already happened inside next_op and
                    // `chance()` consumes exactly one draw whatever the
                    // outcome, so streams stay aligned; this makes the
                    // whole warm-up prefix invariant across read_ratio,
                    // which is what lets sweep cells differing only in
                    // post-warm-up knobs fork from one shared snapshot
                    // (`engine::snapshot`, `sweep` warm-start).
                    let is_write = is_write
                        && (self.cfg.warmup_requests == 0
                            || ctx.collecting
                            || matches!(self.cfg.pattern, Pattern::Trace(_)));
                    self.issued += 1;
                    let cached = self.cfg.cache_lines > 0;
                    if cached && self.cache.access(addr, is_write) == Access::Hit {
                        // Served locally; completes after one cache access.
                        ctx.after(
                            self.cfg.cache_access,
                            self.cfg.id,
                            Payload::Timer(TIMER_LOCAL_HIT, if is_write { 1 } else { 0 }),
                        );
                    } else {
                        let dst = self.endpoint_of(addr);
                        let op = if is_write { Opcode::MemWr } else { Opcode::MemRd };
                        let id = ctx.txn_id();
                        let mut pkt = Packet::request(id, op, self.cfg.id, dst, addr, ctx.now);
                        pkt.coherent = cached;
                        self.outstanding += 1;
                        // Cache lookup (miss) + request processing + port
                        // delay happen before the packet reaches the link.
                        let lookup = if cached { self.cfg.cache_access } else { 0 };
                        let egress = self.cfg.process_time + lookup + self.cfg.port_delay;
                        pkt.breakdown.device_ps += egress;
                        if !ctx.forward(pkt, egress) {
                            // unroutable destination: reclaim the slot and
                            // count toward the budget so the run drains
                            self.outstanding -= 1;
                            self.after_completion(ctx);
                        }
                    }
                }
                ctx.after(self.cfg.issue_interval, self.cfg.id, Payload::IssueTick);
            }
            Payload::Timer(TIMER_LOCAL_HIT, is_write) => {
                // Local cache hit completion: no traffic, but it counts as
                // a completed access for throughput purposes.
                if ctx.collecting {
                    self.stats.completed += 1;
                    if self.cfg.window_every > 0
                        && self.stats.completed % self.cfg.window_every == 0
                    {
                        self.stats.window_marks.push(ctx.now);
                    }
                    self.stats.cache_hit_completions += 1;
                    self.stats.bytes += CACHELINE;
                    self.stats.lat_sum += self.cfg.cache_access as u128;
                    // Keep lat_max consistent with lat_sum/lat_hist: all
                    // three cover every measured completion, local hits
                    // included (else p100 could exceed the reported max).
                    self.stats.lat_max = self.stats.lat_max.max(self.cfg.cache_access);
                    *self.stats.lat_hist.entry(self.cfg.cache_access).or_insert(0) += 1;
                    if is_write == 1 {
                        self.stats.writes += 1;
                    } else {
                        self.stats.reads += 1;
                    }
                }
                self.after_completion(ctx);
            }
            Payload::Packet(pkt) => match pkt.op {
                Opcode::MemRdData | Opcode::MemWrCmp => {
                    self.outstanding = self.outstanding.saturating_sub(1);
                    self.record_completion(&pkt, ctx);
                    if self.cfg.cache_lines > 0 {
                        let evicted = self.cache.insert(
                            pkt.addr,
                            LineMeta {
                                dirty: pkt.op == Opcode::MemWrCmp,
                                src: pkt.src,
                            },
                        );
                        if let Some(ev) = evicted {
                            if ev.meta.dirty {
                                // Background write-back of the dirty victim
                                // (loads the fabric, no outstanding slot).
                                let id = ctx.txn_id();
                                let mut wb = Packet::request(
                                    id,
                                    Opcode::MemWr,
                                    self.cfg.id,
                                    ev.meta.src,
                                    ev.addr,
                                    ctx.now,
                                );
                                wb.coherent = false; // silent WB, no re-own
                                wb.posted = true; // no completion message
                                if ctx.collecting {
                                    self.stats.dirty_writebacks += 1;
                                }
                                ctx.forward(wb, self.cfg.process_time + self.cfg.port_delay);
                            }
                        }
                    }
                    self.after_completion(ctx);
                }
                Opcode::BISnp { len } => {
                    // Device coherency agent asks us to flush a run of
                    // lines. The flush occupies the cache port for
                    // cache_access x len (stalling our own issue path —
                    // the InvBlk overhead of paper §V-C).
                    let (n, dirty) = self.cache.invalidate_block(pkt.addr, len);
                    if ctx.collecting {
                        self.stats.bisnp_received += 1;
                        self.stats.lines_invalidated += n as u64;
                    }
                    let start = ctx.now.max(self.cache_busy_until);
                    let busy = self.cfg.cache_access * len.max(1) as Ps;
                    self.cache_busy_until = start + busy;
                    let mut rsp = pkt.response(dirty);
                    if dirty {
                        // Write back every dirty line in the run.
                        rsp.payload_bytes = (n.max(1) as u64) * CACHELINE;
                    }
                    let delay = (start - ctx.now) + busy + self.cfg.port_delay;
                    ctx.forward(rsp, delay);
                }
                // A requester is never an intermediate hop, and stray
                // responses (e.g. for silent write-backs) need no action.
                _ => {}
            },
            _ => {}
        }
    }

    fn snapshot(&self, w: &mut crate::util::snap::SnapWriter) {
        let (state, inc) = self.rng.save_state();
        w.u64(state);
        w.u64(inc);
        w.u64(self.issued);
        w.u64(self.completed_total);
        w.usize(self.outstanding);
        w.u64(self.stream_pos);
        w.usize(self.trace_pos);
        w.u64(self.chase);
        w.u64(self.cache_busy_until);
        w.bool(self.stalled);
        w.bool(self.warmed);
        self.cache.snapshot(w);
        let s = &self.stats;
        w.u64(s.completed);
        w.u64(s.reads);
        w.u64(s.writes);
        w.u128(s.lat_sum);
        w.u64(s.lat_max);
        w.usize(s.lat_hist.len());
        for (&lat, &count) in &s.lat_hist {
            w.u64(lat);
            w.u64(count);
        }
        w.u64(s.bytes);
        w.usize(s.by_hops.len());
        for (&hops, h) in &s.by_hops {
            w.u32(hops);
            w.u64(h.count);
            w.u128(h.lat_sum);
            w.u128(h.queue_sum);
            w.u128(h.switch_sum);
            w.u128(h.bus_sum);
            w.u128(h.device_sum);
        }
        w.u64(s.cache_hit_completions);
        w.u64(s.bisnp_received);
        w.u64(s.lines_invalidated);
        w.u64(s.dirty_writebacks);
        w.usize(s.window_marks.len());
        for &m in &s.window_marks {
            w.u64(m);
        }
    }

    fn restore(&mut self, r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        let state = r.u64()?;
        let inc = r.u64()?;
        self.rng = Pcg32::from_state(state, inc);
        self.issued = r.u64()?;
        self.completed_total = r.u64()?;
        self.outstanding = r.usize()?;
        self.stream_pos = r.u64()?;
        self.trace_pos = r.usize()?;
        self.chase = r.u64()?;
        self.cache_busy_until = r.u64()?;
        self.stalled = r.bool()?;
        self.warmed = r.bool()?;
        self.cache.restore(r)?;
        let s = &mut self.stats;
        s.completed = r.u64()?;
        s.reads = r.u64()?;
        s.writes = r.u64()?;
        s.lat_sum = r.u128()?;
        s.lat_max = r.u64()?;
        s.lat_hist.clear();
        for _ in 0..r.usize()? {
            let lat = r.u64()?;
            let count = r.u64()?;
            s.lat_hist.insert(lat, count);
        }
        s.bytes = r.u64()?;
        s.by_hops.clear();
        for _ in 0..r.usize()? {
            let hops = r.u32()?;
            let h = HopStats {
                count: r.u64()?,
                lat_sum: r.u128()?,
                queue_sum: r.u128()?,
                switch_sum: r.u128()?,
                bus_sum: r.u128()?,
                device_sum: r.u128()?,
            };
            s.by_hops.insert(hops, h);
        }
        s.cache_hit_completions = r.u64()?;
        s.bisnp_received = r.u64()?;
        s.lines_invalidated = r.u64()?;
        s.dirty_writebacks = r.u64()?;
        s.window_marks.clear();
        for _ in 0..r.usize()? {
            s.window_marks.push(r.u64()?);
        }
        Ok(())
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

const TIMER_LOCAL_HIT: u64 = 1;

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RequesterCfg {
        RequesterCfg::new(0, vec![1, 2, 3, 4])
    }

    #[test]
    fn line_interleave_rotates_endpoints() {
        let r = Requester::new(cfg());
        assert_eq!(r.endpoint_of(0), 1);
        assert_eq!(r.endpoint_of(64), 2);
        assert_eq!(r.endpoint_of(128), 3);
        assert_eq!(r.endpoint_of(192), 4);
        assert_eq!(r.endpoint_of(256), 1);
    }

    #[test]
    fn page_interleave_groups_lines() {
        let mut c = cfg();
        c.interleave = Interleave::Page(64); // 4KiB pages
        let r = Requester::new(c);
        assert_eq!(r.endpoint_of(0), 1);
        assert_eq!(r.endpoint_of(63 * 64), 1);
        assert_eq!(r.endpoint_of(64 * 64), 2);
    }

    #[test]
    fn fixed_interleave_pins_endpoint() {
        let mut c = cfg();
        c.interleave = Interleave::Fixed(2);
        let r = Requester::new(c);
        for a in [0u64, 64, 4096, 1 << 20] {
            assert_eq!(r.endpoint_of(a), 3);
        }
    }

    #[test]
    fn stream_pattern_is_sequential() {
        let mut c = cfg();
        c.pattern = Pattern::Stream;
        c.read_ratio = 1.0;
        let mut r = Requester::new(c);
        let a0 = r.next_op().0;
        let a1 = r.next_op().0;
        let a2 = r.next_op().0;
        assert_eq!((a0, a1, a2), (0, 64, 128));
    }

    #[test]
    fn skewed_pattern_respects_hot_fraction() {
        let mut c = cfg();
        c.pattern = Pattern::Skewed {
            hot_frac: 0.1,
            hot_prob: 0.9,
        };
        c.footprint_lines = 1000;
        let mut r = Requester::new(c);
        let mut hot = 0;
        let n = 10_000;
        for _ in 0..n {
            let (addr, _) = r.next_op();
            if addr / CACHELINE < 100 {
                hot += 1;
            }
        }
        let frac = hot as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.03, "hot fraction {frac}");
    }

    #[test]
    fn zipf_pattern_is_head_heavy_and_deterministic() {
        let mut c = cfg();
        c.pattern = Pattern::Zipf { theta: 0.99 };
        c.footprint_lines = 1000;
        let mut r = Requester::new(c.clone());
        let mut head = 0;
        let n = 10_000;
        let first: Vec<u64> = (0..n)
            .map(|_| {
                let (addr, _) = r.next_op();
                if addr / CACHELINE < 10 {
                    head += 1;
                }
                addr
            })
            .collect();
        // top-10 of 1000 lines draw a large share under theta=0.99
        let frac = head as f64 / n as f64;
        assert!(frac > 0.25, "zipf head fraction {frac}");
        // footprint respected
        assert!(first.iter().all(|a| a / CACHELINE < 1000));
        // same cfg -> same stream
        let mut r2 = Requester::new(c);
        let second: Vec<u64> = (0..n).map(|_| r2.next_op().0).collect();
        assert_eq!(first, second);
    }

    #[test]
    fn pointer_chase_is_dependent_and_spread_out() {
        let mut c = cfg();
        c.pattern = Pattern::PointerChase;
        c.footprint_lines = 1 << 14;
        let mut r = Requester::new(c.clone());
        let addrs: Vec<u64> = (0..10_000).map(|_| r.next_op().0).collect();
        // no short-period cycles, near-uniform coverage
        let mut distinct: Vec<u64> = addrs.clone();
        distinct.sort_unstable();
        distinct.dedup();
        assert!(distinct.len() > 7000, "chase revisits too much: {}", distinct.len());
        assert!(addrs.iter().all(|a| a / CACHELINE < (1 << 14)));
        // deterministic given the seed, and seed-sensitive
        let mut r2 = Requester::new(c.clone());
        assert_eq!(addrs[..100], (0..100).map(|_| r2.next_op().0).collect::<Vec<_>>()[..]);
        c.seed ^= 1;
        let mut r3 = Requester::new(c);
        let other: Vec<u64> = (0..100).map(|_| r3.next_op().0).collect();
        assert_ne!(addrs[..100], other[..]);
    }

    #[test]
    fn trace_pattern_replays_ops() {
        let mut c = cfg();
        c.pattern = Pattern::Trace(Arc::new(vec![
            TraceOp {
                addr: 0x40,
                is_write: false,
                gap_ps: 0,
            },
            TraceOp {
                addr: 0x80,
                is_write: true,
                gap_ps: 0,
            },
        ]));
        let mut r = Requester::new(c);
        assert_eq!(r.next_op(), (0x40, false));
        assert_eq!(r.next_op(), (0x80, true));
        assert_eq!(r.next_op(), (0x40, false)); // cycles
    }

    #[test]
    fn read_ratio_statistics() {
        let mut c = cfg();
        c.read_ratio = 0.75;
        let mut r = Requester::new(c);
        let writes = (0..10_000).filter(|_| r.next_op().1).count();
        let frac = writes as f64 / 10_000.0;
        assert!((frac - 0.25).abs() < 0.03, "write fraction {frac}");
    }
}
