//! The device layer (paper §III): computational components (requesters),
//! PBR switches, memory endpoints with pluggable media backends, the
//! requester-side coherent cache, and the device-side inclusive snoop
//! filter (the DCOH example for device-managed coherence).
//!
//! Buses are modelled as passive link state in `interconnect::links` (see
//! that module for why), so there is no bus component here; everything
//! else the paper's Fig 4 shows is.

pub mod cache;
pub mod memdev;
pub mod requester;
pub mod snoop_filter;
pub mod switch;

pub use cache::{Access, Cache, LineMeta};
pub use memdev::{FixedBackend, MemBackend, MemDev, MemDevCfg, MemStats};
pub use requester::{Interleave, Pattern, ReqStats, Requester, RequesterCfg};
pub use snoop_filter::{SfStats, SnoopFilter, Victim, VictimPolicy};
pub use switch::{Switch, SwitchCfg, SwitchStats};
