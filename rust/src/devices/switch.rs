//! CXL PBR switch (paper §III-C).
//!
//! The switch derives its internal routing table from the interconnect
//! layer's shortest-path information (the `Routing` table in `Shared`),
//! then forwards each arriving packet toward its destination edge port.
//! Output-port contention and queuing are modelled at the egress link
//! (`interconnect::links`), which is where the port's serialization
//! bandwidth lives; the switch itself charges its switching time plus the
//! PCIe port delay.
//!
//! In PBR terms every node id is an edge-port id (12-bit in CXL 3.1, i.e.
//! up to 4096 edge ports — far above anything we instantiate).

use crate::engine::time::{ns, Ps};
use crate::engine::{Component, Payload, Shared};
use crate::proto::NodeId;
use std::any::Any;

#[derive(Clone, Copy, Debug)]
pub struct SwitchCfg {
    pub id: NodeId,
    /// Table III "Switching time": 20 ns.
    pub switching_time: Ps,
    /// Table III "PCIe port delay": 25 ns, charged per switch traversal.
    pub port_delay: Ps,
}

impl SwitchCfg {
    pub fn new(id: NodeId) -> SwitchCfg {
        SwitchCfg {
            id,
            switching_time: ns(20.0),
            port_delay: ns(25.0),
        }
    }
}

#[derive(Default, Clone, Copy, Debug)]
pub struct SwitchStats {
    pub forwarded: u64,
}

pub struct Switch {
    cfg: SwitchCfg,
    pub stats: SwitchStats,
}

impl Switch {
    pub fn new(cfg: SwitchCfg) -> Switch {
        Switch {
            cfg,
            stats: SwitchStats::default(),
        }
    }
}

impl Component for Switch {
    fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
        if let Payload::Packet(mut pkt) = payload {
            debug_assert_ne!(pkt.dst, self.cfg.id, "switch is not an endpoint");
            if ctx.collecting {
                self.stats.forwarded += 1;
            }
            let hop_cost = self.cfg.switching_time + self.cfg.port_delay;
            pkt.breakdown.switch_ps += hop_cost;
            ctx.forward_boxed(pkt, hop_cost);
        }
    }

    fn snapshot(&self, w: &mut crate::util::snap::SnapWriter) {
        w.u64(self.stats.forwarded);
    }

    fn restore(&mut self, r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        self.stats.forwarded = r.u64()?;
        Ok(())
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{time::NS, Engine};
    use crate::interconnect::{LinkCfg, NodeKind, Routing, Strategy, Topology};
    use crate::proto::{Opcode, Packet};

    /// Sink endpoint that records arrival times.
    struct Sink {
        got: Vec<(Ps, u32)>,
    }
    impl Component for Sink {
        fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
            if let Payload::Packet(p) = payload {
                self.got.push((ctx.now, p.breakdown.hops));
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    /// Source that fires one read at t=0.
    struct Src {
        id: NodeId,
        dst: NodeId,
    }
    impl Component for Src {
        fn start(&mut self, ctx: &mut Shared) {
            ctx.after(0, self.id, Payload::Timer(0, 0));
        }
        fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
            if let Payload::Timer(..) = payload {
                let id = ctx.txn_id();
                let pkt = Packet::request(id, Opcode::MemRd, self.id, self.dst, 0, ctx.now);
                ctx.forward(pkt, 0);
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    #[test]
    fn switch_charges_latency_and_counts_hops() {
        let mut t = Topology::new();
        let r = t.add_node("r", NodeKind::Requester);
        let s = t.add_node("s", NodeKind::Switch);
        let m = t.add_node("m", NodeKind::Memory);
        let link = LinkCfg {
            bandwidth_gbps: 0.0, // isolate latency terms
            latency: NS,
            duplex: crate::interconnect::Duplex::Full,
            turnaround: 0,
            header_bytes: 0,
        };
        t.add_link(r, s, link);
        t.add_link(s, m, link);
        let routing = Routing::build_bfs(&t);
        let mut e = Engine::new(Shared::new(t, routing, Strategy::Oblivious));
        e.register(Box::new(Src { id: r, dst: m }));
        e.register(Box::new(Switch::new(SwitchCfg::new(s))));
        e.register(Box::new(Sink { got: vec![] }));
        e.run(100);
        let sink = e.component::<Sink>(m).unwrap();
        // 1ns link + (20+25)ns switch + 1ns link = 47ns, 2 hops.
        assert_eq!(sink.got, vec![(47 * NS, 2)]);
        assert_eq!(e.component::<Switch>(s).unwrap().stats.forwarded, 1);
    }
}
