//! Parallel sweep subsystem: run independent scenarios across threads.
//!
//! The paper's evaluation (§IV–§V) is a grid of independent simulations —
//! topology x scale x R:W mix x routing strategy. Each simulation is a
//! share-nothing deterministic `Engine`, so a batch of them is
//! embarrassingly parallel by construction. This module provides:
//!
//!  * [`run_sweep`] / [`map_sweep`] — the generic batch driver: shard a
//!    list of closures across `--jobs N` worker threads (0 = all available
//!    cores) and collect results **in submission order**, so output is
//!    byte-identical regardless of worker interleaving. Every experiment
//!    harness (`experiments::*`) expresses its config grid as data handed
//!    to this driver.
//!  * [`Scenario`] / [`GridSpec`] — a JSON-configurable scenario grid
//!    (cartesian product of axis values over a base `SystemCfg`) behind
//!    the `esf sweep --config <grid.json> [--jobs N]` CLI command.
//!
//! Determinism contract: a worker thread only runs a scenario's closure
//! and writes its result into the slot reserved at submission; nothing
//! about scheduling can leak into results, and `--jobs 1` vs `--jobs 8`
//! produce identical tables (covered by unit + integration tests).

use crate::config::{build_system, BackendKind, System, SystemCfg};
use crate::devices::{Pattern, VictimPolicy};
use crate::dram::DramCfg;
use crate::engine::parallel::BarrierMode;
use crate::engine::snapshot::SnapMeta;
use crate::engine::time::ns;
use crate::interconnect::{Duplex, Strategy, TopologyKind, WeightModel};
use crate::metrics::{aggregate, latency_dist};
use crate::ssd::SsdCfg;
use crate::util::json::Json;
use crate::util::table::{f, Table};
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

pub mod cache;

pub use cache::{scenario_key, SweepCache};

/// Worker count for `--jobs 0` / unspecified: all available cores.
pub fn available_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a requested `--jobs` value: 0 means auto (available cores).
pub fn resolve_jobs(requested: usize) -> usize {
    if requested == 0 {
        available_jobs()
    } else {
        requested
    }
}

/// Share one thread budget between across-scenario (`--jobs`) and
/// intra-scenario (`--intra-jobs`) parallelism so the product can never
/// oversubscribe the machine: the intra request wins its full width
/// (clamped to the budget) and the across width is clipped to
/// `budget / intra` (floor, min 1). Zeros mean auto — `jobs 0` takes
/// whatever the clip allows, `intra 0` takes the whole budget (maximally
/// parallel single scenarios). With `intra_jobs <= 1` there is nothing
/// to share and an explicit `--jobs N` is honored verbatim, exactly as
/// in the pre-intra-jobs sweep driver (deliberate oversubscription of
/// across-scenario workers stays possible).
///
/// The split is a pure function of its three arguments — `jobs == 0`
/// fills from `budget`, never from a live core probe — and degrades
/// deterministically at the edges: a zero budget clamps to 1 and yields
/// `(1, 1)` under autos, `intra_jobs > budget` serializes the across
/// dimension to `(1, budget)`, and no share is ever zero (pinned by the
/// exhaustive small-value grid test below).
pub fn split_thread_budget(jobs: usize, intra_jobs: usize, budget: usize) -> (usize, usize) {
    let budget = budget.max(1);
    if intra_jobs == 1 {
        return (if jobs == 0 { budget } else { jobs }, 1);
    }
    let intra = if intra_jobs == 0 {
        budget
    } else {
        intra_jobs.min(budget)
    };
    let across = if jobs == 0 { budget } else { jobs };
    let across = across.min((budget / intra).max(1));
    (across, intra)
}

/// Run every task, sharded over `jobs` worker threads (0 = auto), and
/// return the results in submission order.
///
/// Tasks are claimed from a shared cursor, so long and short scenarios
/// load-balance; each result is written into the slot reserved for its
/// task at submission, which keeps output deterministic regardless of
/// completion order. A panicking task propagates the panic to the caller
/// once the scope joins.
pub fn run_sweep<T, F>(tasks: Vec<F>, jobs: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = tasks.len();
    let jobs = resolve_jobs(jobs).min(n.max(1));
    if jobs <= 1 {
        return tasks.into_iter().map(|task| task()).collect();
    }
    let slots: Vec<Mutex<Option<F>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let task = slots[i]
                    .lock()
                    .expect("sweep task lock")
                    .take()
                    .expect("each task is claimed exactly once");
                let out = task();
                *results[i].lock().expect("sweep result lock") = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("sweep result lock")
                .expect("every slot is filled when the scope joins")
        })
        .collect()
}

/// [`run_sweep`] over a list of inputs with one shared function — the
/// shape every experiment grid uses.
pub fn map_sweep<I, T, F>(items: Vec<I>, jobs: usize, func: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(I) -> T + Send + Sync,
{
    let func = &func;
    let tasks: Vec<_> = items.into_iter().map(|item| move || func(item)).collect();
    run_sweep(tasks, jobs)
}

// ----------------------------------------------------- scenario grids

/// One fully-specified simulation in a sweep.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub label: String,
    pub cfg: SystemCfg,
}

/// Aggregate results of one scenario (submission-ordered in the output).
/// Percentiles are exact nearest-rank values from the recorded latency
/// histogram (`metrics::LatencyDist`).
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub label: String,
    pub events: u64,
    pub completed: u64,
    pub bandwidth_gbps: f64,
    pub avg_latency_ns: f64,
    pub max_latency_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub dropped: u64,
}

impl ScenarioResult {
    /// Canonical JSON for the machine-readable dump and the result cache.
    /// Counters are exact (integers < 2^53) and floats serialize
    /// shortest-roundtrip, so `from_json(to_json(r))` is lossless.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("events", Json::Num(self.events as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("bandwidth_gbps", Json::Num(self.bandwidth_gbps)),
            ("avg_latency_ns", Json::Num(self.avg_latency_ns)),
            ("max_latency_ns", Json::Num(self.max_latency_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p95_ns", Json::Num(self.p95_ns)),
            ("p99_ns", Json::Num(self.p99_ns)),
            ("dropped", Json::Num(self.dropped as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ScenarioResult> {
        let need_u64 = |k: &str| {
            j.get(k)
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("scenario result missing integer field '{k}'"))
        };
        let need_f64 = |k: &str| {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("scenario result missing number field '{k}'"))
        };
        Ok(ScenarioResult {
            label: j
                .get("label")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("scenario result missing 'label'"))?
                .to_string(),
            events: need_u64("events")?,
            completed: need_u64("completed")?,
            bandwidth_gbps: need_f64("bandwidth_gbps")?,
            avg_latency_ns: need_f64("avg_latency_ns")?,
            max_latency_ns: need_f64("max_latency_ns")?,
            p50_ns: need_f64("p50_ns")?,
            p95_ns: need_f64("p95_ns")?,
            p99_ns: need_f64("p99_ns")?,
            dropped: need_u64("dropped")?,
        })
    }
}

/// Build + run one scenario to completion and extract aggregates
/// (sequential engine).
pub fn run_scenario(sc: &Scenario) -> ScenarioResult {
    run_scenario_intra(sc, 1)
}

/// Build + run one scenario on `intra_jobs` worker threads through the
/// partitioned event-domain engine (byte-identical to `intra_jobs = 1`;
/// see `tests/partition.rs`).
pub fn run_scenario_intra(sc: &Scenario, intra_jobs: usize) -> ScenarioResult {
    run_scenario_intra_mode(sc, intra_jobs, BarrierMode::default())
}

/// [`run_scenario_intra`] with an explicit barrier mode (`esf run/sweep
/// --barrier {adaptive|fixed|speculative}`). Every mode is byte-identical
/// — the cache key deliberately excludes the mode, exactly like
/// `intra_jobs`, because it is a pure parallelism knob.
pub fn run_scenario_intra_mode(
    sc: &Scenario,
    intra_jobs: usize,
    mode: BarrierMode,
) -> ScenarioResult {
    let mut sys = build_system(&sc.cfg);
    let events = if intra_jobs == 1 {
        sys.engine.run(u64::MAX)
    } else {
        sys.engine
            .run_partitioned_opts(intra_jobs, WeightModel::Traffic, mode)
    };
    scenario_result(&sc.label, events, &sys)
}

/// Extract a finished system's aggregates into a [`ScenarioResult`].
fn scenario_result(label: &str, events: u64, sys: &System) -> ScenarioResult {
    let a = aggregate(sys);
    let dist = latency_dist(sys);
    ScenarioResult {
        label: label.to_string(),
        events,
        completed: a.completed,
        bandwidth_gbps: a.bandwidth_gbps(),
        avg_latency_ns: a.avg_latency_ns(),
        max_latency_ns: a.lat_max_ns,
        p50_ns: dist.percentile_ns(0.50),
        p95_ns: dist.percentile_ns(0.95),
        p99_ns: dist.percentile_ns(0.99),
        dropped: sys.engine.shared.dropped,
    }
}

/// Run one scenario from a shared quiescent warm-up snapshot instead of
/// simulating its prefix: build the full-config system, splice in the
/// donor's state at the warm-up boundary ([`crate::engine::Engine::restore`]),
/// and continue to completion. Output is byte-identical to a cold
/// [`run_scenario_intra`] of the same config — the engine's
/// restore-then-run contract plus the forced-read warm-up gate
/// (requesters draw but discard the write coin until collection starts),
/// pinned end-to-end by `tests/checkpoint.rs`.
fn run_scenario_warm(
    sc: &Scenario,
    intra_jobs: usize,
    mode: BarrierMode,
    snap: &[u8],
) -> Result<ScenarioResult> {
    let mut sys = build_system(&sc.cfg);
    let hdr = sys.engine.restore(snap).map_err(|e| anyhow!(e))?;
    if !hdr.quiescent {
        bail!("warm-start snapshot is not quiescent");
    }
    if intra_jobs == 1 {
        sys.engine.run(u64::MAX);
    } else {
        sys.engine
            .run_partitioned_opts(intra_jobs, WeightModel::Traffic, mode);
    }
    // The donor prefix's event count rides in the snapshot
    // (`events_processed` round-trips), so the reported total matches a
    // cold run exactly — `run()`'s return value alone would only count
    // post-restore events.
    Ok(scenario_result(&sc.label, sys.engine.events_processed, &sys))
}

/// Shared warm-up prefix snapshots for one cached sweep run.
///
/// Planning groups the grid by warm-up prefix projection
/// ([`SystemCfg::prefix_fingerprint`]); a group of two or more distinct
/// configs with a non-empty warm-up shares one quiescent snapshot: the
/// first worker that needs it loads it from the cache directory (or
/// simulates the prefix once and persists it as
/// `<prefix_fp>.snap`), and every member forks from the bytes instead
/// of re-simulating the prefix. Warm-start is purely a wall-clock
/// optimization: forked output is byte-identical to a cold run, and any
/// failure (torn file, foreign snapshot, restore mismatch) degrades to
/// a cold run instead of an error.
struct WarmStart<'a> {
    cache: &'a SweepCache,
    /// prefix fingerprint -> lazily built snapshot, one slot per group
    /// worth sharing; a missing key means "run cold" (singleton group
    /// or no warm-up). The slot mutex intentionally serializes a
    /// group's first build — its members need those bytes anyway —
    /// while other groups proceed on their own slots.
    groups: BTreeMap<u64, Mutex<Option<Arc<Vec<u8>>>>>,
}

impl<'a> WarmStart<'a> {
    fn plan(scenarios: &[Scenario], cache: &'a SweepCache) -> WarmStart<'a> {
        let mut members: BTreeMap<u64, BTreeSet<u64>> = BTreeMap::new();
        for sc in scenarios {
            if sc.cfg.warmup_requests() == 0 {
                continue;
            }
            members
                .entry(sc.cfg.prefix_fingerprint())
                .or_default()
                .insert(sc.cfg.fingerprint());
        }
        let groups = members
            .into_iter()
            .filter(|(_, cfgs)| cfgs.len() >= 2)
            .map(|(fp, _)| (fp, Mutex::new(None)))
            .collect();
        WarmStart { cache, groups }
    }

    /// Run one scenario, forking from its group's shared snapshot when
    /// the prefix is shared.
    fn run(&self, sc: &Scenario, intra: usize, mode: BarrierMode, tag: usize) -> ScenarioResult {
        let Some(slot) = self.groups.get(&sc.cfg.prefix_fingerprint()) else {
            return run_scenario_intra_mode(sc, intra, mode);
        };
        let snap = {
            let mut slot = slot.lock().expect("warm-start snapshot lock");
            match &*slot {
                Some(bytes) => Arc::clone(bytes),
                None => {
                    let bytes = Arc::new(self.obtain(&sc.cfg, tag));
                    *slot = Some(Arc::clone(&bytes));
                    bytes
                }
            }
        };
        match run_scenario_warm(sc, intra, mode, &snap) {
            Ok(r) => r,
            Err(e) => {
                eprintln!(
                    "esf: warm-start fork for '{}' failed ({e}); rerunning cold",
                    sc.label
                );
                run_scenario_intra_mode(sc, intra, mode)
            }
        }
    }

    /// Load the group's snapshot from the cache directory, or simulate
    /// the prefix once and persist it. A cached file is trusted only
    /// after [`crate::check::check_snapshot`] proves integrity (embedded
    /// digest) and fork-compatibility (prefix projection + quiescence)
    /// against this scenario's config; anything else is rebuilt and
    /// overwritten.
    fn obtain(&self, cfg: &SystemCfg, tag: usize) -> Vec<u8> {
        let fp = cfg.prefix_fingerprint();
        if let Some(bytes) = self.cache.load_snapshot(fp) {
            if crate::check::check_snapshot(&bytes, Some(cfg)).is_empty() {
                return bytes;
            }
        }
        let prefix = cfg.prefix_cfg();
        let mut sys = build_system(&prefix);
        sys.engine.run_until_collecting();
        let meta = SnapMeta {
            cfg_fingerprint: prefix.fingerprint(),
            prefix_fingerprint: fp,
            prefix_canon: cfg.prefix_canon(),
            quiescent: true,
        };
        let bytes = sys.engine.snapshot(&meta);
        if let Err(e) = self.cache.store_snapshot(fp, &bytes, tag) {
            eprintln!("esf: warm-start snapshot write failed ({e}); continuing in-memory");
        }
        bytes
    }
}

/// Run a scenario batch through the sweep driver.
pub fn run_scenarios(scenarios: Vec<Scenario>, jobs: usize) -> Vec<ScenarioResult> {
    run_scenarios_opts(scenarios, jobs, 1)
}

/// Run a scenario batch with both parallelism dimensions: `jobs` worker
/// threads across scenarios, `intra_jobs` threads inside each scenario
/// (the partitioned engine). The two share one machine budget through
/// [`split_thread_budget`], so `--jobs N --intra-jobs M` can never
/// oversubscribe; output is byte-identical for every combination.
pub fn run_scenarios_opts(
    scenarios: Vec<Scenario>,
    jobs: usize,
    intra_jobs: usize,
) -> Vec<ScenarioResult> {
    run_scenarios_opts_mode(scenarios, jobs, intra_jobs, BarrierMode::default())
}

/// [`run_scenarios_opts`] with an explicit intra-scenario barrier mode.
pub fn run_scenarios_opts_mode(
    scenarios: Vec<Scenario>,
    jobs: usize,
    intra_jobs: usize,
    mode: BarrierMode,
) -> Vec<ScenarioResult> {
    run_scenarios_streaming(
        scenarios,
        jobs,
        intra_jobs,
        mode,
        available_jobs(),
        None,
        |_| {},
    )
}

/// One finished sweep cell, reported the moment it completes.
///
/// Updates arrive in **completion** order (whatever the worker
/// interleaving produced), not submission order — `index` says where the
/// cell belongs in the final table. The assembled return value of
/// [`run_scenarios_streaming`] stays submission-ordered and
/// byte-identical regardless, so streaming consumers (the `esfd` attach
/// path) can show progress early and still reconstruct the exact
/// one-shot output by slotting rows at their indices.
#[derive(Clone, Debug)]
pub struct CellUpdate {
    /// Submission-order position of this cell in the grid.
    pub index: usize,
    /// Total cell count of the grid (constant across updates).
    pub total: usize,
    /// True when the result was served from the sweep cache without
    /// re-simulation.
    pub cached: bool,
    pub result: ScenarioResult,
}

/// The sweep execution core: run a scenario batch with an explicit
/// thread `budget`, optional result `cache`, and a per-cell completion
/// callback — every other `run_scenarios*` entry point is this with a
/// no-op callback and `budget = available_jobs()`.
///
/// `jobs`/`intra_jobs` split `budget` through [`split_thread_budget`];
/// passing an explicit budget (instead of probing cores here) is what
/// lets the `esfd` admission controller hand each concurrent job a slice
/// of one machine-wide budget. `on_cell` fires exactly once per cell,
/// concurrently from worker threads (hence `Sync`), and must not assume
/// submission order. With a cache, hits skip simulation entirely
/// (`cached = true`) and misses run through [`WarmStart`] prefix
/// sharing, exactly like [`run_scenarios_cached_opts_mode`].
pub fn run_scenarios_streaming<F>(
    scenarios: Vec<Scenario>,
    jobs: usize,
    intra_jobs: usize,
    mode: BarrierMode,
    budget: usize,
    cache: Option<&SweepCache>,
    on_cell: F,
) -> Vec<ScenarioResult>
where
    F: Fn(CellUpdate) + Send + Sync,
{
    let (across, intra) = split_thread_budget(jobs, intra_jobs, budget);
    let total = scenarios.len();
    let warm = cache.map(|c| WarmStart::plan(&scenarios, c));
    let warm = warm.as_ref();
    let on_cell = &on_cell;
    let items: Vec<(usize, Scenario)> = scenarios.into_iter().enumerate().collect();
    map_sweep(items, across, move |(idx, sc)| {
        let (result, cached) = match cache {
            None => (run_scenario_intra_mode(&sc, intra, mode), false),
            Some(cache) => {
                let (hash, canon) = scenario_key(&sc.cfg);
                match cache.load(hash, &canon) {
                    Some(mut r) => {
                        r.label = sc.label.clone();
                        (r, true)
                    }
                    None => {
                        let r = match warm {
                            Some(w) => w.run(&sc, intra, mode, idx),
                            None => run_scenario_intra_mode(&sc, intra, mode),
                        };
                        if let Err(e) = cache.store(hash, &canon, &r, idx) {
                            eprintln!("esf: sweep cache write failed ({e}); continuing uncached");
                        }
                        (r, false)
                    }
                }
            }
        };
        on_cell(CellUpdate {
            index: idx,
            total,
            cached,
            result: result.clone(),
        });
        result
    })
}

/// Run a scenario batch with result caching: finished cells are loaded
/// from `cache` instead of re-simulating, and newly computed results are
/// persisted as they complete. Output is byte-identical to an uncached
/// run — cells round-trip losslessly and the cached label is replaced by
/// the current scenario's (the same config may carry different labels in
/// different grids).
pub fn run_scenarios_cached(
    scenarios: Vec<Scenario>,
    jobs: usize,
    cache: &SweepCache,
) -> Vec<ScenarioResult> {
    run_scenarios_cached_opts(scenarios, jobs, 1, cache)
}

/// [`run_scenarios_cached`] with intra-scenario parallelism. The cache
/// key excludes `intra_jobs` (results are byte-identical at any width),
/// so cells written by a sequential run are hit by partitioned runs and
/// vice versa.
///
/// Cells that miss the result cache run through [`WarmStart`]: scenarios
/// sharing a warm-up prefix projection fork from one shared quiescent
/// snapshot (persisted beside the cells as `<prefix_fp>.snap`) instead
/// of each re-simulating the prefix. Output stays byte-identical to an
/// uncached run.
pub fn run_scenarios_cached_opts(
    scenarios: Vec<Scenario>,
    jobs: usize,
    intra_jobs: usize,
    cache: &SweepCache,
) -> Vec<ScenarioResult> {
    run_scenarios_cached_opts_mode(scenarios, jobs, intra_jobs, BarrierMode::default(), cache)
}

/// [`run_scenarios_cached_opts`] with an explicit intra-scenario barrier
/// mode. Like `intra_jobs`, the mode is excluded from the cache key:
/// every mode is byte-identical, so cells written under one barrier are
/// hit by runs under any other.
pub fn run_scenarios_cached_opts_mode(
    scenarios: Vec<Scenario>,
    jobs: usize,
    intra_jobs: usize,
    mode: BarrierMode,
    cache: &SweepCache,
) -> Vec<ScenarioResult> {
    run_scenarios_streaming(
        scenarios,
        jobs,
        intra_jobs,
        mode,
        available_jobs(),
        Some(cache),
        |_| {},
    )
}

/// Render scenario results as one table (the `esf sweep` output).
pub fn results_table(results: &[ScenarioResult]) -> Table {
    let mut t = Table::new(
        "Sweep results",
        &[
            "scenario",
            "events",
            "completed",
            "bw GB/s",
            "avg lat ns",
            "p50 ns",
            "p95 ns",
            "p99 ns",
            "max lat ns",
            "dropped",
        ],
    );
    for r in results {
        t.row(&[
            r.label.clone(),
            r.events.to_string(),
            r.completed.to_string(),
            f(r.bandwidth_gbps),
            f(r.avg_latency_ns),
            f(r.p50_ns),
            f(r.p95_ns),
            f(r.p99_ns),
            f(r.max_latency_ns),
            r.dropped.to_string(),
        ]);
    }
    t
}

/// Machine-readable result dump (`esf sweep --json <path>`): canonical
/// JSON, scenarios in submission order — byte-stable across job counts
/// and across fresh vs cache-resumed runs.
pub fn results_json(results: &[ScenarioResult]) -> Json {
    Json::obj(vec![
        ("schema", Json::Str("esf-sweep-results/1".into())),
        (
            "scenarios",
            Json::Arr(results.iter().map(ScenarioResult::to_json).collect()),
        ),
    ])
}

/// A JSON-configured scenario grid:
///
/// ```json
/// {
///   "jobs": 4,
///   "base": { ... any `esf run --config` system object ... },
///   "sweep": {
///     "topology": ["chain", "ring", "spine-leaf"],
///     "scale": [8, 16],
///     "read_ratio": [1.0, 0.5]
///   }
/// }
/// ```
///
/// Scenarios are the cartesian product of the axis values applied over the
/// base config: axes combine in alphabetical key order with the last axis
/// varying fastest, so the expansion order (and therefore the output
/// order) is deterministic.
pub struct GridSpec {
    pub scenarios: Vec<Scenario>,
    /// Default worker count from the file (0 = auto); the CLI `--jobs`
    /// flag overrides it.
    pub jobs: usize,
    /// Default intra-scenario worker count from the file (1 = sequential
    /// engine, 0 = all cores); the CLI `--intra-jobs` flag overrides it.
    /// Shares the machine budget with `jobs` via [`split_thread_budget`].
    pub intra_jobs: usize,
}

/// Axes `"sweep"` accepts, mapped onto `SystemCfg` fields.
/// `pub(crate)` so `check::grid` can validate axis names and values
/// without expanding the grid.
pub(crate) const AXES: &[&str] = &[
    "topology",
    "scale",
    "read_ratio",
    "routing",
    "duplex",
    "bandwidth_gbps",
    "header_bytes",
    "turnaround_ns",
    "issue_interval_ns",
    "queue_capacity",
    "requests_per_endpoint",
    "seed",
    "pattern",
    "backend",
    "sf_policy",
    "sf_capacity",
    "cache_lines",
];

/// Parse an `sf_policy` axis value; BlockLen keeps a previously
/// configured `max_len` (from the base config or an earlier axis).
fn parse_sf_policy(name: &str, prev: Option<(usize, VictimPolicy)>) -> Result<VictimPolicy> {
    Ok(match name {
        "fifo" => VictimPolicy::Fifo,
        "lru" => VictimPolicy::Lru,
        "lfi" => VictimPolicy::Lfi,
        "lifo" => VictimPolicy::Lifo,
        "mru" => VictimPolicy::Mru,
        "blocklen" => VictimPolicy::BlockLen {
            max_len: match prev {
                Some((_, VictimPolicy::BlockLen { max_len })) => max_len,
                _ => 4,
            },
        },
        other => bail!(
            "sweep axis 'sf_policy': unknown policy '{other}' \
             (supported: none, fifo, lru, lfi, lifo, mru, blocklen)"
        ),
    })
}

fn axis_f64(key: &str, v: &Json) -> Result<f64> {
    v.as_f64()
        .ok_or_else(|| anyhow!("sweep axis '{key}': expected a number, got {v}"))
}

fn axis_str<'a>(key: &str, v: &'a Json) -> Result<&'a str> {
    v.as_str()
        .ok_or_else(|| anyhow!("sweep axis '{key}': expected a string, got {v}"))
}

/// Apply one axis value to a scenario config. `pub(crate)` so
/// `check::grid` can probe each value in isolation and report the exact
/// failing `$.sweep.<axis>[i]` path.
pub(crate) fn apply_axis(cfg: &mut SystemCfg, key: &str, v: &Json) -> Result<()> {
    match key {
        "topology" => {
            let name = axis_str(key, v)?;
            cfg.topology = TopologyKind::parse(name)
                .ok_or_else(|| anyhow!("sweep axis 'topology': unknown kind '{name}'"))?;
        }
        // "system scale = 2N" (N requesters + N memories), as in the
        // `esf run --config` schema.
        "scale" => cfg.n = ((axis_f64(key, v)? as usize).max(2) / 2).max(1),
        "read_ratio" => cfg.read_ratio = axis_f64(key, v)?,
        "routing" => {
            cfg.strategy = match axis_str(key, v)? {
                "adaptive" => Strategy::Adaptive,
                "oblivious" => Strategy::Oblivious,
                other => bail!("sweep axis 'routing': unknown strategy '{other}'"),
            }
        }
        "duplex" => {
            cfg.link.duplex = match axis_str(key, v)? {
                "full" => Duplex::Full,
                "half" => Duplex::Half,
                other => bail!("sweep axis 'duplex': unknown mode '{other}'"),
            }
        }
        "bandwidth_gbps" => cfg.link.bandwidth_gbps = axis_f64(key, v)?,
        "header_bytes" => cfg.link.header_bytes = axis_f64(key, v)? as u64,
        "turnaround_ns" => cfg.link.turnaround = ns(axis_f64(key, v)?),
        "issue_interval_ns" => cfg.issue_interval = ns(axis_f64(key, v)?),
        "queue_capacity" => cfg.queue_capacity = axis_f64(key, v)? as usize,
        "requests_per_endpoint" => cfg.requests_per_endpoint = axis_f64(key, v)? as u64,
        "seed" => cfg.seed = axis_f64(key, v)? as u64,
        // Access pattern (paper workload characters; zipfian/pointer-chase
        // follow the `workloads` generators' structure).
        "pattern" => {
            cfg.pattern = match axis_str(key, v)? {
                "sequential" | "stream" => Pattern::Stream,
                "random" | "uniform" | "uniform-random" => Pattern::Random,
                "zipfian" | "zipf" => Pattern::Zipf { theta: 0.99 },
                "pointer-chase" | "chase" => Pattern::PointerChase,
                "skewed" => Pattern::Skewed {
                    hot_frac: 0.1,
                    hot_prob: 0.9,
                },
                other => bail!(
                    "sweep axis 'pattern': unknown pattern '{other}' (supported: \
                     sequential, random, zipfian, pointer-chase, skewed)"
                ),
            }
        }
        // Media backend under the endpoint controller (DRAMsim3/SimpleSSD
        // substitutes from `dram/` + `ssd/`).
        "backend" => {
            cfg.backend = match axis_str(key, v)? {
                "fixed" => BackendKind::Fixed(45.0),
                "dram" | "ddr5" => BackendKind::Dram(DramCfg::ddr5_4800()),
                "hbm" | "hbm2" => BackendKind::Dram(DramCfg::hbm2()),
                "ssd" => BackendKind::Ssd(SsdCfg::default()),
                other => bail!(
                    "sweep axis 'backend': unknown backend '{other}' \
                     (supported: fixed, dram, hbm, ssd)"
                ),
            }
        }
        // DCOH snoop-filter victim policy; "none" disables device-managed
        // coherence entirely. Capacity comes from the base config, an
        // `sf_capacity` axis, or defaults to 1024.
        "sf_policy" => {
            let name = axis_str(key, v)?;
            if name == "none" {
                cfg.snoop_filter = None;
            } else {
                let policy = parse_sf_policy(name, cfg.snoop_filter)?;
                let cap = cfg.snoop_filter.map(|(c, _)| c).unwrap_or(1024);
                cfg.snoop_filter = Some((cap, policy));
            }
        }
        // Snoop-filter capacity in lines. Disabling the filter is
        // sf_policy="none"'s job alone: axes apply in alphabetical key
        // order, so sf_policy always runs after sf_capacity and a second
        // disable spelling here could be silently re-enabled (or vice
        // versa) within one scenario.
        "sf_capacity" => {
            let cap = axis_f64(key, v)? as usize;
            if cap == 0 {
                bail!(
                    "sweep axis 'sf_capacity': capacity must be > 0 \
                     (disable the filter with sf_policy: \"none\")"
                );
            }
            let policy = cfg.snoop_filter.map(|(_, p)| p).unwrap_or(VictimPolicy::Fifo);
            cfg.snoop_filter = Some((cap, policy));
        }
        // Requester-side coherent cache capacity (0 = non-coherent).
        "cache_lines" => cfg.cache_lines = axis_f64(key, v)? as usize,
        other => bail!(
            "unknown sweep axis '{other}' (supported: {})",
            AXES.join(", ")
        ),
    }
    Ok(())
}

/// Compact value rendering for scenario labels.
fn axis_label(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

impl GridSpec {
    pub fn from_json(j: &Json) -> Result<GridSpec> {
        let base = match j.get("base") {
            Some(b) => SystemCfg::from_json(b)?,
            None => SystemCfg::from_json(&Json::Obj(Default::default()))?,
        };
        let jobs = j.u64_or("jobs", 0) as usize;
        let intra_jobs = j.u64_or("intra_jobs", 1) as usize;
        let sweep = j
            .get("sweep")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("grid config needs a \"sweep\" object of axis arrays"))?;
        let mut scenarios = vec![Scenario {
            label: String::new(),
            cfg: base,
        }];
        // BTreeMap iteration = alphabetical key order: deterministic.
        for (key, vals) in sweep {
            let vals = vals
                .as_arr()
                .ok_or_else(|| anyhow!("sweep axis '{key}' must be an array of values"))?;
            if vals.is_empty() {
                bail!("sweep axis '{key}' has no values");
            }
            let mut next = Vec::with_capacity(scenarios.len() * vals.len());
            for sc in &scenarios {
                for v in vals {
                    let mut cfg = sc.cfg.clone();
                    apply_axis(&mut cfg, key, v)?;
                    let mut label = sc.label.clone();
                    if !label.is_empty() {
                        label.push(' ');
                    }
                    label.push_str(key);
                    label.push('=');
                    label.push_str(&axis_label(v));
                    next.push(Scenario { label, cfg });
                }
            }
            scenarios = next;
            if scenarios.len() > 100_000 {
                bail!("sweep grid expands to more than 100000 scenarios");
            }
        }
        Ok(GridSpec {
            scenarios,
            jobs,
            intra_jobs,
        })
    }

    pub fn from_json_str(s: &str) -> Result<GridSpec> {
        let j = Json::parse(s).map_err(|e| anyhow!("grid config parse: {e}"))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_submission_order_under_parallelism() {
        // Later tasks finish first (reverse-staggered sleeps); results
        // must still come back in submission order.
        let tasks: Vec<_> = (0..16u64)
            .map(|i| {
                move || {
                    std::thread::sleep(std::time::Duration::from_millis(16 - i));
                    i
                }
            })
            .collect();
        let out = run_sweep(tasks, 8);
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let compute = |i: u64| i.wrapping_mul(0x9E3779B97F4A7C15) ^ (i << 7);
        let a = map_sweep((0..64).collect(), 1, compute);
        let b = map_sweep((0..64).collect(), 8, compute);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_sweep_is_fine() {
        let tasks: Vec<fn() -> u32> = Vec::new();
        assert!(run_sweep(tasks, 4).is_empty());
    }

    #[test]
    fn resolve_jobs_auto() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }

    /// `--jobs` x `--intra-jobs` must never oversubscribe the budget:
    /// intra keeps its width, across is clipped to the remainder.
    #[test]
    fn thread_budget_split_never_oversubscribes() {
        assert_eq!(split_thread_budget(8, 1, 16), (8, 1));
        // intra_jobs == 1: nothing to share — an explicit --jobs is
        // honored verbatim even beyond the budget (pre-PR-4 semantics).
        assert_eq!(split_thread_budget(8, 1, 4), (8, 1));
        assert_eq!(split_thread_budget(8, 4, 16), (4, 4));
        assert_eq!(split_thread_budget(8, 8, 16), (2, 8));
        assert_eq!(split_thread_budget(1, 16, 16), (1, 16));
        // Intra larger than the machine: clamped, across serialized.
        assert_eq!(split_thread_budget(8, 64, 16), (1, 16));
        // Autos: jobs 0 fills the clip, intra 0 takes the whole budget.
        assert_eq!(split_thread_budget(0, 4, 16), (4, 4));
        assert_eq!(split_thread_budget(4, 0, 16), (1, 16));
        assert_eq!(split_thread_budget(0, 0, 16), (1, 16));
        // Degenerate budget.
        assert_eq!(split_thread_budget(0, 0, 1), (1, 1));
    }

    /// Exhaustive small-value grid for the budget split: every
    /// combination in 0..=6^3 must hand out non-zero shares, stay inside
    /// the budget (modulo the documented `--jobs`-verbatim carve-out),
    /// and be a deterministic pure function of the arguments — no live
    /// core probe may leak in (regression: `jobs=0, intra_jobs=1` used
    /// to return `available_jobs()` regardless of the passed budget).
    #[test]
    fn thread_budget_split_small_value_grid() {
        for jobs in 0..=6usize {
            for intra_jobs in 0..=6usize {
                for budget in 0..=6usize {
                    let (a, i) = split_thread_budget(jobs, intra_jobs, budget);
                    let eff = budget.max(1);
                    // Never a zero share.
                    assert!(a >= 1 && i >= 1, "zero share for {jobs}/{intra_jobs}/{budget}");
                    // Pure + deterministic.
                    assert_eq!(
                        (a, i),
                        split_thread_budget(jobs, intra_jobs, budget),
                        "split not deterministic"
                    );
                    // Intra never exceeds the (clamped) budget.
                    assert!(i <= eff, "intra {i} over budget {eff}");
                    if intra_jobs == 1 {
                        // Verbatim carve-out: explicit --jobs is honored
                        // even beyond the budget; auto fills the budget.
                        assert_eq!(i, 1);
                        assert_eq!(a, if jobs == 0 { eff } else { jobs });
                    } else {
                        // Sharing dimension active: the product stays in
                        // budget (an across of 1 is the degenerate floor).
                        assert!(a * i <= eff || a == 1, "{a}x{i} over {eff}");
                        // Requested widths are upper bounds.
                        if jobs > 0 {
                            assert!(a <= jobs);
                        }
                        if intra_jobs > 0 {
                            assert!(i <= intra_jobs);
                        }
                    }
                    // Issue-pinned degradations.
                    if budget == 0 && jobs == 0 {
                        assert_eq!((a, i), (1, 1), "zero budget must fully serialize");
                    }
                    if intra_jobs > budget && intra_jobs > 1 && budget >= 1 {
                        assert_eq!(i, budget, "oversized intra must clamp to budget");
                        assert_eq!(a, 1, "clamped intra leaves nothing across");
                    }
                }
            }
        }
    }

    /// Grid-level byte-identity across intra-jobs widths — the `esf
    /// sweep --intra-jobs` acceptance contract at the library layer.
    #[test]
    fn sweep_results_identical_across_intra_jobs() {
        let grid = || {
            GridSpec::from_json_str(
                r#"{
                    "base": {"scale": 16,
                             "requester": {"requests_per_endpoint": 60}},
                    "sweep": {"topology": ["spine-leaf", "fc"],
                              "read_ratio": [1.0, 0.5]}
                }"#,
            )
            .unwrap()
        };
        let dump = |rs: &[ScenarioResult]| results_json(rs).to_string();
        let seq = dump(&run_scenarios_opts(grid().scenarios, 2, 1));
        for intra in [2, 4] {
            let par = dump(&run_scenarios_opts(grid().scenarios, 2, intra));
            assert_eq!(seq, par, "sweep output diverged at intra_jobs={intra}");
        }
    }

    #[test]
    fn grid_parses_intra_jobs() {
        let g = GridSpec::from_json_str(
            r#"{"intra_jobs": 4, "sweep": {"scale": [8]}}"#,
        )
        .unwrap();
        assert_eq!(g.intra_jobs, 4);
        let g = GridSpec::from_json_str(r#"{"sweep": {"scale": [8]}}"#).unwrap();
        assert_eq!(g.intra_jobs, 1);
    }

    #[test]
    fn grid_expands_cartesian_in_deterministic_order() {
        let g = GridSpec::from_json_str(
            r#"{
                "jobs": 2,
                "base": {"requester": {"requests_per_endpoint": 10}},
                "sweep": {
                    "topology": ["chain", "ring"],
                    "read_ratio": [1.0, 0.5, 0.25]
                }
            }"#,
        )
        .unwrap();
        assert_eq!(g.jobs, 2);
        assert_eq!(g.scenarios.len(), 6);
        // Axes in alphabetical order (read_ratio before topology), last
        // axis fastest.
        assert_eq!(g.scenarios[0].label, "read_ratio=1 topology=chain");
        assert_eq!(g.scenarios[1].label, "read_ratio=1 topology=ring");
        assert_eq!(g.scenarios[2].label, "read_ratio=0.5 topology=chain");
        assert_eq!(g.scenarios[5].label, "read_ratio=0.25 topology=ring");
        assert_eq!(g.scenarios[0].cfg.requests_per_endpoint, 10);
        assert_eq!(g.scenarios[5].cfg.topology, TopologyKind::Ring);
        assert_eq!(g.scenarios[5].cfg.read_ratio, 0.25);
    }

    #[test]
    fn grid_rejects_unknown_axis_and_bad_values() {
        assert!(GridSpec::from_json_str(r#"{"sweep": {"warp": [1]}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{"sweep": {"scale": "big"}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{"sweep": {"scale": []}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{"sweep": {"topology": ["mobius"]}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{"sweep": {"pattern": ["quantum"]}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{"sweep": {"backend": ["tape"]}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{"sweep": {"sf_policy": ["magic"]}}"#).is_err());
    }

    #[test]
    fn new_axes_map_onto_system_cfg() {
        let g = GridSpec::from_json_str(
            r#"{
                "base": {"memory": {"snoop_filter": {"capacity": 32,
                                                     "policy": "blocklen",
                                                     "max_len": 2}}},
                "sweep": {
                    "pattern": ["sequential", "zipfian", "pointer-chase"],
                    "backend": ["dram", "ssd"],
                    "sf_policy": ["lfi", "blocklen"],
                    "sf_capacity": [64],
                    "cache_lines": [128]
                }
            }"#,
        )
        .unwrap();
        // 3 * 2 * 2 * 1 * 1 = 12 scenarios.
        assert_eq!(g.scenarios.len(), 12);
        // Alphabetical axis order: backend, cache_lines, pattern,
        // sf_capacity, sf_policy (last fastest).
        assert_eq!(
            g.scenarios[0].label,
            "backend=dram cache_lines=128 pattern=sequential sf_capacity=64 sf_policy=lfi"
        );
        let c0 = &g.scenarios[0].cfg;
        assert!(matches!(c0.backend, BackendKind::Dram(_)));
        assert!(matches!(c0.pattern, Pattern::Stream));
        assert_eq!(c0.cache_lines, 128);
        assert_eq!(c0.snoop_filter, Some((64, VictimPolicy::Lfi)));
        // BlockLen keeps the base config's max_len through the axis.
        let cb = &g.scenarios[1].cfg;
        assert_eq!(cb.snoop_filter, Some((64, VictimPolicy::BlockLen { max_len: 2 })));
        let last = &g.scenarios[11].cfg;
        assert!(matches!(last.backend, BackendKind::Ssd(_)));
        assert!(matches!(last.pattern, Pattern::PointerChase));
    }

    #[test]
    fn sf_axes_can_disable_the_filter() {
        let g = GridSpec::from_json_str(
            r#"{"sweep": {"sf_policy": ["none", "mru"], "sf_capacity": [16]}}"#,
        )
        .unwrap();
        // sf_capacity applies first (alphabetical), then sf_policy — so
        // "none" always wins within a scenario, never the reverse.
        assert_eq!(g.scenarios[0].cfg.snoop_filter, None);
        assert_eq!(g.scenarios[1].cfg.snoop_filter, Some((16, VictimPolicy::Mru)));
        // The one disable spelling is sf_policy="none"; a zero capacity
        // is rejected instead of introducing a second, order-dependent one.
        assert!(GridSpec::from_json_str(r#"{"sweep": {"sf_capacity": [0]}}"#).is_err());
    }

    #[test]
    fn cached_run_matches_fresh_and_resumes() {
        let grid = || {
            GridSpec::from_json_str(
                r#"{
                    "base": {"scale": 4,
                             "requester": {"requests_per_endpoint": 40}},
                    "sweep": {"topology": ["chain", "fc"],
                              "read_ratio": [1.0, 0.5]}
                }"#,
            )
            .unwrap()
        };
        let dir = std::env::temp_dir().join(format!("esf-sweep-unit-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = SweepCache::open(&dir).unwrap();
        let fresh = run_scenarios(grid().scenarios, 2);
        let populate = run_scenarios_cached(grid().scenarios, 2, &cache);
        let dump = |rs: &[ScenarioResult]| results_json(rs).to_string();
        assert_eq!(dump(&fresh), dump(&populate));
        // Four distinct configs -> four result cells on disk, plus one
        // shared warm-up prefix snapshot per topology (read_ratio is
        // normalized out of the prefix projection, so each topology's
        // two cells form one warm-start group).
        let ext_count = |ext: &str| {
            std::fs::read_dir(&dir)
                .unwrap()
                .filter(|e| {
                    e.as_ref()
                        .unwrap()
                        .path()
                        .extension()
                        .is_some_and(|x| x == ext)
                })
                .count()
        };
        assert_eq!(ext_count("json"), 4);
        assert_eq!(ext_count("snap"), 2);
        // Warm resume (all hits) is byte-identical too.
        let warm = run_scenarios_cached(grid().scenarios, 1, &cache);
        assert_eq!(dump(&fresh), dump(&warm));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The streaming execution core must fire the callback exactly once
    /// per cell with correct indices and cached flags, while the
    /// assembled return value stays byte-identical to the non-streaming
    /// entry points — the `esfd` attach contract at the library layer.
    #[test]
    fn streaming_callback_covers_every_cell_and_flags_cache_hits() {
        let grid = || {
            GridSpec::from_json_str(
                r#"{
                    "base": {"scale": 4,
                             "requester": {"requests_per_endpoint": 40}},
                    "sweep": {"topology": ["chain", "fc"],
                              "read_ratio": [1.0, 0.5]}
                }"#,
            )
            .unwrap()
        };
        let dir = std::env::temp_dir().join(format!("esf-sweep-stream-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = SweepCache::open(&dir).unwrap();
        let dump = |rs: &[ScenarioResult]| results_json(rs).to_string();
        let baseline = dump(&run_scenarios(grid().scenarios, 2));
        let collect = |cache: Option<&SweepCache>| {
            let seen: Mutex<Vec<(usize, bool, String)>> = Mutex::new(Vec::new());
            let out = run_scenarios_streaming(
                grid().scenarios,
                2,
                1,
                BarrierMode::default(),
                4,
                cache,
                |u| {
                    assert_eq!(u.total, 4);
                    seen.lock()
                        .expect("update log lock")
                        .push((u.index, u.cached, u.result.label.clone()));
                },
            );
            let mut seen = seen.into_inner().expect("update log lock");
            seen.sort(); // completion order is nondeterministic
            (out, seen)
        };
        // Uncached: every cell computed, callback covers all indices.
        let (out, seen) = collect(None);
        assert_eq!(dump(&out), baseline);
        assert_eq!(seen.len(), 4);
        for (i, (idx, cached, label)) in seen.iter().enumerate() {
            assert_eq!(*idx, i);
            assert!(!cached, "uncached run flagged a cache hit");
            assert_eq!(*label, out[i].label, "update carries the cell's result");
        }
        // Cold cache populates; warm rerun serves every cell cached.
        let (out, seen) = collect(Some(&cache));
        assert_eq!(dump(&out), baseline);
        assert!(seen.iter().all(|(_, cached, _)| !cached));
        let (out, seen) = collect(Some(&cache));
        assert_eq!(dump(&out), baseline);
        assert!(seen.iter().all(|(_, cached, _)| *cached), "{seen:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scenario_result_json_roundtrip_is_lossless() {
        let g = GridSpec::from_json_str(
            r#"{"base": {"scale": 4, "requester": {"requests_per_endpoint": 30}},
                "sweep": {"topology": ["ring"]}}"#,
        )
        .unwrap();
        let r = &run_scenarios(g.scenarios, 1)[0];
        let back = ScenarioResult::from_json(&r.to_json()).unwrap();
        assert_eq!(r.label, back.label);
        assert_eq!(r.events, back.events);
        assert_eq!(r.bandwidth_gbps.to_bits(), back.bandwidth_gbps.to_bits());
        assert_eq!(r.avg_latency_ns.to_bits(), back.avg_latency_ns.to_bits());
        assert_eq!(r.p50_ns.to_bits(), back.p50_ns.to_bits());
        assert_eq!(r.p99_ns.to_bits(), back.p99_ns.to_bits());
        // And through an actual serialize -> parse cycle.
        let reparsed = Json::parse(&r.to_json().to_string()).unwrap();
        let back2 = ScenarioResult::from_json(&reparsed).unwrap();
        assert_eq!(back2.bandwidth_gbps.to_bits(), r.bandwidth_gbps.to_bits());
        // Percentiles are ordered and within [0, max].
        assert!(r.p50_ns <= r.p95_ns && r.p95_ns <= r.p99_ns);
        assert!(r.p99_ns <= r.max_latency_ns);
    }

    #[test]
    fn tiny_scenario_sweep_runs_and_orders() {
        let g = GridSpec::from_json_str(
            r#"{
                "base": {"scale": 4,
                         "requester": {"requests_per_endpoint": 40}},
                "sweep": {"topology": ["chain", "fc"]}
            }"#,
        )
        .unwrap();
        let res = run_scenarios(g.scenarios, 2);
        assert_eq!(res.len(), 2);
        assert_eq!(res[0].label, "topology=chain");
        assert_eq!(res[1].label, "topology=fc");
        for r in &res {
            assert!(r.completed > 0, "{}: no completions", r.label);
        }
        let t = results_table(&res);
        assert_eq!(t.rows.len(), 2);
    }
}
