//! Parallel sweep subsystem: run independent scenarios across threads.
//!
//! The paper's evaluation (§IV–§V) is a grid of independent simulations —
//! topology x scale x R:W mix x routing strategy. Each simulation is a
//! share-nothing deterministic `Engine`, so a batch of them is
//! embarrassingly parallel by construction. This module provides:
//!
//!  * [`run_sweep`] / [`map_sweep`] — the generic batch driver: shard a
//!    list of closures across `--jobs N` worker threads (0 = all available
//!    cores) and collect results **in submission order**, so output is
//!    byte-identical regardless of worker interleaving. Every experiment
//!    harness (`experiments::*`) expresses its config grid as data handed
//!    to this driver.
//!  * [`Scenario`] / [`GridSpec`] — a JSON-configurable scenario grid
//!    (cartesian product of axis values over a base `SystemCfg`) behind
//!    the `esf sweep --config <grid.json> [--jobs N]` CLI command.
//!
//! Determinism contract: a worker thread only runs a scenario's closure
//! and writes its result into the slot reserved at submission; nothing
//! about scheduling can leak into results, and `--jobs 1` vs `--jobs 8`
//! produce identical tables (covered by unit + integration tests).

use crate::config::{build_system, SystemCfg};
use crate::engine::time::ns;
use crate::interconnect::{Duplex, Strategy, TopologyKind};
use crate::metrics::aggregate;
use crate::util::json::Json;
use crate::util::table::{f, Table};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker count for `--jobs 0` / unspecified: all available cores.
pub fn available_jobs() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Resolve a requested `--jobs` value: 0 means auto (available cores).
pub fn resolve_jobs(requested: usize) -> usize {
    if requested == 0 {
        available_jobs()
    } else {
        requested
    }
}

/// Run every task, sharded over `jobs` worker threads (0 = auto), and
/// return the results in submission order.
///
/// Tasks are claimed from a shared cursor, so long and short scenarios
/// load-balance; each result is written into the slot reserved for its
/// task at submission, which keeps output deterministic regardless of
/// completion order. A panicking task propagates the panic to the caller
/// once the scope joins.
pub fn run_sweep<T, F>(tasks: Vec<F>, jobs: usize) -> Vec<T>
where
    T: Send,
    F: FnOnce() -> T + Send,
{
    let n = tasks.len();
    let jobs = resolve_jobs(jobs).min(n.max(1));
    if jobs <= 1 {
        return tasks.into_iter().map(|task| task()).collect();
    }
    let slots: Vec<Mutex<Option<F>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let task = slots[i]
                    .lock()
                    .expect("sweep task lock")
                    .take()
                    .expect("each task is claimed exactly once");
                let out = task();
                *results[i].lock().expect("sweep result lock") = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("sweep result lock")
                .expect("every slot is filled when the scope joins")
        })
        .collect()
}

/// [`run_sweep`] over a list of inputs with one shared function — the
/// shape every experiment grid uses.
pub fn map_sweep<I, T, F>(items: Vec<I>, jobs: usize, func: F) -> Vec<T>
where
    I: Send,
    T: Send,
    F: Fn(I) -> T + Send + Sync,
{
    let func = &func;
    let tasks: Vec<_> = items.into_iter().map(|item| move || func(item)).collect();
    run_sweep(tasks, jobs)
}

// ----------------------------------------------------- scenario grids

/// One fully-specified simulation in a sweep.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub label: String,
    pub cfg: SystemCfg,
}

/// Aggregate results of one scenario (submission-ordered in the output).
#[derive(Clone, Debug)]
pub struct ScenarioResult {
    pub label: String,
    pub events: u64,
    pub completed: u64,
    pub bandwidth_gbps: f64,
    pub avg_latency_ns: f64,
    pub max_latency_ns: f64,
    pub dropped: u64,
}

/// Build + run one scenario to completion and extract aggregates.
pub fn run_scenario(sc: &Scenario) -> ScenarioResult {
    let mut sys = build_system(&sc.cfg);
    let events = sys.engine.run(u64::MAX);
    let a = aggregate(&sys);
    ScenarioResult {
        label: sc.label.clone(),
        events,
        completed: a.completed,
        bandwidth_gbps: a.bandwidth_gbps(),
        avg_latency_ns: a.avg_latency_ns(),
        max_latency_ns: a.lat_max_ns,
        dropped: sys.engine.shared.dropped,
    }
}

/// Run a scenario batch through the sweep driver.
pub fn run_scenarios(scenarios: Vec<Scenario>, jobs: usize) -> Vec<ScenarioResult> {
    map_sweep(scenarios, jobs, |sc| run_scenario(&sc))
}

/// Render scenario results as one table (the `esf sweep` output).
pub fn results_table(results: &[ScenarioResult]) -> Table {
    let mut t = Table::new(
        "Sweep results",
        &[
            "scenario",
            "events",
            "completed",
            "bw GB/s",
            "avg lat ns",
            "max lat ns",
            "dropped",
        ],
    );
    for r in results {
        t.row(&[
            r.label.clone(),
            r.events.to_string(),
            r.completed.to_string(),
            f(r.bandwidth_gbps),
            f(r.avg_latency_ns),
            f(r.max_latency_ns),
            r.dropped.to_string(),
        ]);
    }
    t
}

/// A JSON-configured scenario grid:
///
/// ```json
/// {
///   "jobs": 4,
///   "base": { ... any `esf run --config` system object ... },
///   "sweep": {
///     "topology": ["chain", "ring", "spine-leaf"],
///     "scale": [8, 16],
///     "read_ratio": [1.0, 0.5]
///   }
/// }
/// ```
///
/// Scenarios are the cartesian product of the axis values applied over the
/// base config: axes combine in alphabetical key order with the last axis
/// varying fastest, so the expansion order (and therefore the output
/// order) is deterministic.
pub struct GridSpec {
    pub scenarios: Vec<Scenario>,
    /// Default worker count from the file (0 = auto); the CLI `--jobs`
    /// flag overrides it.
    pub jobs: usize,
}

/// Axes `"sweep"` accepts, mapped onto `SystemCfg` fields.
const AXES: &[&str] = &[
    "topology",
    "scale",
    "read_ratio",
    "routing",
    "duplex",
    "bandwidth_gbps",
    "header_bytes",
    "turnaround_ns",
    "issue_interval_ns",
    "queue_capacity",
    "requests_per_endpoint",
    "seed",
];

fn axis_f64(key: &str, v: &Json) -> Result<f64> {
    v.as_f64()
        .ok_or_else(|| anyhow!("sweep axis '{key}': expected a number, got {v}"))
}

fn axis_str<'a>(key: &str, v: &'a Json) -> Result<&'a str> {
    v.as_str()
        .ok_or_else(|| anyhow!("sweep axis '{key}': expected a string, got {v}"))
}

/// Apply one axis value to a scenario config.
fn apply_axis(cfg: &mut SystemCfg, key: &str, v: &Json) -> Result<()> {
    match key {
        "topology" => {
            let name = axis_str(key, v)?;
            cfg.topology = TopologyKind::parse(name)
                .ok_or_else(|| anyhow!("sweep axis 'topology': unknown kind '{name}'"))?;
        }
        // "system scale = 2N" (N requesters + N memories), as in the
        // `esf run --config` schema.
        "scale" => cfg.n = ((axis_f64(key, v)? as usize).max(2) / 2).max(1),
        "read_ratio" => cfg.read_ratio = axis_f64(key, v)?,
        "routing" => {
            cfg.strategy = match axis_str(key, v)? {
                "adaptive" => Strategy::Adaptive,
                "oblivious" => Strategy::Oblivious,
                other => bail!("sweep axis 'routing': unknown strategy '{other}'"),
            }
        }
        "duplex" => {
            cfg.link.duplex = match axis_str(key, v)? {
                "full" => Duplex::Full,
                "half" => Duplex::Half,
                other => bail!("sweep axis 'duplex': unknown mode '{other}'"),
            }
        }
        "bandwidth_gbps" => cfg.link.bandwidth_gbps = axis_f64(key, v)?,
        "header_bytes" => cfg.link.header_bytes = axis_f64(key, v)? as u64,
        "turnaround_ns" => cfg.link.turnaround = ns(axis_f64(key, v)?),
        "issue_interval_ns" => cfg.issue_interval = ns(axis_f64(key, v)?),
        "queue_capacity" => cfg.queue_capacity = axis_f64(key, v)? as usize,
        "requests_per_endpoint" => cfg.requests_per_endpoint = axis_f64(key, v)? as u64,
        "seed" => cfg.seed = axis_f64(key, v)? as u64,
        other => bail!(
            "unknown sweep axis '{other}' (supported: {})",
            AXES.join(", ")
        ),
    }
    Ok(())
}

/// Compact value rendering for scenario labels.
fn axis_label(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

impl GridSpec {
    pub fn from_json(j: &Json) -> Result<GridSpec> {
        let base = match j.get("base") {
            Some(b) => SystemCfg::from_json(b)?,
            None => SystemCfg::from_json(&Json::Obj(Default::default()))?,
        };
        let jobs = j.u64_or("jobs", 0) as usize;
        let sweep = j
            .get("sweep")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("grid config needs a \"sweep\" object of axis arrays"))?;
        let mut scenarios = vec![Scenario {
            label: String::new(),
            cfg: base,
        }];
        // BTreeMap iteration = alphabetical key order: deterministic.
        for (key, vals) in sweep {
            let vals = vals
                .as_arr()
                .ok_or_else(|| anyhow!("sweep axis '{key}' must be an array of values"))?;
            if vals.is_empty() {
                bail!("sweep axis '{key}' has no values");
            }
            let mut next = Vec::with_capacity(scenarios.len() * vals.len());
            for sc in &scenarios {
                for v in vals {
                    let mut cfg = sc.cfg.clone();
                    apply_axis(&mut cfg, key, v)?;
                    let mut label = sc.label.clone();
                    if !label.is_empty() {
                        label.push(' ');
                    }
                    label.push_str(key);
                    label.push('=');
                    label.push_str(&axis_label(v));
                    next.push(Scenario { label, cfg });
                }
            }
            scenarios = next;
            if scenarios.len() > 100_000 {
                bail!("sweep grid expands to more than 100000 scenarios");
            }
        }
        Ok(GridSpec { scenarios, jobs })
    }

    pub fn from_json_str(s: &str) -> Result<GridSpec> {
        let j = Json::parse(s).map_err(|e| anyhow!("grid config parse: {e}"))?;
        Self::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_submission_order_under_parallelism() {
        // Later tasks finish first (reverse-staggered sleeps); results
        // must still come back in submission order.
        let tasks: Vec<_> = (0..16u64)
            .map(|i| {
                move || {
                    std::thread::sleep(std::time::Duration::from_millis(16 - i));
                    i
                }
            })
            .collect();
        let out = run_sweep(tasks, 8);
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let compute = |i: u64| i.wrapping_mul(0x9E3779B97F4A7C15) ^ (i << 7);
        let a = map_sweep((0..64).collect(), 1, compute);
        let b = map_sweep((0..64).collect(), 8, compute);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_sweep_is_fine() {
        let tasks: Vec<fn() -> u32> = Vec::new();
        assert!(run_sweep(tasks, 4).is_empty());
    }

    #[test]
    fn resolve_jobs_auto() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }

    #[test]
    fn grid_expands_cartesian_in_deterministic_order() {
        let g = GridSpec::from_json_str(
            r#"{
                "jobs": 2,
                "base": {"requester": {"requests_per_endpoint": 10}},
                "sweep": {
                    "topology": ["chain", "ring"],
                    "read_ratio": [1.0, 0.5, 0.25]
                }
            }"#,
        )
        .unwrap();
        assert_eq!(g.jobs, 2);
        assert_eq!(g.scenarios.len(), 6);
        // Axes in alphabetical order (read_ratio before topology), last
        // axis fastest.
        assert_eq!(g.scenarios[0].label, "read_ratio=1 topology=chain");
        assert_eq!(g.scenarios[1].label, "read_ratio=1 topology=ring");
        assert_eq!(g.scenarios[2].label, "read_ratio=0.5 topology=chain");
        assert_eq!(g.scenarios[5].label, "read_ratio=0.25 topology=ring");
        assert_eq!(g.scenarios[0].cfg.requests_per_endpoint, 10);
        assert_eq!(g.scenarios[5].cfg.topology, TopologyKind::Ring);
        assert_eq!(g.scenarios[5].cfg.read_ratio, 0.25);
    }

    #[test]
    fn grid_rejects_unknown_axis_and_bad_values() {
        assert!(GridSpec::from_json_str(r#"{"sweep": {"warp": [1]}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{"sweep": {"scale": "big"}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{"sweep": {"scale": []}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{"sweep": {"topology": ["mobius"]}}"#).is_err());
        assert!(GridSpec::from_json_str(r#"{}"#).is_err());
    }

    #[test]
    fn tiny_scenario_sweep_runs_and_orders() {
        let g = GridSpec::from_json_str(
            r#"{
                "base": {"scale": 4,
                         "requester": {"requests_per_endpoint": 40}},
                "sweep": {"topology": ["chain", "fc"]}
            }"#,
        )
        .unwrap();
        let res = run_scenarios(g.scenarios, 2);
        assert_eq!(res.len(), 2);
        assert_eq!(res[0].label, "topology=chain");
        assert_eq!(res[1].label, "topology=fc");
        for r in &res {
            assert!(r.completed > 0, "{}: no completions", r.label);
        }
        let t = results_table(&res);
        assert_eq!(t.rows.len(), 2);
    }
}
