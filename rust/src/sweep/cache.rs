//! Sweep-level result caching with resume.
//!
//! Every scenario in a grid is identified by the **content hash** of its
//! canonical config JSON (`SystemCfg::to_json` / `fingerprint`). As
//! scenarios complete, their aggregate results are persisted to one JSON
//! cell file per config under the cache directory; re-running an
//! interrupted or extended grid loads the finished cells and recomputes
//! only the missing ones.
//!
//! Byte-identity contract: a resumed sweep must produce output
//! byte-identical to an uninterrupted run. Two properties carry that:
//!
//!  * results are deterministic functions of the config (the engine's
//!    reproducibility guarantee), and
//!  * every number in a cell round-trips losslessly — counters are
//!    integers well under 2^53 and floats serialize shortest-roundtrip,
//!    so `parse(format(x)) == x` exactly.
//!
//! Cells are written to a temp file and `rename`d into place, so a run
//! killed mid-write never leaves a torn cell — the resume path treats
//! any unreadable/mismatching cell as a miss and recomputes it. The
//! stored canonical config doubles as a hash-collision guard: a cell is
//! only trusted if its embedded config string matches the scenario's.

use super::ScenarioResult;
use crate::config::SystemCfg;
use crate::util::fnv1a64;
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};

/// Cell schema version; bump when `ScenarioResult`'s fields change so
/// stale caches are recomputed instead of misread.
const CELL_SCHEMA: u64 = 1;

/// Content identity of one scenario: `(hash, canonical config JSON)`.
pub fn scenario_key(cfg: &SystemCfg) -> (u64, String) {
    let canon = cfg.to_json().to_string();
    (fnv1a64(canon.as_bytes()), canon)
}

/// An open sweep result cache directory.
pub struct SweepCache {
    dir: PathBuf,
}

impl SweepCache {
    /// Open (creating if needed) a cache directory. Stale `.tmp-*` files
    /// left by a writer killed between write and rename are swept here:
    /// they are never loaded (cells are only read through their final
    /// names) but would otherwise accumulate forever. A concurrent
    /// writer's live temp file can be swept too — its rename then fails
    /// and that store degrades to "continuing uncached", never to a torn
    /// or wrong cell.
    pub fn open(dir: &Path) -> Result<SweepCache> {
        std::fs::create_dir_all(dir)
            .map_err(|e| anyhow!("creating cache dir {}: {e}", dir.display()))?;
        if let Ok(entries) = std::fs::read_dir(dir) {
            for entry in entries.flatten() {
                if entry.file_name().to_string_lossy().starts_with(".tmp-") {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        Ok(SweepCache { dir: dir.to_path_buf() })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn cell_path(&self, hash: u64) -> PathBuf {
        self.dir.join(format!("{hash:016x}.json"))
    }

    /// Load a finished cell, or `None` when the scenario must (re)run:
    /// missing, unparsable, wrong schema, or config mismatch (torn write
    /// or hash collision) all count as misses.
    pub fn load(&self, hash: u64, canon: &str) -> Option<ScenarioResult> {
        let text = std::fs::read_to_string(self.cell_path(hash)).ok()?;
        let j = Json::parse(&text).ok()?;
        if j.u64_or("schema", 0) != CELL_SCHEMA {
            return None;
        }
        if j.get("config")?.to_string() != canon {
            return None;
        }
        ScenarioResult::from_json(j.get("result")?).ok()
    }

    /// Persist a finished cell atomically ([`crate::util::atomic_write`]:
    /// temp-with-pid + rename). `tag` disambiguates concurrent writers'
    /// temp files within one process; the embedded process id
    /// disambiguates across processes sharing the cache dir (two sweeps
    /// over overlapping grids use the same per-grid `tag` for different
    /// cells, so a tag-only name collides and one writer renames the
    /// other's half-written bytes into place). Identical configs racing
    /// here write identical content, so last-rename-wins is fine.
    pub fn store(&self, hash: u64, canon: &str, result: &ScenarioResult, tag: usize) -> Result<()> {
        let cell = Json::obj(vec![
            ("schema", Json::Num(CELL_SCHEMA as f64)),
            (
                "config",
                Json::parse(canon).map_err(|e| anyhow!("canonical config reparse: {e}"))?,
            ),
            ("result", result.to_json()),
        ]);
        let mut text = cell.to_string();
        text.push('\n');
        self.write_atomic(&self.cell_path(hash), text.as_bytes(), tag)
    }

    /// Path of the shared warm-up prefix snapshot for one prefix
    /// fingerprint ([`crate::config::SystemCfg::prefix_fingerprint`]).
    fn snap_path(&self, prefix_fp: u64) -> PathBuf {
        self.dir.join(format!("{prefix_fp:016x}.snap"))
    }

    /// Load a persisted warm-up prefix snapshot. Integrity and
    /// fork-compatibility are the caller's job (`check::check_snapshot`
    /// — the file embeds a digest and the prefix projection), so a torn
    /// or foreign file is rejected there and rebuilt, never trusted.
    pub fn load_snapshot(&self, prefix_fp: u64) -> Option<Vec<u8>> {
        std::fs::read(self.snap_path(prefix_fp)).ok()
    }

    /// Persist a warm-up prefix snapshot atomically (same temp+rename
    /// discipline as cells; equal prefix fingerprints imply byte-equal
    /// snapshots, so concurrent writers racing is fine).
    pub fn store_snapshot(&self, prefix_fp: u64, bytes: &[u8], tag: usize) -> Result<()> {
        self.write_atomic(&self.snap_path(prefix_fp), bytes, tag)
    }

    fn write_atomic(&self, path: &Path, bytes: &[u8], tag: usize) -> Result<()> {
        crate::util::atomic_write(path, bytes, tag as u64)
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::TopologyKind;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("esf-cache-test-{tag}-{}", std::process::id()))
    }

    fn result_fixture() -> ScenarioResult {
        ScenarioResult {
            label: "t=1".into(),
            events: 123_456,
            completed: 400,
            bandwidth_gbps: 12.345678901234567,
            avg_latency_ns: 210.0 / 7.0,
            max_latency_ns: 999.25,
            p50_ns: 101.5,
            p95_ns: 333.125,
            p99_ns: 420.75,
            dropped: 0,
        }
    }

    #[test]
    fn store_then_load_roundtrips_exactly() {
        let dir = tmp_dir("roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let cache = SweepCache::open(&dir).unwrap();
        let cfg = SystemCfg::new(TopologyKind::Ring, 4);
        let (hash, canon) = scenario_key(&cfg);
        let r = result_fixture();
        assert!(cache.load(hash, &canon).is_none(), "cold cache must miss");
        cache.store(hash, &canon, &r, 0).unwrap();
        let got = cache.load(hash, &canon).expect("warm cache must hit");
        // Bit-exact float round-trip is the byte-identity contract.
        assert_eq!(got.bandwidth_gbps.to_bits(), r.bandwidth_gbps.to_bits());
        assert_eq!(got.avg_latency_ns.to_bits(), r.avg_latency_ns.to_bits());
        assert_eq!(got.p95_ns.to_bits(), r.p95_ns.to_bits());
        assert_eq!(got.events, r.events);
        assert_eq!(got.label, r.label);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_sweeps_stale_temp_files_and_temp_names_carry_the_pid() {
        let dir = tmp_dir("tmpsweep");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // A writer killed between write and rename leaves these behind.
        std::fs::write(dir.join(".tmp-00000000deadbeef-7"), "{torn").unwrap();
        std::fs::write(dir.join(".tmp-0000000000000001-0"), "").unwrap();
        // Finished cells must survive the sweep.
        let keep = dir.join("00000000deadbeef.json");
        std::fs::write(&keep, "{}").unwrap();
        let cache = SweepCache::open(&dir).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["00000000deadbeef.json".to_string()]);
        // A store's temp name embeds the process id, so two processes
        // sharing the dir with equal per-grid tags cannot collide.
        let (hash, canon) = scenario_key(&SystemCfg::new(TopologyKind::Ring, 4));
        cache.store(hash, &canon, &result_fixture(), 3).unwrap();
        assert!(cache.load(hash, &canon).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_files_roundtrip_and_live_beside_cells() {
        let dir = tmp_dir("snapfiles");
        let _ = std::fs::remove_dir_all(&dir);
        let cache = SweepCache::open(&dir).unwrap();
        let fp = 0xfeed_face_cafe_0042u64;
        assert!(cache.load_snapshot(fp).is_none(), "cold snapshot must miss");
        let bytes = vec![0xE5u8, 0xF5, 0x00, 0x42, 0x99];
        cache.store_snapshot(fp, &bytes, 1).unwrap();
        assert_eq!(cache.load_snapshot(fp).as_deref(), Some(&bytes[..]));
        // Snapshots use a distinct extension, so cell loads never see them.
        let (hash, canon) = scenario_key(&SystemCfg::new(TopologyKind::Ring, 4));
        assert!(cache.load(hash, &canon).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatching_config_is_a_miss() {
        let dir = tmp_dir("mismatch");
        let _ = std::fs::remove_dir_all(&dir);
        let cache = SweepCache::open(&dir).unwrap();
        let (hash, canon) = scenario_key(&SystemCfg::new(TopologyKind::Ring, 4));
        cache.store(hash, &canon, &result_fixture(), 0).unwrap();
        let (_, other) = scenario_key(&SystemCfg::new(TopologyKind::Chain, 4));
        // Same hash slot, different stored config -> recompute.
        assert!(cache.load(hash, &other).is_none());
        // Corrupt cell -> miss, not a panic.
        std::fs::write(cache.cell_path(hash), "{torn").unwrap();
        assert!(cache.load(hash, &canon).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
