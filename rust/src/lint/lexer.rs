//! Minimal Rust source lexer for the determinism lint.
//!
//! The offline crate set has no `syn`, so `esf lint` carries its own
//! comment/string stripper: rule matching must never fire on a doc
//! comment that *mentions* `HashMap` (see `devices/snoop_filter.rs`) or a
//! string literal containing `Instant::now`. The lexer walks the source
//! once and splits every line into its **code** text (comments removed,
//! string/char literal contents blanked to `""`/`' '`) and its **comment**
//! text (where `// det-ok: <reason>` waivers live).
//!
//! Handled: line comments, nested block comments, string literals with
//! escapes (including multi-line), raw strings `r#"..."#` with any hash
//! count (plus `b`/`br` prefixes), char literals vs. lifetimes (`'a'`
//! consumes three chars; `'a` in `Vec<'a>` is a lifetime and only the
//! quote is consumed).

/// One source line, split by the lexer.
#[derive(Clone, Debug, Default)]
pub struct Line {
    /// Code with comments removed and literal contents blanked.
    pub code: String,
    /// Concatenated comment text on this line (both `//` and `/* */`).
    pub comment: String,
}

/// Lex `source` into per-line code/comment splits.
pub fn split_lines(source: &str) -> Vec<Line> {
    let mut lines: Vec<Line> = Vec::new();
    let mut cur = Line::default();
    let bytes: Vec<char> = source.chars().collect();
    let mut i = 0usize;
    let n = bytes.len();

    // Finishing a line pushes `cur`; helper closures can't borrow `lines`
    // and `cur` mutably at once, so the loop does it inline.
    macro_rules! newline {
        () => {{
            lines.push(std::mem::take(&mut cur));
        }};
    }

    while i < n {
        let c = bytes[i];
        match c {
            '\n' => {
                newline!();
                i += 1;
            }
            '/' if i + 1 < n && bytes[i + 1] == '/' => {
                // Line comment: everything to end-of-line is comment text.
                i += 2;
                while i < n && bytes[i] != '\n' {
                    cur.comment.push(bytes[i]);
                    i += 1;
                }
            }
            '/' if i + 1 < n && bytes[i + 1] == '*' => {
                // Block comment, nesting per Rust.
                i += 2;
                let mut depth = 1usize;
                while i < n && depth > 0 {
                    if bytes[i] == '/' && i + 1 < n && bytes[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if bytes[i] == '*' && i + 1 < n && bytes[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        if bytes[i] == '\n' {
                            newline!();
                        } else {
                            cur.comment.push(bytes[i]);
                        }
                        i += 1;
                    }
                }
            }
            '"' => {
                // Plain string literal; contents blanked.
                cur.code.push_str("\"\"");
                i += 1;
                while i < n {
                    match bytes[i] {
                        '\\' => i += 2, // escape: skip escaped char
                        '"' => {
                            i += 1;
                            break;
                        }
                        '\n' => {
                            newline!();
                            i += 1;
                        }
                        _ => i += 1,
                    }
                }
            }
            'r' | 'b' if starts_raw_or_byte_str(&bytes, i) => {
                // r"...", r#"..."#, br"...", b"..." — blank the contents.
                let mut j = i;
                while j < n && (bytes[j] == 'r' || bytes[j] == 'b') {
                    j += 1;
                }
                let mut hashes = 0usize;
                while j < n && bytes[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && bytes[j] == '"' {
                    cur.code.push_str("\"\"");
                    j += 1;
                    if hashes == 0 && bytes[i] == 'b' && bytes[i + 1] == '"' {
                        // plain byte string: honors escapes
                        while j < n {
                            match bytes[j] {
                                '\\' => j += 2,
                                '"' => {
                                    j += 1;
                                    break;
                                }
                                '\n' => {
                                    newline!();
                                    j += 1;
                                }
                                _ => j += 1,
                            }
                        }
                    } else {
                        // raw string: ends at `"` + `hashes` hashes
                        'raw: while j < n {
                            if bytes[j] == '\n' {
                                newline!();
                                j += 1;
                                continue;
                            }
                            if bytes[j] == '"' {
                                let mut k = 0usize;
                                while k < hashes && j + 1 + k < n && bytes[j + 1 + k] == '#' {
                                    k += 1;
                                }
                                if k == hashes {
                                    j += 1 + hashes;
                                    break 'raw;
                                }
                            }
                            j += 1;
                        }
                    }
                    i = j;
                } else {
                    // Not actually a string start (e.g. ident `radius`).
                    cur.code.push(c);
                    i += 1;
                }
            }
            '\'' => {
                // Char literal vs lifetime. A char literal is `'` +
                // (escape | one char) + `'`; anything else is a lifetime.
                if i + 2 < n && bytes[i + 1] == '\\' {
                    // escaped char literal: skip to closing quote
                    cur.code.push_str("' '");
                    i += 2;
                    while i < n && bytes[i] != '\'' && bytes[i] != '\n' {
                        i += 1;
                    }
                    i += 1; // closing quote
                } else if i + 2 < n && bytes[i + 2] == '\'' {
                    cur.code.push_str("' '");
                    i += 3;
                } else {
                    // lifetime: keep the quote so code stays token-separated
                    cur.code.push('\'');
                    i += 1;
                }
            }
            _ => {
                cur.code.push(c);
                i += 1;
            }
        }
    }
    // Final (unterminated) line.
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Does `bytes[i..]` start a raw/byte string literal (`r"`, `r#`, `b"`,
/// `br"`, `br#`) rather than an identifier beginning with r/b? An ident
/// character immediately *before* position `i` means we are inside an
/// identifier (e.g. `number"` in `renumber"...` can't happen, but
/// `attr` / `subr` followed by `"` can't either — Rust has no implicit
/// concatenation, so a quote directly after an ident is always a
/// prefixed literal; the check below is still conservative).
fn starts_raw_or_byte_str(bytes: &[char], i: usize) -> bool {
    if i > 0 {
        let p = bytes[i - 1];
        if p.is_alphanumeric() || p == '_' {
            return false;
        }
    }
    let n = bytes.len();
    let mut j = i;
    // at most "br" of prefix
    if bytes[j] == 'b' {
        j += 1;
        if j < n && bytes[j] == 'r' {
            j += 1;
        }
    } else if bytes[j] == 'r' {
        j += 1;
    } else {
        return false;
    }
    while j < n && bytes[j] == '#' {
        j += 1;
    }
    j < n && bytes[j] == '"'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code(src: &str) -> Vec<String> {
        split_lines(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_and_doc_comments() {
        let ls = split_lines("let x = 1; // HashMap here\n/// doc HashMap\nlet y = 2;");
        assert_eq!(ls[0].code.trim(), "let x = 1;");
        assert!(ls[0].comment.contains("HashMap"));
        assert!(!ls[1].code.contains("HashMap"));
        assert!(ls[1].comment.contains("doc HashMap"));
        assert_eq!(ls[2].code.trim(), "let y = 2;");
    }

    #[test]
    fn strips_nested_block_comments() {
        let ls = code("a /* x /* y */ z */ b");
        assert_eq!(ls[0].replace(' ', ""), "ab");
    }

    #[test]
    fn blanks_string_contents() {
        let ls = code("let s = \"Instant::now()\"; let t = 1;");
        assert!(!ls[0].contains("Instant"));
        assert!(ls[0].contains("let t = 1;"));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let ls = code("let s = r#\"HashMap \" inner\"#; done()");
        assert!(!ls[0].contains("HashMap"));
        assert!(ls[0].contains("done()"));
    }

    #[test]
    fn multiline_string_tracks_lines() {
        let ls = split_lines("let s = \"a\nSystemTime\nb\"; fin()");
        assert_eq!(ls.len(), 3);
        assert!(!ls[1].code.contains("SystemTime"));
        assert!(ls[2].code.contains("fin()"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let ls = code("let c = 'x'; fn f<'a>(v: &'a str) { v.len(); } let nl = '\\n';");
        assert!(ls[0].contains("v.len()"));
        assert!(ls[0].contains("<'a>"));
    }

    #[test]
    fn idents_starting_with_r_or_b_are_not_strings() {
        let ls = code("let radius = b + r; br_label();");
        assert!(ls[0].contains("radius"));
        assert!(ls[0].contains("br_label()"));
    }

    #[test]
    fn det_ok_comment_survives_on_comment_channel() {
        let ls = split_lines("x.iter(); // det-ok: reason text");
        assert!(ls[0].comment.contains("det-ok: reason text"));
        assert!(!ls[0].code.contains("det-ok"));
    }
}
