//! `esf lint` — determinism static analysis over the workspace sources.
//!
//! The framework's correctness story (golden digests, cache resume, the
//! partitioned engine's byte-identity to `Engine::reference_sequential`)
//! rests on source-level invariants that, before this pass, lived only in
//! comments and release-stripped `debug_assert!`s. The lint makes them
//! machine-checked: a dependency-free scanner (hand-rolled lexer, no
//! `syn` — vendored-deps policy) walks every `.rs` file and enforces the
//! rulebook below.
//!
//! ## Rule catalog (stable ids)
//!
//! | id       | name            | scope      | what it flags |
//! |----------|-----------------|------------|---------------|
//! | ESF-L000 | waiver-reason   | everywhere | `det-ok` waiver without a reason |
//! | ESF-L001 | hash-iter       | det paths  | iteration over a `HashMap`/`HashSet` binding (`.iter()`, `.keys()`, `.values()`, `.drain()`, `.retain()`, `for … in`) |
//! | ESF-L002 | hash-container  | det paths  | any `HashMap`/`HashSet` declaration/construction (waiver documents keyed-lookup-only use) |
//! | ESF-L003 | wall-clock      | everywhere | `Instant` / `SystemTime` (host wall-clock) |
//! | ESF-L004 | os-random       | everywhere except `util/rng.rs` | OS/entropy randomness: `RandomState`, `DefaultHasher`, `getrandom`, `from_entropy`, `rand` paths |
//! | ESF-L005 | thread-id       | everywhere | `thread::current` / `ThreadId` influencing behavior |
//! | ESF-L006 | float-time      | det paths except `engine/time.rs` | float-valued expression cast `as Ps` (simulated-time construction outside the sanctioned converters) |
//! | ESF-L007 | narrow-cast     | det paths  | truncating `as u8/u16/u32` of a time/id-flavored identifier |
//!
//! **Deterministic paths** are the modules whose behavior must be a pure
//! function of the config: `engine/`, `interconnect/`, `devices/`,
//! `sweep/`, `workloads/`, `ssd/`, `dram/`, `proto/`, `config/`,
//! `metrics/`, `server/` (the daemon schedules host threads but its
//! results — job ids, cell rows, cache decisions — must be pure
//! functions of the submissions). Host-side layers (`cpu/` wall-clock
//! speed measurement, `runtime/` PJRT artifact caching, `util/`, the
//! CLI) are exempt from the det-path rules but still covered by the
//! global ones — the legitimate wall-clock sites (`main.rs`,
//! `cpu/mod.rs`, `server/mod.rs` duration logging) carry `det-ok`
//! waivers and `#[allow(clippy::disallowed_methods)]`.
//!
//! ## Waivers
//!
//! `// det-ok: <reason>` on the finding's line — or on a comment line
//! directly above it — suppresses every rule on that line. The reason is
//! mandatory (an empty one is itself a violation, ESF-L000) and should
//! say *why* the construct cannot leak nondeterminism into results.

pub mod lexer;

use crate::util::json::Json;
use crate::util::table::Table;
use std::path::Path;

/// Module prefixes whose behavior must be bit-deterministic.
pub const DET_PATHS: &[&str] = &[
    "engine/",
    "interconnect/",
    "devices/",
    "sweep/",
    "workloads/",
    "ssd/",
    "dram/",
    "proto/",
    "config/",
    "metrics/",
    "server/",
];

/// Where a rule applies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scope {
    /// Every scanned file.
    All,
    /// Every scanned file except the listed relative paths.
    AllExcept(&'static [&'static str]),
    /// Only files under [`DET_PATHS`].
    DetPaths,
    /// Det paths minus the listed relative paths.
    DetPathsExcept(&'static [&'static str]),
}

/// One catalog entry.
#[derive(Clone, Copy, Debug)]
pub struct Rule {
    pub id: &'static str,
    pub name: &'static str,
    pub summary: &'static str,
    pub scope: Scope,
}

/// The full rule catalog, in id order. Ids are stable: tools (CI, waiver
/// comments, fixture tests) may reference them forever.
pub const RULES: &[Rule] = &[
    Rule {
        id: "ESF-L000",
        name: "waiver-reason",
        summary: "det-ok waiver must carry a non-empty reason",
        scope: Scope::All,
    },
    Rule {
        id: "ESF-L001",
        name: "hash-iter",
        summary: "iteration over a hash container (order is nondeterministic)",
        scope: Scope::DetPaths,
    },
    Rule {
        id: "ESF-L002",
        name: "hash-container",
        summary: "HashMap/HashSet in a deterministic path (waiver = keyed lookup only)",
        scope: Scope::DetPaths,
    },
    Rule {
        id: "ESF-L003",
        name: "wall-clock",
        summary: "host wall-clock read (Instant/SystemTime)",
        scope: Scope::All,
    },
    Rule {
        id: "ESF-L004",
        name: "os-random",
        summary: "OS/entropy randomness outside util/rng.rs",
        scope: Scope::AllExcept(&["util/rng.rs"]),
    },
    Rule {
        id: "ESF-L005",
        name: "thread-id",
        summary: "thread identity influencing behavior",
        scope: Scope::All,
    },
    Rule {
        id: "ESF-L006",
        name: "float-time",
        summary: "float expression cast to Ps outside engine/time.rs",
        scope: Scope::DetPathsExcept(&["engine/time.rs"]),
    },
    Rule {
        id: "ESF-L007",
        name: "narrow-cast",
        summary: "truncating cast of a time/id-flavored value",
        scope: Scope::DetPaths,
    },
];

/// One lint violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// The offending source line (code portion, trimmed).
    pub excerpt: String,
}

/// Result of linting one file or a whole tree.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// Waiver comments that suppressed at least one finding.
    pub waivers_used: usize,
}

impl LintReport {
    pub fn ok(&self) -> bool {
        self.findings.is_empty()
    }
}

fn rule(id: &'static str) -> &'static Rule {
    RULES.iter().find(|r| r.id == id).expect("unknown rule id")
}

fn in_scope(scope: Scope, rel: &str) -> bool {
    let det = DET_PATHS.iter().any(|p| rel.starts_with(p));
    match scope {
        Scope::All => true,
        Scope::AllExcept(ex) => !ex.contains(&rel),
        Scope::DetPaths => det,
        Scope::DetPathsExcept(ex) => det && !ex.contains(&rel),
    }
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// `needle` appears in `hay` with non-identifier characters (or edges) on
/// both sides.
fn contains_word(hay: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = hay[start..].find(needle) {
        let at = start + pos;
        let before_ok = at == 0 || !is_ident(hay[..at].chars().next_back().unwrap());
        let after = at + needle.len();
        let after_ok = after >= hay.len() || !is_ident(hay[after..].chars().next().unwrap());
        if before_ok && after_ok {
            return true;
        }
        start = at + needle.len().max(1);
    }
    false
}

/// Any float literal (`digit . digit`) in the code text.
fn has_float_literal(code: &str) -> bool {
    let b: Vec<char> = code.chars().collect();
    for i in 1..b.len().saturating_sub(1) {
        if b[i] == '.' && b[i - 1].is_ascii_digit() && b[i + 1].is_ascii_digit() {
            return true;
        }
    }
    false
}

/// Identifier token ending right before byte offset `end` (skipping
/// trailing whitespace), or None if the preceding token is not a bare
/// identifier (e.g. `)`, `]`).
fn ident_before(code: &str, end: usize) -> Option<String> {
    let b: Vec<char> = code[..end].chars().collect();
    let mut i = b.len();
    while i > 0 && b[i - 1].is_whitespace() {
        i -= 1;
    }
    let stop = i;
    while i > 0 && is_ident(b[i - 1]) {
        i -= 1;
    }
    if i == stop {
        return None;
    }
    Some(b[i..stop].iter().collect())
}

/// Keywords marking an identifier as time/id-flavored for ESF-L007.
/// Matched against `_`-separated segments (so `gbps` does not match `ps`
/// but `time_ps`, `txn_id`, `now` do).
const TIMEY_SEGMENTS: &[&str] = &[
    "time", "now", "seq", "txn", "id", "ps", "latency", "lookahead", "deadline",
];

fn is_timey_ident(ident: &str) -> bool {
    ident
        .split('_')
        .any(|seg| TIMEY_SEGMENTS.contains(&seg.to_ascii_lowercase().as_str()))
}

/// Names of bindings declared as hash containers in this file
/// (`name: HashMap<..>` fields/params/struct-literal inits and
/// `let [mut] name = HashMap::new()` style).
fn hash_bindings(lines: &[lexer::Line]) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for l in lines {
        let code = &l.code;
        for container in ["HashMap", "HashSet"] {
            let mut start = 0;
            while let Some(pos) = code[start..].find(container) {
                let at = start + pos;
                start = at + container.len();
                // word-boundary check
                if at > 0 && is_ident(code[..at].chars().next_back().unwrap()) {
                    continue;
                }
                // `name : HashMap` (field, param, struct-literal init)
                let before = code[..at].trim_end();
                if let Some(pre) = before.strip_suffix(':') {
                    // skip `::` paths like std::collections::HashMap
                    if !pre.ends_with(':') {
                        if let Some(name) = ident_before(pre, pre.len()) {
                            if !out.contains(&name) {
                                out.push(name);
                            }
                            continue;
                        }
                    }
                }
                // `let [mut] name ... = ... HashMap::` / `= HashMap::new()`
                if contains_word(code, "let") {
                    if let Some(eq) = code.find('=') {
                        if eq < at {
                            if let Some(name) = ident_before(code, eq) {
                                if name != "mut" && !out.contains(&name) {
                                    out.push(name);
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

const ITER_METHODS: &[&str] = &[
    ".iter()",
    ".iter_mut()",
    ".keys()",
    ".values()",
    ".values_mut()",
    ".drain(",
    ".retain(",
    ".into_iter()",
];

/// `for … in` sugar over binding `b` (possibly `&`, `&mut `, `self.`).
fn for_loop_over(code: &str, b: &str) -> bool {
    if !contains_word(code, "for") {
        return false;
    }
    let Some(pos) = code.find(" in ") else { return false };
    let mut rest = code[pos + 4..].trim_start();
    rest = rest.strip_prefix('&').unwrap_or(rest).trim_start();
    rest = rest.strip_prefix("mut ").unwrap_or(rest).trim_start();
    rest = rest.strip_prefix("self.").unwrap_or(rest);
    if let Some(tail) = rest.strip_prefix(b) {
        let t = tail.trim_start();
        return t.is_empty() || t.starts_with('{');
    }
    false
}

/// Lint one file's source text. `rel` is the `/`-separated path relative
/// to the scan root (it selects which rules apply).
pub fn lint_source(rel: &str, source: &str) -> LintReport {
    let lines = lexer::split_lines(source);
    let scoped = |id: &'static str| in_scope(rule(id).scope, rel);

    // Waivers: line idx -> reason text; empty reason is an ESF-L000.
    let mut waived = vec![false; lines.len()];
    let mut raw = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        if let Some(pos) = l.comment.find("det-ok") {
            let reason = l.comment[pos + "det-ok".len()..]
                .trim_start_matches(':')
                .trim();
            if reason.is_empty() {
                raw.push(Finding {
                    rule: "ESF-L000",
                    file: rel.to_string(),
                    line: i + 1,
                    excerpt: l.comment.trim().to_string(),
                });
            } else {
                waived[i] = true;
            }
        }
    }

    let bindings = if scoped("ESF-L001") {
        hash_bindings(&lines)
    } else {
        Vec::new()
    };

    for (i, l) in lines.iter().enumerate() {
        let code = &l.code;
        if code.trim().is_empty() {
            continue;
        }
        let mut hit = |id: &'static str| {
            raw.push(Finding {
                rule: id,
                file: rel.to_string(),
                line: i + 1,
                excerpt: code.trim().to_string(),
            });
        };

        if scoped("ESF-L001") {
            for b in &bindings {
                let called = ITER_METHODS.iter().any(|m| {
                    let pat = format!("{b}{m}");
                    let mut s = 0;
                    while let Some(pos) = code[s..].find(&pat) {
                        let at = s + pos;
                        // word boundary: `lines.iter()` must not match
                        // inside `capacity_lines.iter()`
                        if at == 0 || !is_ident(code[..at].chars().next_back().unwrap()) {
                            return true;
                        }
                        s = at + 1;
                    }
                    false
                });
                if called || for_loop_over(code, b) {
                    hit("ESF-L001");
                    break;
                }
            }
        }
        if scoped("ESF-L002")
            && !code.trim_start().starts_with("use ")
            && (contains_word(code, "HashMap") || contains_word(code, "HashSet"))
        {
            hit("ESF-L002");
        }
        if scoped("ESF-L003")
            && (contains_word(code, "Instant") || contains_word(code, "SystemTime"))
        {
            hit("ESF-L003");
        }
        if scoped("ESF-L004")
            && (contains_word(code, "RandomState")
                || contains_word(code, "DefaultHasher")
                || contains_word(code, "getrandom")
                || contains_word(code, "from_entropy")
                || contains_word(code, "rand"))
        {
            hit("ESF-L004");
        }
        if scoped("ESF-L005") {
            let squashed: String = code.chars().filter(|c| !c.is_whitespace()).collect();
            if squashed.contains("thread::current") || contains_word(code, "ThreadId") {
                hit("ESF-L005");
            }
        }
        if scoped("ESF-L006") && contains_word(code, "Ps") {
            // `<float evidence> ... as Ps` on one line: the sanctioned
            // converters live in engine/time.rs (exempt above).
            let squashed: String = code.chars().filter(|c| !c.is_whitespace()).collect();
            let casts_to_ps = squashed.contains("asPs");
            let floaty = has_float_literal(code)
                || code.contains(".round()")
                || code.contains(".ceil()")
                || code.contains(".floor()")
                || contains_word(code, "f64")
                || contains_word(code, "f32");
            if casts_to_ps && floaty {
                hit("ESF-L006");
            }
        }
        if scoped("ESF-L007") {
            for narrow in ["u8", "u16", "u32"] {
                let mut start = 0;
                while let Some(pos) = code[start..].find(narrow) {
                    let at = start + pos;
                    start = at + narrow.len();
                    // word-bounded type name preceded by word `as`
                    let after = at + narrow.len();
                    if after < code.len() && is_ident(code[after..].chars().next().unwrap()) {
                        continue;
                    }
                    let before = code[..at].trim_end();
                    let Some(pre) = before.strip_suffix("as") else { continue };
                    if pre
                        .chars()
                        .next_back()
                        .map(is_ident)
                        .unwrap_or(true)
                    {
                        continue; // not the keyword `as` (e.g. `alias u8`)
                    }
                    if let Some(ident) = ident_before(pre, pre.len()) {
                        if is_timey_ident(&ident) {
                            hit("ESF-L007");
                            break;
                        }
                    }
                }
            }
        }
    }

    // Waiver coverage: a `det-ok` covers its own line, and a waiver in a
    // comment block covers the next code line (multi-line justifications
    // propagate through comment-only/blank lines AND attribute lines, so
    // `// det-ok: …` stacks above `#[allow(clippy::disallowed_methods)]`).
    // ESF-L000 is never waivable — a malformed waiver cannot waive itself.
    let mut coverage: Vec<Option<usize>> = vec![None; lines.len()];
    let mut pending: Option<usize> = None;
    for (i, l) in lines.iter().enumerate() {
        if waived[i] {
            pending = Some(i);
        }
        coverage[i] = pending;
        let code = l.code.trim();
        if !code.is_empty() && !code.starts_with("#[") && !code.starts_with("#![") {
            pending = None;
        }
    }
    let mut used = vec![false; lines.len()];
    let findings: Vec<Finding> = raw
        .into_iter()
        .filter(|f| {
            if f.rule == "ESF-L000" {
                return true;
            }
            match coverage[f.line - 1] {
                Some(src) => {
                    used[src] = true;
                    false
                }
                None => true,
            }
        })
        .collect();

    LintReport {
        findings,
        files_scanned: 1,
        waivers_used: used.iter().filter(|u| **u).count(),
    }
}

/// Recursively collect `.rs` files under `root`, sorted for deterministic
/// report order, and lint each.
pub fn lint_tree(root: &Path) -> std::io::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(root, root, &mut files)?;
    files.sort();
    let mut report = LintReport::default();
    for rel in files {
        let text = std::fs::read_to_string(root.join(&rel))?;
        let one = lint_source(&rel, &text);
        report.findings.extend(one.findings);
        report.files_scanned += 1;
        report.waivers_used += one.waivers_used;
    }
    Ok(report)
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<String>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Human-readable findings table (`esf lint`).
pub fn report_table(r: &LintReport) -> Table {
    let mut t = Table::new("determinism lint", &["rule", "location", "finding"]);
    for f in &r.findings {
        let mut excerpt = f.excerpt.clone();
        if excerpt.len() > 60 {
            excerpt.truncate(57);
            excerpt.push_str("...");
        }
        t.row(&[
            f.rule.to_string(),
            format!("{}:{}", f.file, f.line),
            excerpt,
        ]);
    }
    t.note(format!(
        "{} file(s) scanned, {} finding(s), {} waiver(s) applied",
        r.files_scanned,
        r.findings.len(),
        r.waivers_used
    ));
    t
}

/// Machine-readable report (`esf lint --json`).
pub fn report_json(r: &LintReport) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(r.ok())),
        ("files_scanned", Json::Num(r.files_scanned as f64)),
        ("waivers_used", Json::Num(r.waivers_used as f64)),
        (
            "findings",
            Json::Arr(
                r.findings
                    .iter()
                    .map(|f| {
                        Json::obj(vec![
                            ("rule", Json::Str(f.rule.to_string())),
                            ("file", Json::Str(f.file.clone())),
                            ("line", Json::Num(f.line as f64)),
                            ("excerpt", Json::Str(f.excerpt.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Rule catalog table (`esf lint --rules`).
pub fn rules_table() -> Table {
    let mut t = Table::new("determinism lint rules", &["id", "name", "scope", "summary"]);
    for r in RULES {
        let scope = match r.scope {
            Scope::All => "everywhere".to_string(),
            Scope::AllExcept(ex) => format!("everywhere except {}", ex.join(", ")),
            Scope::DetPaths => "det paths".to_string(),
            Scope::DetPathsExcept(ex) => format!("det paths except {}", ex.join(", ")),
        };
        t.row(&[
            r.id.to_string(),
            r.name.to_string(),
            scope,
            r.summary.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(rel: &str, src: &str) -> Vec<&'static str> {
        lint_source(rel, src).findings.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn det_path_scoping() {
        assert!(in_scope(Scope::DetPaths, "engine/mod.rs"));
        assert!(in_scope(Scope::DetPaths, "devices/cache.rs"));
        assert!(in_scope(Scope::DetPaths, "server/wire.rs"));
        assert!(!in_scope(Scope::DetPaths, "cpu/mod.rs"));
        assert!(!in_scope(Scope::DetPaths, "main.rs"));
        assert!(!in_scope(Scope::DetPathsExcept(&["engine/time.rs"]), "engine/time.rs"));
        assert!(!in_scope(Scope::AllExcept(&["util/rng.rs"]), "util/rng.rs"));
        assert!(in_scope(Scope::AllExcept(&["util/rng.rs"]), "util/json.rs"));
    }

    #[test]
    fn comments_and_strings_never_trip() {
        let src = "/// uses HashMap internally\nlet s = \"Instant::now\";\n// SystemTime notes\n";
        assert!(ids("engine/mod.rs", src).is_empty());
    }

    #[test]
    fn waiver_on_same_and_previous_line() {
        let bad = "let m: HashMap<u64, u64> = HashMap::new();";
        assert_eq!(ids("engine/x.rs", bad), vec!["ESF-L002"]);
        let same = "let m: HashMap<u64, u64> = HashMap::new(); // det-ok: keyed only";
        assert!(ids("engine/x.rs", same).is_empty());
        let above = "// det-ok: keyed only\nlet m: HashMap<u64, u64> = HashMap::new();";
        assert!(ids("engine/x.rs", above).is_empty());
    }

    #[test]
    fn empty_waiver_reason_is_a_finding() {
        assert_eq!(ids("engine/x.rs", "let x = 1; // det-ok:\n"), vec!["ESF-L000"]);
        // ...and it does not waive the line it sits on.
        let r = ids("engine/x.rs", "let m: HashMap<u8,u8>; // det-ok:");
        assert!(r.contains(&"ESF-L000") && r.contains(&"ESF-L002"), "{r:?}");
    }

    #[test]
    fn waiver_propagates_through_attribute_lines() {
        // The clippy-allow + det-ok stack used at the two sanctioned
        // wall-clock sites (main.rs, cpu/mod.rs).
        let src = "// det-ok: host-side duration report only\n\
                   #[allow(clippy::disallowed_methods)]\n\
                   let t0 = std::time::Instant::now();\n";
        let r = lint_source("util/x.rs", src);
        assert!(r.ok(), "{:?}", r.findings);
        assert_eq!(r.waivers_used, 1);
    }

    #[test]
    fn timey_ident_matching_is_segmented() {
        assert!(is_timey_ident("time_ps"));
        assert!(is_timey_ident("txn_id"));
        assert!(is_timey_ident("now"));
        assert!(!is_timey_ident("gbps"));
        assert!(!is_timey_ident("width"));
        assert!(!is_timey_ident("die_idx"));
    }
}
