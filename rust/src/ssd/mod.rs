//! SSD endpoint timing model — the SimpleSSD substitute (paper Table I
//! integrates SimpleSSD for SSD endpoints; we provide an in-tree
//! channel/die NAND model with an FTL page map, exercising the same
//! event-driven endpoint-wrapper interface as the DRAM model).
//!
//! First-order model: page-granular FTL (log-structured writes), per-die
//! NAND read/program occupancy, per-channel transfer serialization.

use crate::devices::memdev::MemBackend;
use crate::engine::time::{ns, us, Ps};
use crate::util::rng::Pcg32;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct SsdCfg {
    pub channels: usize,
    pub dies_per_channel: usize,
    pub page_bytes: u64,
    /// NAND array read (tR).
    pub read_lat: Ps,
    /// NAND program (tPROG).
    pub program_lat: Ps,
    /// Channel transfer time per page.
    pub xfer_lat: Ps,
    /// FTL lookup/processing per request.
    pub ftl_lat: Ps,
}

impl Default for SsdCfg {
    fn default() -> Self {
        // TLC-class NAND.
        SsdCfg {
            channels: 8,
            dies_per_channel: 4,
            page_bytes: 4096,
            read_lat: us(45.0),
            program_lat: us(660.0),
            xfer_lat: us(3.0),
            ftl_lat: ns(500.0),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct SsdStats {
    pub reads: u64,
    pub writes: u64,
    pub mapped_pages: u64,
}

pub struct SsdBackend {
    cfg: SsdCfg,
    /// die occupancy: busy-until per (channel, die).
    dies: Vec<Ps>,
    /// channel bus busy-until.
    channels: Vec<Ps>,
    /// FTL: logical page -> physical (channel, die). Writes go
    /// log-structured round-robin; reads follow the map.
    // det-ok: keyed get/insert only — the FTL map is never iterated, so
    // hash order cannot reach timing or placement.
    ftl: HashMap<u64, (usize, usize)>,
    write_ptr: usize,
    rng: Pcg32,
    pub stats: SsdStats,
}

impl SsdBackend {
    pub fn new(cfg: SsdCfg, seed: u64) -> SsdBackend {
        SsdBackend {
            dies: vec![0; cfg.channels * cfg.dies_per_channel],
            channels: vec![0; cfg.channels],
            ftl: HashMap::new(), // det-ok: keyed lookup only, never iterated
            write_ptr: 0,
            rng: Pcg32::new(seed, 0x55d),
            stats: SsdStats::default(),
            cfg,
        }
    }

    fn die_count(&self) -> usize {
        self.cfg.channels * self.cfg.dies_per_channel
    }

    fn place_read(&mut self, page: u64) -> (usize, usize) {
        if let Some(&loc) = self.ftl.get(&page) {
            return loc;
        }
        // Unwritten page: pretend it was placed somewhere (pre-conditioned
        // drive) — deterministic pseudo-random placement.
        let d = (self.rng.next_u64() % self.die_count() as u64) as usize;
        let loc = (d / self.cfg.dies_per_channel, d % self.cfg.dies_per_channel);
        self.ftl.insert(page, loc);
        self.stats.mapped_pages += 1;
        loc
    }

    fn place_write(&mut self, page: u64) -> (usize, usize) {
        // Log-structured: round-robin across dies for write parallelism.
        let d = self.write_ptr % self.die_count();
        self.write_ptr += 1;
        let loc = (d / self.cfg.dies_per_channel, d % self.cfg.dies_per_channel);
        if self.ftl.insert(page, loc).is_none() {
            self.stats.mapped_pages += 1;
        }
        loc
    }
}

impl MemBackend for SsdBackend {
    fn access(&mut self, addr: u64, is_write: bool, at: Ps) -> Ps {
        let page = addr / self.cfg.page_bytes;
        let (ch, die) = if is_write {
            self.stats.writes += 1;
            self.place_write(page)
        } else {
            self.stats.reads += 1;
            self.place_read(page)
        };
        let die_idx = ch * self.cfg.dies_per_channel + die;
        let start = (at + self.cfg.ftl_lat).max(self.dies[die_idx]);
        let nand = if is_write {
            self.cfg.program_lat
        } else {
            self.cfg.read_lat
        };
        let nand_done = start + nand;
        self.dies[die_idx] = nand_done;
        // Page transfer serializes on the channel.
        let xfer_start = nand_done.max(self.channels[ch]);
        let done = xfer_start + self.cfg.xfer_lat;
        self.channels[ch] = done;
        done
    }

    fn name(&self) -> &'static str {
        "ssd(nand-ftl-model)"
    }

    fn snapshot(&self, w: &mut crate::util::snap::SnapWriter) {
        w.usize(self.dies.len());
        for &d in &self.dies {
            w.u64(d);
        }
        w.usize(self.channels.len());
        for &c in &self.channels {
            w.u64(c);
        }
        // det-ok: collected and sorted by logical page before writing, so
        // hash order never reaches the snapshot bytes.
        let mut pages: Vec<(u64, (usize, usize))> = self.ftl.iter().map(|(&k, &v)| (k, v)).collect();
        pages.sort_unstable_by_key(|&(k, _)| k);
        w.usize(pages.len());
        for (page, (ch, die)) in pages {
            w.u64(page);
            w.usize(ch);
            w.usize(die);
        }
        w.usize(self.write_ptr);
        let (state, inc) = self.rng.save_state();
        w.u64(state);
        w.u64(inc);
        w.u64(self.stats.reads);
        w.u64(self.stats.writes);
        w.u64(self.stats.mapped_pages);
    }

    fn restore(&mut self, r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        let nd = r.usize()?;
        if nd != self.dies.len() {
            return Err(format!(
                "snapshot has {nd} NAND dies, this backend has {}",
                self.dies.len()
            ));
        }
        for d in &mut self.dies {
            *d = r.u64()?;
        }
        let nc = r.usize()?;
        if nc != self.channels.len() {
            return Err(format!(
                "snapshot has {nc} channels, this backend has {}",
                self.channels.len()
            ));
        }
        for c in &mut self.channels {
            *c = r.u64()?;
        }
        self.ftl.clear();
        for _ in 0..r.usize()? {
            let page = r.u64()?;
            let ch = r.usize()?;
            let die = r.usize()?;
            self.ftl.insert(page, (ch, die));
        }
        self.write_ptr = r.usize()?;
        let state = r.u64()?;
        let inc = r.u64()?;
        self.rng = Pcg32::from_state(state, inc);
        self.stats.reads = r.u64()?;
        self.stats.writes = r.u64()?;
        self.stats.mapped_pages = r.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_after_write_hits_same_die() {
        let mut s = SsdBackend::new(SsdCfg::default(), 1);
        let w = s.access(0, true, 0);
        let loc_w = s.ftl[&0];
        let _r = s.access(0, false, w);
        assert_eq!(s.ftl[&0], loc_w, "read must follow the FTL map");
    }

    #[test]
    fn program_much_slower_than_read() {
        let mut s = SsdBackend::new(SsdCfg::default(), 1);
        let w = s.access(0, true, 0);
        let mut s2 = SsdBackend::new(SsdCfg::default(), 1);
        let r = s2.access(0, false, 0);
        assert!(w > 5 * r, "program {w} vs read {r}");
    }

    #[test]
    fn writes_stripe_across_dies() {
        let cfg = SsdCfg::default();
        let n = cfg.channels * cfg.dies_per_channel;
        let mut s = SsdBackend::new(cfg, 1);
        // n sequential page writes at t=0 should land on n distinct dies.
        // det-ok: distinct-count assertion only (insert + len), no iteration
        let mut locs = std::collections::HashSet::new();
        for p in 0..n as u64 {
            s.access(p * 4096, true, 0);
            locs.insert(s.ftl[&p]);
        }
        assert_eq!(locs.len(), n);
    }

    #[test]
    fn die_occupancy_serializes_same_die() {
        let cfg = SsdCfg {
            channels: 1,
            dies_per_channel: 1,
            ..SsdCfg::default()
        };
        let mut s = SsdBackend::new(cfg.clone(), 1);
        let a = s.access(0, false, 0);
        let b = s.access(4096, false, 0);
        assert!(b >= a + cfg.read_lat, "single die must serialize");
    }

    #[test]
    fn deterministic_placement() {
        let mk = || {
            let mut s = SsdBackend::new(SsdCfg::default(), 7);
            (0..20u64).map(|p| s.access(p * 4096, false, 0)).collect::<Vec<_>>()
        };
        assert_eq!(mk(), mk());
    }
}
