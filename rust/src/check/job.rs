//! ESF-C016 — daemon job-spec validation.
//!
//! Every frame a client sends `esfd` is validated here **before** it can
//! touch the queue: the envelope must name a known `op` with the right
//! operands, and a `submit`'s embedded grid must pass the full grid rule
//! set ([`super::grid`], ESF-C010/C011/C012) with its error loci
//! re-rooted under `$.grid` so they point into the submitted document.
//! A rejected spec answers with an error frame carrying every violation;
//! the daemon never queues, partially runs, or panics on malformed
//! input. `esf check <job.json>` runs the same rules offline (any JSON
//! document with an `"op"` key dispatches here).

use super::grid::check_grid_json;
use super::{CheckError, CheckReport};
use crate::util::json::Json;

/// Ops the `esfd/1` protocol accepts.
pub const JOB_OPS: [&str; 4] = ["submit", "status", "attach", "ping"];

/// Control op accepted alongside [`JOB_OPS`] (listed separately so the
/// catalog of *job* operations stays honest — shutdown carries no job).
pub const CONTROL_OP: &str = "shutdown";

/// ESF-C016: validate one protocol request. Always returns a report
/// (subject `"job spec"`); use [`CheckReport::ok`] to gate queueing.
pub fn check_job_json(j: &Json) -> CheckReport {
    let mut errors = Vec::new();
    let mut bad = |path: &str, msg: String| {
        errors.push(CheckError::new("ESF-C016", path, msg));
    };
    if j.as_obj().is_none() {
        bad("$", "job spec must be a JSON object".into());
        return report(errors);
    }
    let op = match j.get("op") {
        None => {
            bad("$.op", "missing required field 'op'".into());
            return report(errors);
        }
        Some(v) => match v.as_str() {
            None => {
                bad("$.op", "'op' must be a string".into());
                return report(errors);
            }
            Some(op) => op,
        },
    };
    match op {
        "submit" => match j.get("grid") {
            None => bad("$.grid", "submit requires a 'grid' document".into()),
            Some(grid) => {
                // Full grid validation with loci re-rooted under $.grid
                // so they locate errors inside the submitted spec.
                for e in check_grid_json(grid).errors {
                    errors.push(CheckError {
                        rule: e.rule,
                        path: format!("$.grid{}", e.path.trim_start_matches('$')),
                        msg: e.msg,
                    });
                }
            }
        },
        "attach" => match j.get("job").and_then(Json::as_str) {
            Some(_) => {}
            None => bad("$.job", "attach requires a string 'job' id".into()),
        },
        "status" => {
            // The job filter is optional, but if present it must be an id.
            if let Some(v) = j.get("job") {
                if v.as_str().is_none() {
                    bad("$.job", "status 'job' filter must be a string id".into());
                }
            }
        }
        "ping" => {}
        s if s == CONTROL_OP => {}
        other => bad(
            "$.op",
            format!("unknown op '{other}' (expected one of {JOB_OPS:?} or '{CONTROL_OP}')"),
        ),
    }
    report(errors)
}

fn report(errors: Vec<CheckError>) -> CheckReport {
    CheckReport {
        errors,
        subject: "job spec".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn errs(src: &str) -> Vec<CheckError> {
        check_job_json(&Json::parse(src).unwrap()).errors
    }

    #[test]
    fn well_formed_requests_pass() {
        for src in [
            r#"{"op":"submit","grid":{"sweep":{"scale":[4,8]}}}"#,
            r#"{"op":"status"}"#,
            r#"{"op":"status","job":"j0-0000000000000000"}"#,
            r#"{"op":"attach","job":"j1-00000000deadbeef"}"#,
            r#"{"op":"ping"}"#,
            r#"{"op":"shutdown"}"#,
        ] {
            assert!(errs(src).is_empty(), "{src} should pass");
        }
    }

    #[test]
    fn envelope_violations_carry_exact_loci() {
        for (src, path) in [
            (r#"[1,2]"#, "$"),
            (r#"{"grid":{}}"#, "$.op"),
            (r#"{"op":7}"#, "$.op"),
            (r#"{"op":"restart"}"#, "$.op"),
            (r#"{"op":"submit"}"#, "$.grid"),
            (r#"{"op":"attach"}"#, "$.job"),
            (r#"{"op":"attach","job":3}"#, "$.job"),
            (r#"{"op":"status","job":3}"#, "$.job"),
        ] {
            let errors = errs(src);
            assert!(
                errors.iter().any(|e| e.rule == "ESF-C016" && e.path == path),
                "{src}: expected ESF-C016 at {path}, got {errors:?}"
            );
        }
    }

    /// Grid violations surface through the job spec with their original
    /// rule ids and loci re-rooted under `$.grid`, so a daemon rejection
    /// points into the document the client actually submitted.
    #[test]
    fn grid_violations_are_rerooted_under_grid() {
        let errors = errs(r#"{"op":"submit","grid":{"sweep":{"warp":[1]}}}"#);
        assert!(
            errors.iter().any(|e| e.rule == "ESF-C010" && e.path == "$.grid.sweep.warp"),
            "{errors:?}"
        );
        let errors = errs(
            r#"{"op":"submit","grid":{"base":{"requester":{"read_ratio":1.5}},
                "sweep":{"scale":[4]}}}"#,
        );
        assert!(
            errors.iter().any(|e| e.rule == "ESF-C012" && e.path.starts_with("$.grid.")),
            "{errors:?}"
        );
    }
}
