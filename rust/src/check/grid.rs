//! Sweep-grid well-formedness checks (ESF-C000/C010/C011/C012).
//!
//! `GridSpec::from_json` already rejects malformed grids, but it stops at
//! the **first** error and reports it without a location. This validator
//! walks the whole grid document, collects every problem, and pins each
//! one to a precise JSON path (`$.sweep.scale[2]`, `$.base.requester`,
//! `$.jobs`), so a 17-axis study config can be fixed in one edit cycle.
//! It never expands the cartesian product: axis values are probed one at
//! a time against a clone of the base config, and the expansion size is
//! checked arithmetically (ESF-C011).

use crate::check::{check_config, CheckError, CheckReport};
use crate::config::SystemCfg;
use crate::sweep::{apply_axis, AXES};
use crate::util::json::Json;

/// Cap mirrored from `GridSpec::from_json` — keep in sync.
pub const GRID_SCENARIO_CAP: u64 = 100_000;

/// Validate a parsed grid document. Also accepts the errors a broken
/// parse would hide: call [`check_grid_str`] on raw text to get ESF-C000
/// parse errors with a byte offset.
pub fn check_grid_json(j: &Json) -> CheckReport {
    let mut errors = Vec::new();

    // Base config: must parse as a system config; then its values must be
    // sane (a bad base poisons every scenario in the product).
    let base = match j.get("base") {
        Some(b) => match SystemCfg::from_json(b) {
            Ok(cfg) => {
                for mut e in check_config(&cfg) {
                    e.path = format!("$.base{}", e.path.trim_start_matches('$'));
                    errors.push(e);
                }
                Some(cfg)
            }
            Err(e) => {
                errors.push(CheckError {
                    rule: "ESF-C012",
                    path: "$.base".to_string(),
                    msg: e.to_string(),
                });
                None
            }
        },
        None => SystemCfg::from_json(&Json::Obj(Default::default())).ok(),
    };

    for key in ["jobs", "intra_jobs"] {
        if let Some(v) = j.get(key) {
            if v.as_u64().is_none() {
                errors.push(CheckError {
                    rule: "ESF-C012",
                    path: format!("$.{key}"),
                    msg: format!("'{key}' must be a non-negative integer, got {v}"),
                });
            }
        }
    }

    // Sweep object: each axis must be a known name with a non-empty array
    // of individually applicable values.
    let mut expansion: u64 = 1;
    match j.get("sweep").map(|s| (s, s.as_obj())) {
        None => errors.push(CheckError {
            rule: "ESF-C010",
            path: "$.sweep".to_string(),
            msg: "grid config needs a \"sweep\" object of axis arrays".to_string(),
        }),
        Some((s, None)) => errors.push(CheckError {
            rule: "ESF-C010",
            path: "$.sweep".to_string(),
            msg: format!("\"sweep\" must be an object of axis arrays, got {s}"),
        }),
        Some((_, Some(axes))) => {
            for (key, vals) in axes {
                let axis_path = format!("$.sweep.{key}");
                if !AXES.contains(&key.as_str()) {
                    errors.push(CheckError {
                        rule: "ESF-C010",
                        path: axis_path,
                        msg: format!("unknown sweep axis '{key}' (known: {})", AXES.join(", ")),
                    });
                    continue;
                }
                let Some(arr) = vals.as_arr() else {
                    errors.push(CheckError {
                        rule: "ESF-C010",
                        path: axis_path,
                        msg: format!("axis '{key}' must be an array of values, got {vals}"),
                    });
                    continue;
                };
                if arr.is_empty() {
                    errors.push(CheckError {
                        rule: "ESF-C010",
                        path: axis_path,
                        msg: format!("axis '{key}' has no values"),
                    });
                    continue;
                }
                expansion = expansion.saturating_mul(arr.len() as u64);
                if let Some(base) = &base {
                    // Errors the base already has must not be re-reported
                    // for every probed value — only what the value changed.
                    let base_errs: Vec<(&str, String)> = check_config(base)
                        .into_iter()
                        .map(|e| (e.rule, e.path))
                        .collect();
                    for (i, v) in arr.iter().enumerate() {
                        let mut probe = base.clone();
                        match apply_axis(&mut probe, key, v) {
                            Err(e) => errors.push(CheckError {
                                rule: "ESF-C010",
                                path: format!("$.sweep.{key}[{i}]"),
                                msg: e.to_string(),
                            }),
                            Ok(()) => {
                                for pe in check_config(&probe) {
                                    if base_errs.contains(&(pe.rule, pe.path.clone())) {
                                        continue;
                                    }
                                    errors.push(CheckError {
                                        rule: pe.rule,
                                        path: format!("$.sweep.{key}[{i}]"),
                                        msg: pe.msg,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    if expansion > GRID_SCENARIO_CAP {
        errors.push(CheckError {
            rule: "ESF-C011",
            path: "$.sweep".to_string(),
            msg: format!("grid expands to {expansion} scenarios (cap {GRID_SCENARIO_CAP})"),
        });
    }

    CheckReport {
        errors,
        subject: "sweep grid".to_string(),
    }
}

/// Validate raw grid text: ESF-C000 on parse failure, else the full
/// structural pass.
pub fn check_grid_str(text: &str) -> CheckReport {
    match Json::parse(text) {
        Ok(j) => check_grid_json(&j),
        Err(e) => CheckReport {
            errors: vec![CheckError {
                rule: "ESF-C000",
                path: format!("byte {}", e.pos),
                msg: e.msg,
            }],
            subject: "sweep grid".to_string(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_grid_passes() {
        let r = check_grid_str(
            r#"{"base": {"scale": 8}, "sweep": {"read_ratio": [0.5, 1.0], "scale": [8, 16]}}"#,
        );
        assert!(r.ok(), "{:?}", r.errors);
    }

    #[test]
    fn parse_error_is_c000_with_offset() {
        let r = check_grid_str("{\"sweep\": ");
        assert_eq!(r.errors.len(), 1);
        assert_eq!(r.errors[0].rule, "ESF-C000");
        assert!(r.errors[0].path.starts_with("byte "));
    }

    #[test]
    fn bad_axis_value_reports_exact_path() {
        let r = check_grid_str(r#"{"sweep": {"scale": [8, 16, "big"]}}"#);
        assert_eq!(r.errors.len(), 1);
        assert_eq!(r.errors[0].rule, "ESF-C010");
        assert_eq!(r.errors[0].path, "$.sweep.scale[2]");
    }

    #[test]
    fn unknown_axis_and_empty_axis_both_collected() {
        let r = check_grid_str(r#"{"sweep": {"scal": [8], "read_ratio": []}}"#);
        let rules: Vec<_> = r.errors.iter().map(|e| (e.rule, e.path.as_str())).collect();
        assert!(rules.contains(&("ESF-C010", "$.sweep.scal")), "{rules:?}");
        assert!(rules.contains(&("ESF-C010", "$.sweep.read_ratio")), "{rules:?}");
    }

    #[test]
    fn out_of_range_axis_value_is_caught_via_probe() {
        // apply_axis accepts 1.5 (no range check there); the probe's
        // check_config pass must catch it at the sweep-value path.
        let r = check_grid_str(r#"{"sweep": {"read_ratio": [0.5, 1.5]}}"#);
        assert_eq!(r.errors.len(), 1, "{:?}", r.errors);
        assert_eq!(r.errors[0].rule, "ESF-C012");
        assert_eq!(r.errors[0].path, "$.sweep.read_ratio[1]");
    }

    #[test]
    fn oversized_grid_is_c011_without_expansion() {
        // 60^3 = 216000 > 100000; must be caught arithmetically.
        let vals: Vec<String> = (0..60).map(|i| format!("{}", 2 * (i + 2))).collect();
        let axis = format!("[{}]", vals.join(","));
        let r = check_grid_str(&format!(
            r#"{{"sweep": {{"scale": {axis}, "queue_capacity": {axis}, "requests_per_endpoint": {axis}}}}}"#
        ));
        assert!(r.errors.iter().any(|e| e.rule == "ESF-C011"), "{:?}", r.errors);
    }

    #[test]
    fn bad_base_reports_under_base_path() {
        // `from_json` parses read_ratio 1.5 without complaint — the
        // range check is exactly the gap this pass fills.
        let r = check_grid_str(
            r#"{"base": {"requester": {"read_ratio": 1.5}}, "sweep": {"scale": [8]}}"#,
        );
        assert_eq!(r.errors.len(), 1, "{:?}", r.errors);
        assert_eq!(r.errors[0].rule, "ESF-C012");
        assert_eq!(r.errors[0].path, "$.base.requester.read_ratio");
    }
}
