//! `esf check` — model-level static validation, run before a single event
//! is simulated.
//!
//! Where `esf lint` (the sibling subsystem, `crate::lint`) proves source
//! properties, this module proves **model** properties of a configured
//! system: the routing arena cannot loop, every requester can reach every
//! memory, link configs are physically consistent, a partition satisfies
//! the conservative-parallelism preconditions, and the txn-id namespace
//! `(node+1) << 40 | k` cannot overflow under the configured workload —
//! the always-on guard in `engine::Shared::txn_id` then never fires at
//! runtime. `esf run` / `esf sweep` run these as a pre-pass; the CLI
//! `esf check <config>` runs them standalone (accepting both system
//! configs and sweep grids, see [`grid`]).
//!
//! ## Rule catalog (stable ids)
//!
//! | id       | name                | proves |
//! |----------|---------------------|--------|
//! | ESF-C000 | parse               | config file parses as JSON |
//! | ESF-C001 | route-consistency   | every next-hop candidate strictly decreases distance-to-destination over an incident link (⇒ per-destination loop-freedom), and no reachable cell has an empty candidate set |
//! | ESF-C002 | unreachable         | every requester reaches every memory endpoint |
//! | ESF-C003 | duplex-mismatch     | parallel links between one node pair agree on duplex mode |
//! | ESF-C004 | link-config         | bandwidth is finite and non-negative; turnaround only on half-duplex links |
//! | ESF-C005 | partition-cover     | domains cover every node exactly once, sorted, renumbered by min node id |
//! | ESF-C006 | partition-cut       | cut set = links crossing domains; no half-duplex or zero-latency link is cut |
//! | ESF-C007 | partition-lookahead | lookahead = min latency over cut links (`Ps::MAX` iff nothing is cut), never zero |
//! | ESF-C008 | txn-capacity        | worst-case per-node txn mints stay below `2^40` |
//! | ESF-C009 | node-capacity       | node ids fit the txn namespace (`n+1 < 2^24`) and `u32` event keys |
//! | ESF-C010 | grid-axis           | sweep axis exists, is a non-empty array, every value applies (JSON-path located) |
//! | ESF-C011 | grid-size           | grid expansion stays under the scenario cap |
//! | ESF-C012 | config-value        | scalar config fields are in range (JSON-path located) |
//! | ESF-C013 | window-advance      | adaptive-barrier safety: the horizon graph mirrors the physical cut set exactly (symmetric peers = exchange peers, per-pair latency = minimum cut-link latency, all positive, global minimum = partition lookahead) — a missing edge or understated latency would let a widened window swallow a real arrival |
//! | ESF-C014 | snapshot            | engine snapshot file integrity and fork compatibility: magic/version/digest verify, and the restoring config either matches the snapshot's fingerprint exactly or shares its warm-up prefix projection (prefix-forking additionally requires a quiescent snapshot) |
//! | ESF-C015 | speculation-safety  | speculative-barrier side-conditions: every physically crossing link has positive latency (so the rollback checkpoint taken at the certified frontier dominates every optimistically executed event), the partition lookahead never overstates the physical cut minimum (so the commit frontier — the global seed minimum — can never run ahead of the true GVT), and the bounded speculation window is saturating-monotone in the lookahead (never wrapping below it, never zero on a real cut) |
//! | ESF-C016 | job-spec            | `esfd` protocol requests are well-formed: known `op` with the right operands, and a `submit`'s embedded grid passes the full grid rule set with loci re-rooted under `$.grid` — enforced server-side before anything is queued (see [`job`]) |

pub mod grid;
pub mod job;

use crate::config::SystemCfg;
use crate::engine::time::Ps;
use crate::interconnect::{build, Duplex, Partition, Routing, Topology, WeightModel, UNREACHABLE};
use crate::util::json::Json;
use crate::util::table::Table;

/// The per-node txn counter width in `engine::Shared::txn_id`
/// (`(node+1) << TXN_NODE_SHIFT | k` — keep in sync with `engine::mod`).
pub const TXN_COUNTER_BITS: u32 = 40;

/// Worst-case protocol messages that can mint a txn id per issued request
/// end-to-end (request, per-hop switch forwards bounded by the response
/// path, memory response, snoop/back-invalidation, cache writeback).
/// Deliberately generous: ESF-C008 is a capacity proof, not an estimate.
pub const TXN_MINTS_PER_REQUEST: u64 = 8;

/// One model-check violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckError {
    pub rule: &'static str,
    /// Error locus: a JSON path (`$.requester.read_ratio`,
    /// `$.sweep.scale[2]`) for config-shaped input, a model locus
    /// (`link[3]`, `route[4->7]`, `partition.domains[1]`) otherwise.
    pub path: String,
    pub msg: String,
}

impl CheckError {
    fn new(rule: &'static str, path: impl Into<String>, msg: impl Into<String>) -> CheckError {
        CheckError {
            rule,
            path: path.into(),
            msg: msg.into(),
        }
    }
}

/// Outcome of a full check pass.
#[derive(Clone, Debug, Default)]
pub struct CheckReport {
    pub errors: Vec<CheckError>,
    /// Human label of what was checked (config path, "grid", ...).
    pub subject: String,
}

impl CheckReport {
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }

    pub fn to_table(&self) -> Table {
        let mut t = Table::new("model check", &["rule", "path", "error"]);
        for e in &self.errors {
            t.row(&[e.rule.to_string(), e.path.clone(), e.msg.clone()]);
        }
        t.note(format!("{}: {} error(s)", self.subject, self.errors.len()));
        t
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ok", Json::Bool(self.ok())),
            ("subject", Json::Str(self.subject.clone())),
            (
                "errors",
                Json::Arr(
                    self.errors
                        .iter()
                        .map(|e| {
                            Json::obj(vec![
                                ("rule", Json::Str(e.rule.to_string())),
                                ("path", Json::Str(e.path.clone())),
                                ("msg", Json::Str(e.msg.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

// ------------------------------------------------------------- routing

/// ESF-C001/ESF-C002: next-hop loop-freedom and reachability over the CSR
/// routing arena.
///
/// Loop-freedom proof: if every candidate `w` in cell `(u, v)` satisfies
/// `dist(w, v) + 1 == dist(u, v)` over a link incident to both `u` and
/// `w`, then distance-to-destination strictly decreases at every hop —
/// any packet walk toward `v` is a strictly decreasing sequence in a
/// well-founded order, so no routing cycle can exist for any destination.
pub fn check_routing(topo: &Topology, routing: &Routing) -> Vec<CheckError> {
    let n = topo.n();
    let mut errs = Vec::new();
    for u in 0..n {
        for v in 0..n {
            let d = routing.dist(u, v);
            let cands = routing.candidates(u, v);
            let locus = || format!("route[{u}->{v}]");
            if u == v || d == UNREACHABLE {
                if !cands.is_empty() {
                    errs.push(CheckError::new(
                        "ESF-C001",
                        locus(),
                        format!(
                            "cell is {} but has {} next-hop candidate(s)",
                            if u == v { "reflexive" } else { "unreachable" },
                            cands.len()
                        ),
                    ));
                }
                continue;
            }
            if cands.is_empty() {
                errs.push(CheckError::new(
                    "ESF-C001",
                    locus(),
                    format!("reachable cell (dist {d}) has no next-hop candidate"),
                ));
                continue;
            }
            for &(w, link) in cands {
                let l = &topo.links[link];
                let incident = (l.a == u && l.b == w) || (l.b == u && l.a == w);
                if !incident {
                    errs.push(CheckError::new(
                        "ESF-C001",
                        locus(),
                        format!("candidate ({w}, link {link}) is not a {u}-{w} link"),
                    ));
                }
                let dw = routing.dist(w, v);
                if dw == UNREACHABLE || dw + 1 != d {
                    errs.push(CheckError::new(
                        "ESF-C001",
                        locus(),
                        format!(
                            "candidate {w} does not decrease distance \
                             (dist({w},{v})={dw}, dist({u},{v})={d}) — a loop is possible"
                        ),
                    ));
                }
            }
        }
    }
    // Reachability: every requester must reach every memory endpoint.
    for u in 0..n {
        if !matches!(topo.kind(u), crate::interconnect::NodeKind::Requester) {
            continue;
        }
        for v in 0..n {
            if !matches!(topo.kind(v), crate::interconnect::NodeKind::Memory) {
                continue;
            }
            if routing.dist(u, v) == UNREACHABLE {
                errs.push(CheckError::new(
                    "ESF-C002",
                    format!("route[{u}->{v}]"),
                    format!("requester {u} cannot reach memory {v}"),
                ));
            }
        }
    }
    errs
}

// ------------------------------------------------------------- links

/// ESF-C003/ESF-C004: link-pair duplex consistency and per-link config
/// sanity.
pub fn check_links(topo: &Topology) -> Vec<CheckError> {
    let mut errs = Vec::new();
    for (i, l) in topo.links.iter().enumerate() {
        let locus = format!("link[{i}]");
        if !l.cfg.bandwidth_gbps.is_finite() || l.cfg.bandwidth_gbps < 0.0 {
            errs.push(CheckError::new(
                "ESF-C004",
                locus.clone(),
                format!("bandwidth must be finite and >= 0 (got {})", l.cfg.bandwidth_gbps),
            ));
        }
        if l.cfg.duplex == Duplex::Full && l.cfg.turnaround > 0 {
            errs.push(CheckError::new(
                "ESF-C004",
                locus.clone(),
                format!(
                    "turnaround {} ps configured on a full-duplex link is never \
                     charged — half-duplex intended?",
                    l.cfg.turnaround
                ),
            ));
        }
        // Parallel links over the same node pair must agree on duplex:
        // a half/full mix on one physical pair makes shared-medium
        // accounting ambiguous.
        for (j, m) in topo.links.iter().enumerate().skip(i + 1) {
            let same_pair = (l.a.min(l.b), l.a.max(l.b)) == (m.a.min(m.b), m.a.max(m.b));
            if same_pair && l.cfg.duplex != m.cfg.duplex {
                errs.push(CheckError::new(
                    "ESF-C003",
                    format!("link[{j}]"),
                    format!(
                        "links {i} and {j} both connect nodes {}-{} but disagree on \
                         duplex mode",
                        l.a.min(l.b),
                        l.a.max(l.b)
                    ),
                ));
            }
        }
    }
    // ESF-C009 (node-capacity) lives here too: it is a pure topology
    // property. `(node+1) << 40` must fit u64 and event keys carry src
    // as u32.
    let n = topo.n();
    if (n as u64 + 1) >= (1u64 << (64 - TXN_COUNTER_BITS)) {
        errs.push(CheckError::new(
            "ESF-C009",
            "topology",
            format!(
                "{n} nodes (+1 external origin) overflow the txn-id namespace \
                 ((node+1) << {TXN_COUNTER_BITS} must fit u64)"
            ),
        ));
    }
    errs
}

// ------------------------------------------------------------- partition

/// ESF-C005/C006/C007: conservative-parallelism preconditions for a
/// computed partition (these re-prove what `interconnect::partition`
/// promises, so corruption anywhere upstream fails here, not as a
/// nondeterministic run).
pub fn check_partition(topo: &Topology, part: &Partition) -> Vec<CheckError> {
    let n = topo.n();
    let mut errs = Vec::new();

    // Cover + disjointness + stable numbering.
    if part.domain_of.len() != n {
        errs.push(CheckError::new(
            "ESF-C005",
            "partition.domain_of",
            format!("domain_of covers {} nodes, fabric has {n}", part.domain_of.len()),
        ));
        return errs; // everything below indexes by node
    }
    let mut seen = vec![false; n];
    for (d, members) in part.domains.iter().enumerate() {
        let locus = format!("partition.domains[{d}]");
        if members.is_empty() {
            errs.push(CheckError::new("ESF-C005", locus.clone(), "empty domain"));
        }
        if !members.windows(2).all(|w| w[0] < w[1]) {
            errs.push(CheckError::new(
                "ESF-C005",
                locus.clone(),
                "member list not sorted/duplicate-free",
            ));
        }
        for &node in members {
            if node >= n {
                errs.push(CheckError::new(
                    "ESF-C005",
                    locus.clone(),
                    format!("node {node} out of range"),
                ));
                continue;
            }
            if seen[node] {
                errs.push(CheckError::new(
                    "ESF-C005",
                    locus.clone(),
                    format!("node {node} appears in more than one domain"),
                ));
            }
            seen[node] = true;
            if part.domain_of[node] as usize != d {
                errs.push(CheckError::new(
                    "ESF-C005",
                    locus.clone(),
                    format!(
                        "node {node}: domain_of says {} but membership says {d}",
                        part.domain_of[node]
                    ),
                ));
            }
        }
    }
    for (node, covered) in seen.iter().enumerate() {
        if !covered {
            errs.push(CheckError::new(
                "ESF-C005",
                "partition.domains",
                format!("node {node} is in no domain"),
            ));
        }
    }
    // Stable renumbering: domains ordered by minimum member node id.
    let mins: Vec<usize> = part
        .domains
        .iter()
        .map(|m| m.first().copied().unwrap_or(usize::MAX))
        .collect();
    if !mins.windows(2).all(|w| w[0] < w[1]) {
        errs.push(CheckError::new(
            "ESF-C005",
            "partition.domains",
            "domains not renumbered by minimum node id",
        ));
    }

    // Cut set: exactly the links crossing domains; never half-duplex or
    // zero-latency (both would break barrier-window conservatism).
    for (i, l) in topo.links.iter().enumerate() {
        let crossing = part.domain_of[l.a] != part.domain_of[l.b];
        let in_cut = part.cut_links.contains(&i);
        if crossing != in_cut {
            errs.push(CheckError::new(
                "ESF-C006",
                format!("partition.cut_links/link[{i}]"),
                if crossing {
                    format!("link {i} crosses domains but is not in the cut set")
                } else {
                    format!("link {i} is in the cut set but does not cross domains")
                },
            ));
        }
        if crossing && l.cfg.duplex == Duplex::Half {
            errs.push(CheckError::new(
                "ESF-C006",
                format!("partition.cut_links/link[{i}]"),
                format!(
                    "half-duplex link {i} is cut: both directions share one medium, \
                     so its state cannot be split across domains"
                ),
            ));
        }
        if crossing && l.cfg.latency == 0 {
            errs.push(CheckError::new(
                "ESF-C006",
                format!("partition.cut_links/link[{i}]"),
                format!("zero-latency link {i} is cut: it provides no lookahead"),
            ));
        }
    }

    // Lookahead: min latency over the cut, Ps::MAX iff nothing is cut.
    if part.cut_links.is_empty() {
        if part.lookahead != Ps::MAX {
            errs.push(CheckError::new(
                "ESF-C007",
                "partition.lookahead",
                format!("empty cut needs unbounded lookahead (Ps::MAX), got {}", part.lookahead),
            ));
        }
    } else {
        let min_lat = part
            .cut_links
            .iter()
            .filter_map(|&l| topo.links.get(l).map(|link| link.cfg.latency))
            .min()
            .unwrap_or(0);
        if part.lookahead == 0 {
            errs.push(CheckError::new(
                "ESF-C007",
                "partition.lookahead",
                "zero lookahead with a non-empty cut: the conservative barrier \
                 could not advance",
            ));
        } else if part.lookahead != min_lat {
            errs.push(CheckError::new(
                "ESF-C007",
                "partition.lookahead",
                format!("lookahead {} != min cut-link latency {min_lat}", part.lookahead),
            ));
        }
    }
    errs
}

/// ESF-C013: the adaptive barrier's window-advance safety condition.
/// `engine::parallel` (`BarrierMode::Adaptive`) widens windows by
/// relaxing per-domain activity bounds over [`Partition::horizon_graph`]
/// — so that graph must mirror the *physical* cut set exactly. The
/// per-pair minima are recomputed here from the raw topology and
/// `domain_of` (deliberately not from `part.cut_links`, so a link
/// missing from the cut set still fails this rule rather than hiding
/// behind an ESF-C006 violation): a missing edge or an understated
/// latency would let a widened window swallow a real arrival; an
/// overstated latency or a spurious edge stalls or mis-seeds the
/// relaxation.
pub fn check_window_advance(topo: &Topology, part: &Partition) -> Vec<CheckError> {
    use std::collections::BTreeMap;
    let mut errs = Vec::new();
    if part.domain_of.len() != topo.n() {
        return errs; // ESF-C005 already reports the cover mismatch
    }
    let hg = part.horizon_graph(topo);
    if hg.len() != part.n_domains() {
        errs.push(CheckError::new(
            "ESF-C013",
            "partition.horizon_graph",
            format!("graph covers {} domains, partition has {}", hg.len(), part.n_domains()),
        ));
        return errs;
    }
    // Ground truth: per directed domain pair, the minimum latency over
    // every link physically crossing that pair.
    let mut expect: BTreeMap<(usize, usize), Ps> = BTreeMap::new();
    for l in &topo.links {
        let (da, db) = (part.domain_of[l.a] as usize, part.domain_of[l.b] as usize);
        if da != db {
            for key in [(da, db), (db, da)] {
                let e = expect.entry(key).or_insert(Ps::MAX);
                *e = (*e).min(l.cfg.latency);
            }
        }
    }
    let mut got: BTreeMap<(usize, usize), Ps> = BTreeMap::new();
    for (d, edges) in hg.iter().enumerate() {
        if !edges.windows(2).all(|w| w[0].0 < w[1].0) {
            errs.push(CheckError::new(
                "ESF-C013",
                format!("partition.horizon_graph[{d}]"),
                "peer list not sorted/duplicate-free (must match exchange_peers order)",
            ));
        }
        for &(p, lat) in edges {
            if p >= part.n_domains() || p == d {
                errs.push(CheckError::new(
                    "ESF-C013",
                    format!("partition.horizon_graph[{d}]"),
                    format!("edge to invalid domain {p}"),
                ));
                continue;
            }
            got.insert((d, p), lat);
        }
    }
    for (&(d, p), &lat) in &expect {
        match got.get(&(d, p)) {
            None => errs.push(CheckError::new(
                "ESF-C013",
                format!("partition.horizon_graph[{d}]"),
                format!(
                    "missing edge to cut-neighbor {p}: the relaxation would widen \
                     past arrivals over that cut"
                ),
            )),
            Some(&g) if g != lat => errs.push(CheckError::new(
                "ESF-C013",
                format!("partition.horizon_graph[{d}]"),
                format!("edge to {p} carries latency {g}, physical minimum is {lat}"),
            )),
            _ => {}
        }
    }
    for (&(d, p), &lat) in &got {
        if !expect.contains_key(&(d, p)) {
            errs.push(CheckError::new(
                "ESF-C013",
                format!("partition.horizon_graph[{d}]"),
                format!("spurious edge to {p}: no link crosses that domain pair"),
            ));
        }
        if lat == 0 {
            errs.push(CheckError::new(
                "ESF-C013",
                format!("partition.horizon_graph[{d}]"),
                format!("zero-latency horizon edge to {p}: no conservative window \
                         could ever advance over it"),
            ));
        }
        if got.get(&(p, d)) != Some(&lat) {
            errs.push(CheckError::new(
                "ESF-C013",
                format!("partition.horizon_graph[{d}]"),
                format!("edge to {p} not mirrored symmetrically"),
            ));
        }
    }
    // The relaxation's guaranteed floor (`tmin + lookahead`) must be the
    // global minimum of the graph it runs on.
    if let Some(&min_edge) = got.values().min() {
        if min_edge != part.lookahead {
            errs.push(CheckError::new(
                "ESF-C013",
                "partition.lookahead",
                format!(
                    "global minimum horizon latency {min_edge} != partition \
                     lookahead {}",
                    part.lookahead
                ),
            ));
        }
    }
    errs
}

/// ESF-C015: the speculative barrier's safety side-conditions.
///
/// `BarrierMode::Speculative` (`engine::parallel`) lets a domain execute
/// past its certified horizon and undoes the stint by restoring a
/// checkpoint captured at the certified frontier. That is only sound if
/// (a) the capture point *dominates* every optimistically executed event
/// — every event a stint can consume, and every delivery that can trigger
/// a rollback, postdates the frontier, which requires every physically
/// crossing link to carry positive latency; (b) the commit frontier (the
/// global minimum of the per-domain seeds, the deterministic GVT
/// analogue) is never ahead of the true GVT, which requires the partition
/// lookahead to never *overstate* the physical cut minimum; and (c) the
/// bounded speculation window derived from that lookahead saturates
/// rather than wraps, so the stint bound `end + window` can never land
/// behind the certified horizon. Like ESF-C013, the ground truth is
/// recomputed here from the raw topology and `domain_of` — deliberately
/// not from `part.cut_links` — so upstream corruption fails this rule
/// instead of hiding behind it.
pub fn check_speculation(topo: &Topology, part: &Partition) -> Vec<CheckError> {
    use crate::engine::parallel::speculation_window;
    let mut errs = Vec::new();
    if part.domain_of.len() != topo.n() {
        return errs; // ESF-C005 already reports the cover mismatch
    }
    // Ground truth: the minimum latency over every link that physically
    // crosses domains. `tmin + true_min` lower-bounds every uncommitted
    // event anywhere, so it IS the true GVT bound.
    let mut true_min = Ps::MAX;
    let mut crossing = false;
    for (i, l) in topo.links.iter().enumerate() {
        if part.domain_of[l.a] != part.domain_of[l.b] {
            crossing = true;
            true_min = true_min.min(l.cfg.latency);
            if l.cfg.latency == 0 {
                errs.push(CheckError::new(
                    "ESF-C015",
                    format!("partition.cut_links/link[{i}]"),
                    format!(
                        "zero-latency crossing link {i}: an arrival over it can land \
                         exactly on the certified frontier, so no rollback-capture \
                         point dominates the speculated events"
                    ),
                ));
            }
        }
    }
    if !crossing {
        // Empty cut: the single certified window already drains
        // everything; speculation never starts and nothing can straggle.
        return errs;
    }
    if part.lookahead > true_min {
        errs.push(CheckError::new(
            "ESF-C015",
            "partition.lookahead",
            format!(
                "lookahead {} overstates the physical cut minimum {true_min}: the \
                 commit frontier (global seed minimum + lookahead conservatism) \
                 could run ahead of the true GVT and commit speculative state",
                part.lookahead
            ),
        ));
    }
    let window = speculation_window(part.lookahead);
    if window < part.lookahead {
        errs.push(CheckError::new(
            "ESF-C015",
            "partition.speculation_window",
            format!(
                "speculation window {window} wrapped below the lookahead {} — the \
                 stint bound end + window would land behind the certified horizon",
                part.lookahead
            ),
        ));
    }
    if window == 0 {
        errs.push(CheckError::new(
            "ESF-C015",
            "partition.speculation_window",
            "zero speculation window on a real cut: every stint would be empty \
             and the capture margin vanishes",
        ));
    }
    errs
}

// ------------------------------------------------------------- config

/// ESF-C012 value-range checks plus the ESF-C008 txn-id capacity proof.
/// Paths use the `esf run` JSON schema so errors point into the file the
/// user wrote.
pub fn check_config(cfg: &SystemCfg) -> Vec<CheckError> {
    let mut errs = Vec::new();
    let mut bad = |path: &str, msg: String| {
        errs.push(CheckError::new("ESF-C012", path, msg));
    };
    if cfg.n == 0 {
        bad("$.scale", "system scale must be >= 2 (N requesters + N memories)".into());
    }
    if !cfg.read_ratio.is_finite() || !(0.0..=1.0).contains(&cfg.read_ratio) {
        bad(
            "$.requester.read_ratio",
            format!("read_ratio must be in [0, 1], got {}", cfg.read_ratio),
        );
    }
    if !cfg.warmup_fraction.is_finite() || !(0.0..1.0).contains(&cfg.warmup_fraction) {
        bad(
            "$.requester.warmup_fraction",
            format!("warmup_fraction must be in [0, 1), got {}", cfg.warmup_fraction),
        );
    }
    if cfg.queue_capacity == 0 {
        bad("$.requester.queue_capacity", "queue_capacity must be >= 1".into());
    }
    if cfg.requests_per_endpoint == 0 {
        bad("$.requester.requests_per_endpoint", "requests_per_endpoint must be >= 1".into());
    }
    if cfg.footprint_lines == 0 {
        bad("$.requester.footprint_lines", "footprint_lines must be >= 1".into());
    }
    if !cfg.link.bandwidth_gbps.is_finite() || cfg.link.bandwidth_gbps < 0.0 {
        bad(
            "$.link.bandwidth_gbps",
            format!("bandwidth must be finite and >= 0, got {}", cfg.link.bandwidth_gbps),
        );
    }

    // ESF-C008: worst-case per-node txn mints vs the 2^40 namespace.
    // Every node's counter is bounded by the total protocol messages the
    // workload can generate: each of the `n` requesters issues
    // `requests_per_endpoint * n_memories` requests, each minting at most
    // TXN_MINTS_PER_REQUEST ids anywhere in the fabric (a spine switch
    // sees nearly all of them — hence the fabric-wide bound per node).
    let per_requester = cfg.requests_per_endpoint.saturating_mul(cfg.n as u64);
    let fabric_total = per_requester
        .saturating_mul(cfg.n as u64)
        .saturating_mul(TXN_MINTS_PER_REQUEST);
    if fabric_total >= 1u64 << TXN_COUNTER_BITS {
        errs.push(CheckError::new(
            "ESF-C008",
            "$.requester.requests_per_endpoint",
            format!(
                "workload can mint up to {fabric_total} txn ids at one node, \
                 overflowing the per-node 2^{TXN_COUNTER_BITS} namespace \
                 ({} requesters x {per_requester} requests x {TXN_MINTS_PER_REQUEST} \
                 messages)",
                cfg.n
            ),
        ));
    }
    errs
}

// ------------------------------------------------------------- snapshot

/// ESF-C014: engine snapshot header validation and fork compatibility.
///
/// Structural failures (`snapshot.magic` / `snapshot.version` /
/// `snapshot.digest` / `snapshot.body`) come straight from the format
/// layer ([`crate::engine::snapshot::header`]). With a config given, the
/// restore must additionally be *provably* compatible: either the exact
/// config fingerprint matches (`esf run --restore` resuming the same
/// config), or the configs share the warm-up prefix projection AND the
/// snapshot was taken at the quiescent warm-up boundary (sweep warm-start
/// forking) — mid-run checkpoints carry post-warm-up state that a
/// different config must never inherit (`snapshot.config` /
/// `snapshot.prefix` loci).
pub fn check_snapshot(bytes: &[u8], cfg: Option<&SystemCfg>) -> Vec<CheckError> {
    let hdr = match crate::engine::snapshot::header(bytes) {
        Ok(h) => h,
        Err(e) => {
            return vec![CheckError::new("ESF-C014", e.locus(), e.message())];
        }
    };
    let Some(cfg) = cfg else {
        return Vec::new();
    };
    let mut errs = Vec::new();
    if hdr.cfg_fingerprint == cfg.fingerprint() {
        return errs;
    }
    let prefix_canon = cfg.prefix_canon();
    if hdr.prefix_fingerprint == cfg.prefix_fingerprint() && hdr.prefix_canon == prefix_canon {
        if !hdr.quiescent {
            errs.push(CheckError::new(
                "ESF-C014",
                "snapshot.prefix",
                "prefix-compatible fork requires a quiescent (warm-up boundary) \
                 snapshot; this one is a mid-run checkpoint carrying post-warm-up \
                 state",
            ));
        }
    } else {
        errs.push(CheckError::new(
            "ESF-C014",
            "snapshot.config",
            format!(
                "snapshot was taken under config fingerprint {:#018x}; this config \
                 hashes to {:#018x} and its warm-up prefix projection differs too \
                 (snapshot prefix {:#018x}, config prefix {:#018x}) — neither exact \
                 resume nor prefix fork is sound",
                hdr.cfg_fingerprint,
                cfg.fingerprint(),
                hdr.prefix_fingerprint,
                cfg.prefix_fingerprint()
            ),
        ));
    }
    errs
}

// ------------------------------------------------------------- system

/// Full pre-pass for one system config: config values, fabric links,
/// routing, txn capacity, and — when the config asks for intra-scenario
/// parallelism — the partition preconditions.
pub fn check_system(cfg: &SystemCfg) -> CheckReport {
    let mut errors = check_config(cfg);
    let fabric = build(cfg.topology, cfg.n, cfg.link);
    errors.extend(check_links(&fabric.topo));
    let routing = Routing::build_bfs(&fabric.topo);
    errors.extend(check_routing(&fabric.topo, &routing));
    if cfg.intra_jobs != 1 {
        let domains = crate::sweep::resolve_jobs(cfg.intra_jobs);
        let part =
            Partition::compute_weighted(&fabric.topo, &routing, domains, WeightModel::Traffic);
        errors.extend(check_partition(&fabric.topo, &part));
        errors.extend(check_window_advance(&fabric.topo, &part));
        errors.extend(check_speculation(&fabric.topo, &part));
    }
    CheckReport {
        errors,
        subject: format!("{} scale-{} system", cfg.topology.name(), 2 * cfg.n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::{LinkCfg, NodeKind, TopologyKind};

    fn two_node(cfg_a: LinkCfg) -> Topology {
        let mut t = Topology::new();
        let a = t.add_node("r0", NodeKind::Requester);
        let b = t.add_node("m0", NodeKind::Memory);
        t.add_link(a, b, cfg_a);
        t
    }

    #[test]
    fn default_system_checks_clean() {
        let cfg = SystemCfg::new(TopologyKind::SpineLeaf, 8);
        let r = check_system(&cfg);
        assert!(r.ok(), "{:?}", r.errors);
    }

    #[test]
    fn partitioned_default_system_checks_clean() {
        let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 8);
        cfg.intra_jobs = 4;
        let r = check_system(&cfg);
        assert!(r.ok(), "{:?}", r.errors);
    }

    #[test]
    fn healthy_routing_passes() {
        let t = two_node(LinkCfg::default());
        let r = Routing::build_bfs(&t);
        assert!(check_routing(&t, &r).is_empty());
        assert!(check_links(&t).is_empty());
    }

    #[test]
    fn full_duplex_turnaround_flagged() {
        let t = two_node(LinkCfg { turnaround: 100, ..LinkCfg::default() });
        let errs = check_links(&t);
        assert_eq!(errs.len(), 1);
        assert_eq!(errs[0].rule, "ESF-C004");
        assert_eq!(errs[0].path, "link[0]");
    }

    #[test]
    fn window_advance_clean_on_computed_partitions() {
        use crate::interconnect::build;
        for kind in [TopologyKind::SpineLeaf, TopologyKind::Dragonfly, TopologyKind::Ring] {
            let f = build(kind, 16, LinkCfg::default());
            let routing = Routing::build_bfs(&f.topo);
            for jobs in [2, 4, 8] {
                let p =
                    Partition::compute_weighted(&f.topo, &routing, jobs, WeightModel::Traffic);
                let errs = check_window_advance(&f.topo, &p);
                assert!(errs.is_empty(), "{} jobs={jobs}: {errs:?}", kind.name());
            }
        }
    }

    /// ESF-C013 must catch every way the horizon graph can go unsound:
    /// a dropped cut link (missing edge => widening past real arrivals),
    /// a tampered lookahead (wrong relaxation floor), and a non-crossing
    /// link smuggled into the cut set (self edge).
    #[test]
    fn window_advance_catches_horizon_corruption() {
        use crate::interconnect::build;
        let f = build(TopologyKind::SpineLeaf, 8, LinkCfg::default());
        let routing = Routing::build_bfs(&f.topo);
        let part = Partition::compute_weighted(&f.topo, &routing, 4, WeightModel::Traffic);
        assert!(check_window_advance(&f.topo, &part).is_empty());

        let mut dropped = part.clone();
        dropped.cut_links.clear();
        let errs = check_window_advance(&f.topo, &dropped);
        assert!(
            errs.iter().any(|e| e.rule == "ESF-C013" && e.msg.contains("missing edge")),
            "{errs:?}"
        );

        let mut skewed = part.clone();
        skewed.lookahead += 1;
        let errs = check_window_advance(&f.topo, &skewed);
        assert!(
            errs.iter().any(|e| e.rule == "ESF-C013" && e.path == "partition.lookahead"),
            "{errs:?}"
        );

        let intra = (0..f.topo.links.len())
            .find(|&l| {
                part.domain_of[f.topo.links[l].a] == part.domain_of[f.topo.links[l].b]
            })
            .expect("some link stays inside a domain");
        let mut smuggled = part.clone();
        smuggled.cut_links.push(intra);
        let errs = check_window_advance(&f.topo, &smuggled);
        assert!(
            errs.iter().any(|e| e.rule == "ESF-C013" && e.msg.contains("invalid domain")),
            "{errs:?}"
        );
    }

    #[test]
    fn speculation_clean_on_computed_partitions() {
        use crate::interconnect::build;
        for kind in [TopologyKind::SpineLeaf, TopologyKind::Dragonfly, TopologyKind::Ring] {
            let f = build(kind, 16, LinkCfg::default());
            let routing = Routing::build_bfs(&f.topo);
            for jobs in [2, 4, 8] {
                let p =
                    Partition::compute_weighted(&f.topo, &routing, jobs, WeightModel::Traffic);
                let errs = check_speculation(&f.topo, &p);
                assert!(errs.is_empty(), "{} jobs={jobs}: {errs:?}", kind.name());
            }
        }
    }

    /// ESF-C015 must catch each speculation-safety violation: a
    /// zero-latency crossing link (capture point cannot dominate), an
    /// overstated lookahead (commit frontier ahead of the true GVT), and
    /// the degenerate zero window that follows from a zero lookahead.
    #[test]
    fn speculation_catches_unsafe_partitions() {
        use crate::interconnect::build;
        let mut f = build(TopologyKind::SpineLeaf, 8, LinkCfg::default());
        let routing = Routing::build_bfs(&f.topo);
        let part = Partition::compute_weighted(&f.topo, &routing, 4, WeightModel::Traffic);
        assert!(check_speculation(&f.topo, &part).is_empty());

        let mut overstated = part.clone();
        overstated.lookahead += 1;
        let errs = check_speculation(&f.topo, &overstated);
        assert!(
            errs.iter()
                .any(|e| e.rule == "ESF-C015" && e.msg.contains("true GVT")),
            "{errs:?}"
        );

        let cut = (0..f.topo.links.len())
            .find(|&l| part.domain_of[f.topo.links[l].a] != part.domain_of[f.topo.links[l].b])
            .expect("a multi-domain cut exists");
        f.topo.links[cut].cfg.latency = 0;
        let mut degenerate = part.clone();
        degenerate.lookahead = 0;
        let errs = check_speculation(&f.topo, &degenerate);
        assert!(
            errs.iter()
                .any(|e| e.rule == "ESF-C015" && e.msg.contains("dominates")),
            "{errs:?}"
        );
        assert!(
            errs.iter()
                .any(|e| e.rule == "ESF-C015" && e.path == "partition.speculation_window"),
            "{errs:?}"
        );
    }

    #[test]
    fn snapshot_check_verifies_integrity_and_fork_compatibility() {
        use crate::config::build_system;
        use crate::engine::snapshot::SnapMeta;
        let mut cfg = SystemCfg::new(TopologyKind::Ring, 2);
        cfg.requests_per_endpoint = 40;
        let mut sys = build_system(&cfg);
        sys.engine.run_until_collecting();
        let meta = SnapMeta {
            cfg_fingerprint: cfg.fingerprint(),
            prefix_fingerprint: cfg.prefix_fingerprint(),
            prefix_canon: cfg.prefix_canon(),
            quiescent: true,
        };
        let bytes = sys.engine.snapshot(&meta);
        // Exact resume and prefix fork are both clean on a quiescent file.
        assert!(check_snapshot(&bytes, Some(&cfg)).is_empty());
        let mut fork = cfg.clone();
        fork.read_ratio = 0.5;
        assert!(check_snapshot(&bytes, Some(&fork)).is_empty());
        // A config sharing neither fingerprint is rejected at
        // snapshot.config.
        let mut other = cfg.clone();
        other.seed = 99;
        let errs = check_snapshot(&bytes, Some(&other));
        assert!(
            errs.iter().any(|e| e.rule == "ESF-C014" && e.path == "snapshot.config"),
            "{errs:?}"
        );
        // Corruption surfaces at snapshot.digest before any compat logic.
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 1;
        let errs = check_snapshot(&bad, Some(&cfg));
        assert_eq!(errs[0].path, "snapshot.digest");
        // A mid-run checkpoint resumes its own config but must never fork.
        let mut sys2 = build_system(&cfg);
        sys2.engine.run_until(1_000_000);
        let mut mid_meta = meta.clone();
        mid_meta.quiescent = false;
        let bytes2 = sys2.engine.snapshot(&mid_meta);
        assert!(check_snapshot(&bytes2, Some(&cfg)).is_empty());
        let errs = check_snapshot(&bytes2, Some(&fork));
        assert!(
            errs.iter().any(|e| e.rule == "ESF-C014" && e.path == "snapshot.prefix"),
            "{errs:?}"
        );
    }

    #[test]
    fn txn_capacity_overflow_flagged() {
        let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 2);
        cfg.requests_per_endpoint = 1 << 37;
        let errs = check_config(&cfg);
        assert!(errs.iter().any(|e| e.rule == "ESF-C008"), "{errs:?}");
        cfg.requests_per_endpoint = 1000;
        assert!(check_config(&cfg).is_empty());
    }
}
