//! Framed canonical-JSON wire protocol for `esfd`.
//!
//! Every message — request, response, or stream element — is one frame:
//! a 4-byte big-endian payload length followed by exactly that many
//! bytes of canonical JSON ([`crate::util::json::Json`]'s `Display`:
//! sorted keys, shortest-roundtrip floats). Length-prefixing gives
//! unambiguous message boundaries over a byte stream without any
//! in-band delimiter, and canonical JSON keeps frames byte-stable —
//! the same message always serializes identically, so protocol-level
//! comparisons (tests, cache probes) can be exact.
//!
//! Robustness contract, pinned by the unit tests below:
//!
//!  * clean EOF **between** frames is `Ok(None)` (peer hung up politely);
//!  * EOF **inside** a header or payload is an error (torn frame);
//!  * a length above [`MAX_FRAME`] is rejected before any allocation —
//!    this also catches non-protocol bytes (an HTTP `GET ` or random
//!    garbage decodes to an enormous length) without reading further;
//!  * payloads must be valid UTF-8 and parse as JSON.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};

/// Protocol identifier, echoed in every hello/response so a client can
/// refuse to talk to an incompatible daemon. Bump on breaking changes.
pub const PROTO_VERSION: &str = "esfd/1";

/// Hard per-frame payload cap (64 MiB). Large grids are a few KiB and
/// result rows are tiny; anything near this size is a corrupt length
/// word or a non-protocol peer, not a legitimate message.
pub const MAX_FRAME: usize = 64 << 20;

/// Write one framed message: 4-byte big-endian length + canonical JSON.
pub fn write_frame<W: Write>(w: &mut W, msg: &Json) -> Result<()> {
    let payload = msg.to_string();
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME {
        bail!("frame payload {} bytes exceeds cap {MAX_FRAME}", bytes.len());
    }
    w.write_all(&(bytes.len() as u32).to_be_bytes())
        .and_then(|()| w.write_all(bytes))
        .and_then(|()| w.flush())
        .map_err(|e| anyhow!("writing frame: {e}"))
}

/// Read one framed message. `Ok(None)` means the peer closed the
/// connection cleanly between frames; every torn, oversized, or
/// non-JSON frame is an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Json>> {
    let mut header = [0u8; 4];
    let mut have = 0usize;
    while have < header.len() {
        let n = r
            .read(&mut header[have..])
            .map_err(|e| anyhow!("reading frame header: {e}"))?;
        if n == 0 {
            if have == 0 {
                return Ok(None); // clean EOF between frames
            }
            bail!("connection closed mid-header ({have} of 4 bytes)");
        }
        have += n;
    }
    let len = u32::from_be_bytes(header) as usize;
    if len > MAX_FRAME {
        // Catches corrupt lengths and non-protocol peers (e.g. "GET "
        // decodes to ~1.2 GiB) before allocating or reading anything.
        bail!("frame length {len} exceeds cap {MAX_FRAME}");
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)
        .map_err(|e| anyhow!("short frame payload (wanted {len} bytes): {e}"))?;
    let text = std::str::from_utf8(&payload)
        .map_err(|e| anyhow!("frame payload is not UTF-8: {e}"))?;
    Json::parse(text)
        .map(Some)
        .map_err(|e| anyhow!("frame payload is not JSON: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn frame_bytes(msg: &Json) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, msg).unwrap();
        buf
    }

    /// A reader that hands out its bytes in 1-byte `read` calls —
    /// exercises the header/payload fill loops under maximal
    /// fragmentation, as a real socket may deliver.
    struct Trickle {
        bytes: Vec<u8>,
        pos: usize,
    }

    impl Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.bytes.len() || buf.is_empty() {
                return Ok(0);
            }
            buf[0] = self.bytes[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    /// Every message shape the protocol uses must survive a
    /// write -> read round trip byte-exactly.
    #[test]
    fn roundtrips_every_message_type() {
        let messages = vec![
            // submit request (grid doc embedded verbatim)
            Json::parse(r#"{"op":"submit","grid":{"jobs":2,"sweep":{"scale":[8,16]}}}"#).unwrap(),
            // status request / response
            Json::parse(r#"{"op":"status"}"#).unwrap(),
            Json::parse(
                r#"{"budget":8,"in_use":4,"jobs":[{"cells":36,"done_cells":12,
                    "granted":4,"id":"j0-00d1e2f3a4b5c6d7","phase":"running"}],
                    "ok":true,"type":"status","v":"esfd/1"}"#,
            )
            .unwrap(),
            // attach request + stream elements
            Json::parse(r#"{"op":"attach","job":"j0-00d1e2f3a4b5c6d7"}"#).unwrap(),
            Json::parse(
                r#"{"cached":true,"index":3,"result":{"avg_latency_ns":210.5,
                    "bandwidth_gbps":12.25,"completed":400,"dropped":0,
                    "events":123456,"label":"scale=8","max_latency_ns":999.25,
                    "p50_ns":101.5,"p95_ns":333.125,"p99_ns":420.75},
                    "type":"row"}"#,
            )
            .unwrap(),
            Json::parse(r#"{"cached_cells":36,"cells":36,"ok":true,"type":"done"}"#).unwrap(),
            // errors and control
            Json::parse(
                r#"{"error":"grid rejected","errors":[{"msg":"unknown axis",
                    "path":"$.grid.sweep.warp","rule":"ESF-C010"}],
                    "ok":false,"type":"error"}"#,
            )
            .unwrap(),
            Json::parse(r#"{"op":"ping"}"#).unwrap(),
            Json::parse(r#"{"op":"shutdown"}"#).unwrap(),
        ];
        for msg in &messages {
            let bytes = frame_bytes(msg);
            let mut r = Cursor::new(bytes.clone());
            let back = read_frame(&mut r).unwrap().expect("one frame in");
            assert_eq!(&back, msg);
            assert_eq!(back.to_string(), msg.to_string(), "canonical bytes differ");
            // And under 1-byte fragmentation.
            let mut t = Trickle { bytes, pos: 0 };
            assert_eq!(read_frame(&mut t).unwrap().as_ref(), Some(msg));
        }
        // Several frames back-to-back on one stream, then clean EOF.
        let mut stream = Vec::new();
        for msg in &messages {
            stream.extend_from_slice(&frame_bytes(msg));
        }
        let mut r = Cursor::new(stream);
        for msg in &messages {
            assert_eq!(read_frame(&mut r).unwrap().as_ref(), Some(msg));
        }
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF after last frame");
    }

    #[test]
    fn short_reads_are_torn_not_silent() {
        let full = frame_bytes(&Json::parse(r#"{"op":"ping"}"#).unwrap());
        // EOF inside the header (1..3 bytes) and inside the payload.
        for cut in [1, 2, 3, 5, full.len() - 1] {
            let mut r = Cursor::new(full[..cut].to_vec());
            let err = read_frame(&mut r).expect_err("torn frame must error");
            let text = err.to_string();
            assert!(
                text.contains("mid-header") || text.contains("short frame payload"),
                "cut at {cut}: {text}"
            );
        }
        // Zero bytes is a clean EOF, not an error.
        let mut r = Cursor::new(Vec::new());
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn oversized_frames_are_rejected_before_allocation() {
        let mut bytes = ((MAX_FRAME + 1) as u32).to_be_bytes().to_vec();
        bytes.extend_from_slice(b"{}"); // never read
        let err = read_frame(&mut Cursor::new(bytes)).expect_err("oversized must error");
        assert!(err.to_string().contains("exceeds cap"), "{err}");
        // The writer refuses symmetrically.
        let huge = Json::Str("x".repeat(MAX_FRAME));
        let mut sink = Vec::new();
        assert!(write_frame(&mut sink, &huge).is_err());
    }

    #[test]
    fn garbage_prefixes_are_rejected() {
        // A non-protocol peer: "GET " as a length word is ~1.2 GiB.
        let mut r = Cursor::new(b"GET /jobs HTTP/1.1\r\n".to_vec());
        let err = read_frame(&mut r).expect_err("HTTP must be rejected");
        assert!(err.to_string().contains("exceeds cap"), "{err}");
        // A plausible length followed by non-JSON payload.
        let mut bytes = 7u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(b"not js!");
        let err = read_frame(&mut Cursor::new(bytes)).expect_err("non-JSON must be rejected");
        assert!(err.to_string().contains("not JSON"), "{err}");
        // A plausible length followed by invalid UTF-8.
        let mut bytes = 4u32.to_be_bytes().to_vec();
        bytes.extend_from_slice(&[0xff, 0xfe, 0x80, 0x81]);
        let err = read_frame(&mut Cursor::new(bytes)).expect_err("bad UTF-8 must be rejected");
        assert!(err.to_string().contains("not UTF-8"), "{err}");
    }
}
