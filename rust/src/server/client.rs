//! Client side of the `esfd` protocol.
//!
//! Thin, synchronous wrappers used by the `esf submit` / `esf status` /
//! `esf attach` / `esf shutdown` subcommands (and the daemon integration
//! tests): connect to the daemon's Unix socket, exchange
//! [`super::wire`] frames, and surface daemon-side rejections as errors
//! carrying every rule id and JSON-path locus the server reported.
//!
//! [`attach`] is the byte-identity workhorse: it streams `row` frames as
//! cells finish (completion order) and reassembles them by embedded
//! submission index, so the returned vector is in grid order — feeding
//! it to `sweep::results_table` / `results_json` reproduces the one-shot
//! `esf sweep` output byte-for-byte.

use super::wire::{read_frame, write_frame};
use crate::sweep::ScenarioResult;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::os::unix::net::UnixStream;
use std::path::Path;

/// Connect to a daemon socket, with a hint when nothing is listening.
pub fn connect(socket: &Path) -> Result<UnixStream> {
    UnixStream::connect(socket)
        .map_err(|e| anyhow!("connecting to {} ({e}) — is esfd running?", socket.display()))
}

/// Fail on a daemon rejection, folding the per-rule loci into the error
/// text so `esf submit bad-grid.json` prints actionable diagnostics.
fn expect_ok(resp: &Json) -> Result<()> {
    if resp.bool_or("ok", false) {
        return Ok(());
    }
    let mut text = resp.str_or("error", "daemon rejected the request").to_string();
    if let Some(errs) = resp.get("errors").and_then(Json::as_arr) {
        for e in errs {
            text.push_str(&format!(
                "\n  {} {}: {}",
                e.str_or("rule", "?"),
                e.str_or("path", "?"),
                e.str_or("msg", "?")
            ));
        }
    }
    bail!("{text}")
}

/// One request/response exchange on a fresh connection.
fn roundtrip(socket: &Path, req: &Json) -> Result<Json> {
    let mut stream = connect(socket)?;
    write_frame(&mut stream, req)?;
    match read_frame(&mut stream)? {
        Some(resp) => Ok(resp),
        None => bail!("daemon closed the connection without responding"),
    }
}

/// Submit a grid document; returns the daemon's `submitted` response
/// (`job` id, `cells`) or the full rejection diagnostics.
pub fn submit(socket: &Path, grid: &Json) -> Result<Json> {
    let req = Json::obj(vec![("op", Json::Str("submit".into())), ("grid", grid.clone())]);
    let resp = roundtrip(socket, &req)?;
    expect_ok(&resp)?;
    Ok(resp)
}

/// Fetch the scheduler status, optionally filtered to one job id.
pub fn status(socket: &Path, job: Option<&str>) -> Result<Json> {
    let mut fields = vec![("op", Json::Str("status".into()))];
    if let Some(id) = job {
        fields.push(("job", Json::Str(id.to_string())));
    }
    let resp = roundtrip(socket, &Json::obj(fields))?;
    expect_ok(&resp)?;
    Ok(resp)
}

/// Ask the daemon to drain and exit.
pub fn shutdown(socket: &Path) -> Result<()> {
    let resp = roundtrip(socket, &Json::obj(vec![("op", Json::Str("shutdown".into()))]))?;
    expect_ok(&resp)
}

/// Attach to a job and stream its cells. `on_row` fires once per cell in
/// **completion** order with `(submission index, cache-served, result)`;
/// the returned vector is reassembled into **submission** order — the
/// order one-shot `esf sweep` would have produced.
pub fn attach<F>(socket: &Path, job: &str, mut on_row: F) -> Result<Vec<ScenarioResult>>
where
    F: FnMut(usize, bool, &ScenarioResult),
{
    let mut stream = connect(socket)?;
    let req = Json::obj(vec![
        ("op", Json::Str("attach".into())),
        ("job", Json::Str(job.to_string())),
    ]);
    write_frame(&mut stream, &req)?;
    let hello = match read_frame(&mut stream)? {
        Some(h) => h,
        None => bail!("daemon closed the connection without responding"),
    };
    expect_ok(&hello)?;
    if hello.str_or("type", "") != "attached" {
        bail!("unexpected response type '{}'", hello.str_or("type", ""));
    }
    let cells = hello.u64_or("cells", 0) as usize;
    let mut rows: Vec<Option<ScenarioResult>> = vec![None; cells];
    loop {
        let frame = match read_frame(&mut stream)? {
            Some(f) => f,
            None => bail!("stream ended before the job finished"),
        };
        match frame.str_or("type", "") {
            "row" => {
                let index = frame.u64_or("index", u64::MAX) as usize;
                if index >= cells {
                    bail!("row index {index} out of range (job has {cells} cells)");
                }
                let result = frame
                    .get("result")
                    .ok_or_else(|| anyhow!("row frame missing 'result'"))
                    .and_then(ScenarioResult::from_json)?;
                on_row(index, frame.bool_or("cached", false), &result);
                rows[index] = Some(result);
            }
            "done" => {
                return rows
                    .into_iter()
                    .collect::<Option<Vec<_>>>()
                    .ok_or_else(|| anyhow!("daemon reported done before every row arrived"));
            }
            "error" => {
                expect_ok(&frame)?; // always fails with the daemon's text
                bail!("daemon reported an error frame without detail");
            }
            other => bail!("unexpected stream frame type '{other}'"),
        }
    }
}
