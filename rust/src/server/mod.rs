//! `esfd` — the job-serving sweep daemon.
//!
//! Refactors the one-shot `esf sweep` CLI into a long-running service
//! that owns one machine: clients submit scenario grids over a local
//! Unix socket ([`wire`]: length-prefixed canonical JSON), the daemon
//! queues them, an admission controller partitions the machine-wide
//! thread budget across concurrent jobs, and attached clients stream
//! per-cell results as they complete. Three contracts carry the design:
//!
//!  * **Byte identity** — an attached client's assembled output for a
//!    grid is byte-identical to one-shot `esf sweep` on the same grid.
//!    Cells stream in completion order tagged with their submission
//!    index ([`crate::sweep::CellUpdate`]), so reassembly is exact.
//!  * **Shared budget** — every job's grant comes out of one budget
//!    (`--budget`, default all cores), `--job-width` caps any single
//!    job, and a job's own `jobs` request is clamped to its grant — N
//!    clients can never oversubscribe the machine, including through
//!    [`crate::sweep::split_thread_budget`]'s explicit-`--jobs`
//!    verbatim carve-out (admission owns the budget here, so the
//!    carve-out's deliberate oversubscription does not apply).
//!  * **Cache-served repeats** — all jobs share one
//!    [`crate::sweep::SweepCache`], so resubmitting a grid whose cells
//!    are cached (same content hashes, any client) completes without
//!    re-simulating anything and reports `cached_cells == cells`.
//!
//! Job ids are deterministic: `j<seq>-<grid_hash>` where `seq` is the
//! submit sequence number and `grid_hash` the FNV-1a 64 of the grid's
//! canonical JSON — the same submission order always names jobs the
//! same way, so tests and scripts can predict ids.
//!
//! Every submission is validated server-side (ESF-C016 +
//! the grid rules, [`crate::check::job`]) before it can touch the
//! queue: a malformed job is rejected at the socket with exact
//! JSON-path loci and the daemon keeps serving.
//!
//! This module is host-side I/O by nature (sockets, threads, wall
//! clock) but lives in the lint's deterministic set: everything that
//! could leak nondeterminism into *results* must pass the L-rules
//! clean, and the few legitimate host-side sites carry explicit
//! `det-ok` waivers below.

pub mod client;
pub mod wire;

use crate::check::CheckReport;
use crate::engine::parallel::BarrierMode;
use crate::sweep::{available_jobs, run_scenarios_streaming, Scenario, ScenarioResult, SweepCache};
use crate::util::fnv1a64;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, VecDeque};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Default daemon socket path, shared by `esfd` and the `esf`
/// submit/status/attach/shutdown subcommands.
pub const DEFAULT_SOCKET: &str = "/tmp/esfd.sock";

/// Daemon configuration (`esfd` flags).
#[derive(Clone, Debug)]
pub struct DaemonCfg {
    /// Unix socket path the daemon listens on.
    pub socket: PathBuf,
    /// Shared sweep-cache directory (cells + warm-start snapshots).
    pub cache_dir: PathBuf,
    /// Machine-wide thread budget shared by all jobs (0 = all cores).
    pub budget: usize,
    /// Cap on any single job's grant (0 = the whole budget). Widths
    /// below the budget are what let jobs run concurrently.
    pub job_width: usize,
}

/// Lifecycle of one submitted job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum JobPhase {
    Queued,
    Running,
    Done,
    Failed,
}

impl JobPhase {
    fn name(self) -> &'static str {
        match self {
            JobPhase::Queued => "queued",
            JobPhase::Running => "running",
            JobPhase::Done => "done",
            JobPhase::Failed => "failed",
        }
    }
}

/// Mutable per-job state, updated by the runner and read by status and
/// attach handlers. Lock ordering: the scheduler state lock may be held
/// while taking this lock, never the reverse.
struct Progress {
    phase: JobPhase,
    /// Threads granted by admission (0 while queued).
    granted: usize,
    /// Completion-order log of `(submission index, cache-served)` —
    /// attach streams are cursors into this log.
    done: Vec<(usize, bool)>,
    /// Submission-indexed result slots, filled as cells complete.
    rows: Vec<Option<ScenarioResult>>,
    error: String,
}

/// One submitted job.
struct Job {
    id: String,
    grid_hash: u64,
    cells: usize,
    /// The grid's own `jobs` / `intra_jobs` requests; `jobs` is clamped
    /// to the admission grant at run time.
    jobs_req: usize,
    intra_req: usize,
    /// Scenarios, taken exactly once by the runner.
    scenarios: Mutex<Option<Vec<Scenario>>>,
    progress: Mutex<Progress>,
    /// Signaled on every progress change (cell done, phase change).
    cv: Condvar,
}

/// Scheduler state behind one mutex.
struct Sched {
    next_seq: u64,
    /// Unallocated threads of the machine budget.
    remaining: usize,
    in_use: usize,
    peak_in_use: usize,
    running: usize,
    peak_running: usize,
    queue: VecDeque<Arc<Job>>,
    jobs: BTreeMap<String, Arc<Job>>,
    /// Submission order, for deterministic status listings.
    order: Vec<String>,
    shutdown: bool,
}

struct Daemon {
    cfg: DaemonCfg,
    budget: usize,
    job_width: usize,
    cache: SweepCache,
    state: Mutex<Sched>,
    /// Every spawned thread (connection handlers + job runners); the
    /// accept loop drains this on shutdown. Runners can push while the
    /// drain runs, hence the loop-until-empty join.
    workers: Mutex<Vec<JoinHandle<()>>>,
}

fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

/// Deterministic job id: submit sequence + canonical-grid content hash.
fn job_id(seq: u64, grid_hash: u64) -> String {
    format!("j{seq}-{grid_hash:016x}")
}

fn error_msg(msg: &str) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("type", Json::Str("error".into())),
        ("error", Json::Str(msg.to_string())),
    ])
}

/// Rejection response carrying every check error with its rule id and
/// exact JSON-path locus (the ESF-C016 contract: reject at the socket,
/// never panic a worker).
fn error_from_report(r: &CheckReport) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("type", Json::Str("error".into())),
        ("error", Json::Str(format!("{} rejected: {} error(s)", r.subject, r.errors.len()))),
        (
            "errors",
            Json::Arr(
                r.errors
                    .iter()
                    .map(|e| {
                        Json::obj(vec![
                            ("rule", Json::Str(e.rule.to_string())),
                            ("path", Json::Str(e.path.clone())),
                            ("msg", Json::Str(e.msg.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Bind and serve until a `shutdown` request arrives. Queued and
/// running jobs drain before this returns (shutdown is graceful); the
/// socket file is removed on exit. A stale socket left by a killed
/// daemon is detected (nothing accepts on it) and replaced; a live one
/// is an error — two daemons must not share a machine budget.
pub fn serve(cfg: DaemonCfg) -> Result<()> {
    let budget = if cfg.budget == 0 {
        available_jobs()
    } else {
        cfg.budget
    };
    let job_width = if cfg.job_width == 0 {
        budget
    } else {
        cfg.job_width.min(budget)
    };
    if cfg.socket.exists() {
        match UnixStream::connect(&cfg.socket) {
            Ok(_) => bail!(
                "an esfd is already serving on {} (shut it down first)",
                cfg.socket.display()
            ),
            Err(_) => {
                std::fs::remove_file(&cfg.socket)
                    .map_err(|e| anyhow!("removing stale socket {}: {e}", cfg.socket.display()))?;
            }
        }
    }
    let cache = SweepCache::open(&cfg.cache_dir)?;
    let listener = UnixListener::bind(&cfg.socket)
        .map_err(|e| anyhow!("binding {}: {e}", cfg.socket.display()))?;
    let daemon = Arc::new(Daemon {
        budget,
        job_width,
        cache,
        state: Mutex::new(Sched {
            next_seq: 0,
            remaining: budget,
            in_use: 0,
            peak_in_use: 0,
            running: 0,
            peak_running: 0,
            queue: VecDeque::new(),
            jobs: BTreeMap::new(),
            order: Vec::new(),
            shutdown: false,
        }),
        workers: Mutex::new(Vec::new()),
        cfg,
    });
    eprintln!(
        "esfd: serving on {} (budget {budget} thread(s), job width {job_width}, cache {})",
        daemon.cfg.socket.display(),
        daemon.cfg.cache_dir.display()
    );
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(e) => {
                eprintln!("esfd: accept failed: {e}");
                continue;
            }
        };
        if daemon.state.lock().expect("sched lock").shutdown {
            break;
        }
        let d = Arc::clone(&daemon);
        let h = std::thread::spawn(move || handle_conn(&d, stream));
        daemon.workers.lock().expect("worker list lock").push(h);
    }
    // Drain every handler and runner; runners spawned by late admissions
    // keep appending, so loop until a sweep finds nothing left.
    loop {
        let drained: Vec<JoinHandle<()>> =
            std::mem::take(&mut *daemon.workers.lock().expect("worker list lock"));
        if drained.is_empty() {
            break;
        }
        for h in drained {
            let _ = h.join();
        }
    }
    let _ = std::fs::remove_file(&daemon.cfg.socket);
    eprintln!("esfd: shut down");
    Ok(())
}

/// Per-connection request loop. Every request is validated through the
/// job-spec rules before dispatch; a rejected request answers with an
/// error frame and the connection (and daemon) keep going.
fn handle_conn(d: &Arc<Daemon>, mut stream: UnixStream) {
    loop {
        let msg = match wire::read_frame(&mut stream) {
            Ok(Some(m)) => m,
            Ok(None) => return, // client closed cleanly
            Err(e) => {
                let _ = wire::write_frame(&mut stream, &error_msg(&format!("bad frame: {e}")));
                return;
            }
        };
        let report = crate::check::job::check_job_json(&msg);
        if !report.ok() {
            let _ = wire::write_frame(&mut stream, &error_from_report(&report));
            continue;
        }
        match msg.str_or("op", "") {
            "submit" => {
                let resp = handle_submit(d, &msg);
                let _ = wire::write_frame(&mut stream, &resp);
            }
            "status" => {
                let resp = status_json(d, msg.get("job").and_then(Json::as_str));
                let _ = wire::write_frame(&mut stream, &resp);
            }
            "attach" => {
                let id = msg.str_or("job", "");
                let job = d.state.lock().expect("sched lock").jobs.get(id).cloned();
                match job {
                    None => {
                        let _ = wire::write_frame(
                            &mut stream,
                            &error_msg(&format!("unknown job '{id}'")),
                        );
                    }
                    // A failed stream write means the client vanished;
                    // nothing to do but drop the connection.
                    Some(job) => {
                        if stream_job(&job, &mut stream).is_err() {
                            return;
                        }
                    }
                }
            }
            "ping" => {
                let resp = Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("type", Json::Str("pong".into())),
                    ("v", Json::Str(wire::PROTO_VERSION.into())),
                ]);
                let _ = wire::write_frame(&mut stream, &resp);
            }
            "shutdown" => {
                d.state.lock().expect("sched lock").shutdown = true;
                // Wake the accept loop with a throwaway connection so it
                // observes the flag without waiting for a real client.
                let _ = UnixStream::connect(&d.cfg.socket);
                let resp = Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("type", Json::Str("shutdown".into())),
                ]);
                let _ = wire::write_frame(&mut stream, &resp);
                return;
            }
            other => {
                let resp = error_msg(&format!("unknown op '{other}'"));
                let _ = wire::write_frame(&mut stream, &resp);
            }
        }
    }
}

/// Register a validated submission: expand the grid, mint the
/// deterministic id, queue, and kick admission.
fn handle_submit(d: &Arc<Daemon>, msg: &Json) -> Json {
    let grid = msg.get("grid").expect("validated submit carries a grid");
    let spec = match crate::sweep::GridSpec::from_json(grid) {
        Ok(s) => s,
        Err(e) => return error_msg(&format!("grid expansion failed: {e}")),
    };
    let grid_hash = fnv1a64(grid.to_string().as_bytes());
    let cells = spec.scenarios.len();
    let job = {
        let mut st = d.state.lock().expect("sched lock");
        if st.shutdown {
            return error_msg("daemon is shutting down");
        }
        let id = job_id(st.next_seq, grid_hash);
        st.next_seq += 1;
        let job = Arc::new(Job {
            id: id.clone(),
            grid_hash,
            cells,
            jobs_req: spec.jobs,
            intra_req: spec.intra_jobs,
            scenarios: Mutex::new(Some(spec.scenarios)),
            progress: Mutex::new(Progress {
                phase: JobPhase::Queued,
                granted: 0,
                done: Vec::new(),
                rows: vec![None; cells],
                error: String::new(),
            }),
            cv: Condvar::new(),
        });
        st.queue.push_back(Arc::clone(&job));
        st.jobs.insert(id.clone(), Arc::clone(&job));
        st.order.push(id);
        job
    };
    try_admit(d);
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("type", Json::Str("submitted".into())),
        ("job", Json::Str(job.id.clone())),
        ("cells", num(job.cells)),
        ("v", Json::Str(wire::PROTO_VERSION.into())),
    ])
}

/// Admission control: while budget remains, pop the queue head, grant it
/// `min(remaining, job_width)` threads, and spawn its runner. Called on
/// submit and whenever a runner releases its grant. FIFO by design —
/// deterministic and starvation-free.
fn try_admit(d: &Arc<Daemon>) {
    loop {
        let (job, grant) = {
            let mut st = d.state.lock().expect("sched lock");
            if st.remaining == 0 || st.queue.is_empty() {
                return;
            }
            let job = st.queue.pop_front().expect("non-empty queue");
            let grant = st.remaining.min(d.job_width);
            st.remaining -= grant;
            st.in_use += grant;
            st.peak_in_use = st.peak_in_use.max(st.in_use);
            st.running += 1;
            st.peak_running = st.peak_running.max(st.running);
            {
                let mut p = job.progress.lock().expect("progress lock");
                p.phase = JobPhase::Running;
                p.granted = grant;
            }
            job.cv.notify_all();
            (job, grant)
        };
        let dc = Arc::clone(d);
        let h = std::thread::spawn(move || run_job(&dc, &job, grant));
        d.workers.lock().expect("worker list lock").push(h);
    }
}

/// Run one admitted job on its granted thread slice, streaming each
/// finished cell into the job's progress log. A panicking scenario
/// fails the job (phase + message) instead of killing the daemon; the
/// grant is always released and admission re-kicked.
fn run_job(d: &Arc<Daemon>, job: &Arc<Job>, grant: usize) {
    let scenarios = job
        .scenarios
        .lock()
        .expect("scenario slot lock")
        .take()
        .expect("a job's scenarios are taken exactly once");
    // Admission owns the budget: the grid's explicit `jobs` request is
    // clamped to the grant (0 stays 0 = fill the grant), so the
    // split_thread_budget verbatim carve-out cannot oversubscribe here.
    let jobs = job.jobs_req.min(grant);
    // det-ok: host-side wall-clock for the operator's per-job duration
    // log line only — never feeds simulated time or results.
    #[allow(clippy::disallowed_methods)]
    let t0 = std::time::Instant::now();
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        run_scenarios_streaming(
            scenarios,
            jobs,
            job.intra_req,
            BarrierMode::default(),
            grant,
            Some(&d.cache),
            |u| {
                let mut p = job.progress.lock().expect("progress lock");
                p.rows[u.index] = Some(u.result);
                p.done.push((u.index, u.cached));
                drop(p);
                job.cv.notify_all();
            },
        )
    }));
    let cached = {
        let mut p = job.progress.lock().expect("progress lock");
        match outcome {
            Ok(_) => p.phase = JobPhase::Done,
            Err(panic) => {
                p.phase = JobPhase::Failed;
                p.error = panic_text(panic);
            }
        }
        p.done.iter().filter(|(_, c)| *c).count()
    };
    job.cv.notify_all();
    eprintln!(
        "esfd: job {} finished in {:.2}s ({} cells, {cached} cache-served, {grant} thread(s))",
        job.id,
        t0.elapsed().as_secs_f64(),
        job.cells
    );
    {
        let mut st = d.state.lock().expect("sched lock");
        st.remaining += grant;
        st.in_use -= grant;
        st.running -= 1;
    }
    try_admit(d);
}

fn panic_text(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "scenario worker panicked".to_string()
    }
}

/// Status snapshot: scheduler counters plus every job in submission
/// order (or one job when filtered). Peaks let tests and operators
/// verify the budget was never oversubscribed.
fn status_json(d: &Daemon, filter: Option<&str>) -> Json {
    let st = d.state.lock().expect("sched lock");
    if let Some(id) = filter {
        if !st.jobs.contains_key(id) {
            return error_msg(&format!("unknown job '{id}'"));
        }
    }
    let mut jobs = Vec::new();
    for id in &st.order {
        if filter.is_some_and(|f| f != id.as_str()) {
            continue;
        }
        let job = &st.jobs[id];
        let p = job.progress.lock().expect("progress lock");
        jobs.push(Json::obj(vec![
            ("id", Json::Str(job.id.clone())),
            ("phase", Json::Str(p.phase.name().into())),
            ("cells", num(job.cells)),
            ("done_cells", num(p.done.len())),
            ("cached_cells", num(p.done.iter().filter(|(_, c)| *c).count())),
            ("granted", num(p.granted)),
            ("grid_hash", Json::Str(format!("{:016x}", job.grid_hash))),
            ("error", Json::Str(p.error.clone())),
        ]));
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("type", Json::Str("status".into())),
        ("v", Json::Str(wire::PROTO_VERSION.into())),
        ("budget", num(d.budget)),
        ("job_width", num(d.job_width)),
        ("in_use", num(st.in_use)),
        ("peak_in_use", num(st.peak_in_use)),
        ("running", num(st.running)),
        ("peak_running", num(st.peak_running)),
        ("jobs", Json::Arr(jobs)),
    ])
}

/// Stream a job to an attached client: an `attached` hello, one `row`
/// frame per finished cell (completion order, submission index
/// embedded), then a `done` (or `error`) frame. Blocks on the job's
/// condvar between batches; frames are written outside the lock.
fn stream_job(job: &Arc<Job>, stream: &mut UnixStream) -> Result<()> {
    let hello = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("type", Json::Str("attached".into())),
        ("job", Json::Str(job.id.clone())),
        ("cells", num(job.cells)),
        ("v", Json::Str(wire::PROTO_VERSION.into())),
    ]);
    wire::write_frame(stream, &hello)?;
    let mut sent = 0usize;
    loop {
        let (batch, phase, error, cached_cells) = {
            let mut p = job.progress.lock().expect("progress lock");
            while p.done.len() == sent && matches!(p.phase, JobPhase::Queued | JobPhase::Running) {
                p = job.cv.wait(p).expect("progress cv wait");
            }
            let batch: Vec<(usize, bool, ScenarioResult)> = p.done[sent..]
                .iter()
                .map(|&(idx, cached)| {
                    let row = p.rows[idx].clone().expect("logged cell has its row");
                    (idx, cached, row)
                })
                .collect();
            let cached_cells = p.done.iter().filter(|(_, c)| *c).count();
            (batch, p.phase, p.error.clone(), cached_cells)
        };
        sent += batch.len();
        for (idx, cached, row) in batch {
            let frame = Json::obj(vec![
                ("type", Json::Str("row".into())),
                ("index", num(idx)),
                ("cached", Json::Bool(cached)),
                ("result", row.to_json()),
            ]);
            wire::write_frame(stream, &frame)?;
        }
        match phase {
            JobPhase::Done if sent == job.cells => {
                let done = Json::obj(vec![
                    ("ok", Json::Bool(true)),
                    ("type", Json::Str("done".into())),
                    ("cells", num(job.cells)),
                    ("cached_cells", num(cached_cells)),
                ]);
                return wire::write_frame(stream, &done);
            }
            JobPhase::Failed => {
                return wire::write_frame(stream, &error_msg(&format!("job failed: {error}")));
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_ids_are_deterministic_and_ordered() {
        assert_eq!(job_id(0, 0xdead_beef), "j0-00000000deadbeef");
        assert_eq!(job_id(7, u64::MAX), "j7-ffffffffffffffff");
        // Same grid bytes, different sequence -> distinct ids sharing
        // the content hash.
        let h = fnv1a64(br#"{"sweep":{"scale":[8]}}"#);
        assert_ne!(job_id(0, h), job_id(1, h));
        assert_eq!(job_id(0, h).split('-').nth(1), job_id(1, h).split('-').nth(1));
    }

    #[test]
    fn rejection_response_carries_rule_and_path_loci() {
        let report = crate::check::job::check_job_json(
            &Json::parse(r#"{"op":"submit","grid":{"sweep":{"warp":[1]}}}"#).unwrap(),
        );
        assert!(!report.ok());
        let resp = error_from_report(&report);
        assert!(!resp.bool_or("ok", true));
        let errs = resp.get("errors").and_then(Json::as_arr).unwrap();
        let hit = errs.iter().any(|e| {
            e.str_or("rule", "") == "ESF-C010" && e.str_or("path", "") == "$.grid.sweep.warp"
        });
        assert!(hit, "{resp}");
    }
}
