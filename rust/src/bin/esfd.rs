//! `esfd` — the ESF sweep daemon.
//!
//! Serves sweep jobs over a local Unix socket: clients submit grids with
//! `esf submit`, watch the scheduler with `esf status`, and stream
//! results with `esf attach` (byte-identical to one-shot `esf sweep`).
//! One daemon owns one machine budget; admission control partitions it
//! across concurrent jobs and a shared result cache serves repeated
//! grids without re-simulation. See `esf::server` for the protocol and
//! scheduling contracts.

use esf::server::{serve, DaemonCfg, DEFAULT_SOCKET};
use esf::util::args::Args;
use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "esfd — ESF sweep daemon

USAGE:
    esfd [--socket PATH] [--cache-dir DIR] [--budget N] [--job-width W]

OPTIONS:
    --socket PATH     Unix socket to serve on [default: /tmp/esfd.sock]
    --cache-dir DIR   shared sweep cache directory [default: <socket>.cache]
    --budget N        machine-wide thread budget shared by all jobs
                      (0 = all cores) [default: 0]
    --job-width W     max threads granted to any single job (0 = the whole
                      budget; lower it to run jobs concurrently) [default: 0]

The daemon drains queued and running jobs on `esf shutdown`, then exits
and removes its socket. Submit/status/attach with the matching `esf`
subcommands (see `esf help`).";

fn main() -> ExitCode {
    let args = Args::from_env();
    if args.has("help") || args.command.as_deref() == Some("help") {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    if let Some(cmd) = &args.command {
        eprintln!("esfd: unexpected argument '{cmd}'\n\n{USAGE}");
        return ExitCode::FAILURE;
    }
    let socket = PathBuf::from(args.str_or("socket", DEFAULT_SOCKET));
    let cache_dir = match args.get("cache-dir") {
        Some(d) => PathBuf::from(d),
        None => {
            let mut os = socket.as_os_str().to_os_string();
            os.push(".cache");
            PathBuf::from(os)
        }
    };
    let cfg = DaemonCfg {
        socket,
        cache_dir,
        budget: args.u64_or("budget", 0) as usize,
        job_width: args.u64_or("job-width", 0) as usize,
    };
    match serve(cfg) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("esfd: {e}");
            ExitCode::FAILURE
        }
    }
}
