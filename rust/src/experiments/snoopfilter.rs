//! Snoop-filter victim-policy experiment (paper §V-B, Fig 14).
//!
//! One requester issues coherent requests in a skewed pattern (90% of
//! accesses to hot data that is 10% of the footprint); its local cache is
//! 20% of the footprint (holds all hot data), and each endpoint's
//! inclusive SF is sized to match the cache. Requests reaching the SF are
//! therefore mostly cold misses — the paper's key observation — which
//! inverts the usual recency heuristics: LIFO/MRU beat FIFO/LRU.

use crate::config::{BackendKind, SystemCfg};
use crate::devices::{Pattern, VictimPolicy};
use crate::engine::time::ns;
use crate::interconnect::{Duplex, LinkCfg, TopologyKind};
use crate::metrics::{aggregate, memdev_sum};
use crate::util::table::{f, Table};

#[derive(Clone, Debug)]
pub struct SfResult {
    pub policy: VictimPolicy,
    pub bandwidth_gbps: f64,
    pub avg_latency_ns: f64,
    pub invalidations: u64,
}

pub fn run_policy(policy: VictimPolicy, quick: bool) -> SfResult {
    let footprint: u64 = 20_000;
    let cache_lines = (footprint / 5) as usize; // 20% of footprint
    let sf_per_endpoint = cache_lines / 4; // 4 endpoints, line-interleaved
    let mut cfg = SystemCfg::new(TopologyKind::FullyConnected, 1);
    cfg.pattern = Pattern::Skewed {
        hot_frac: 0.1,
        hot_prob: 0.9,
    };
    cfg.footprint_lines = footprint;
    cfg.cache_lines = cache_lines;
    cfg.read_ratio = 0.7;
    cfg.queue_capacity = 16;
    cfg.issue_interval = ns(6.0);
    cfg.requests_per_endpoint = if quick { 4000 } else { 16000 };
    cfg.warmup_fraction = 1.0; // long warm-up to reach SF steady state
    cfg.snoop_filter = Some((sf_per_endpoint, policy));
    // Bus with "infinite bandwidth to eliminate unexpected performance
    // impact" (paper) — isolate the coherence effects.
    cfg.link = LinkCfg {
        bandwidth_gbps: 0.0,
        latency: ns(1.0),
        duplex: Duplex::Full,
        turnaround: 0,
        header_bytes: 0,
    };
    cfg.backend = BackendKind::Fixed(45.0);
    // The paper's Fig 14 system uses one requester and 4 endpoints; our
    // FullyConnected n=1 gives 1 requester + 1 memory, so build a custom
    // fan-out instead.
    let mut sys = build_fanout(&cfg, 4, policy, sf_per_endpoint);
    sys.engine.run(u64::MAX);
    let a = aggregate(&sys);
    let inval = memdev_sum(&sys, |m| m.stats.bisnp_sent);
    SfResult {
        policy,
        bandwidth_gbps: a.bandwidth_gbps(),
        avg_latency_ns: a.avg_latency_ns(),
        invalidations: inval,
    }
}

/// requester -- direct links -- `n_mem` SF-equipped endpoints.
pub fn build_fanout(
    cfg: &SystemCfg,
    n_mem: usize,
    policy: VictimPolicy,
    sf_cap: usize,
) -> crate::config::System {
    use crate::config::build_on_fabric;
    use crate::interconnect::{Fabric, NodeKind, Routing, Topology};
    let mut topo = Topology::new();
    let r = topo.add_node("host", NodeKind::Requester);
    let mut memories = Vec::new();
    for i in 0..n_mem {
        let m = topo.add_node(format!("m{i}"), NodeKind::Memory);
        topo.add_link(r, m, cfg.link);
        memories.push(m);
    }
    let routing = Routing::build_bfs(&topo);
    let fabric = Fabric {
        topo,
        requesters: vec![r],
        memories,
        switches: vec![],
    };
    let mut cfg = cfg.clone();
    cfg.snoop_filter = Some((sf_cap, policy));
    build_on_fabric(&cfg, fabric, routing, &mut |_i, rc| rc)
}

/// Fig 14: bandwidth / latency / invalidation count per victim policy,
/// normalized to FIFO. One sweep cell per policy; the FIFO cell
/// (`BASIC[0]`) doubles as the normalization base.
pub fn fig14(quick: bool, jobs: usize) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 14 — snoop filter victim policies (normalized to FIFO)",
        &["policy", "bandwidth", "avg latency", "invalidations"],
    );
    let results = crate::sweep::map_sweep(VictimPolicy::BASIC.to_vec(), jobs, |policy| {
        run_policy(policy, quick)
    });
    let base = results[0].clone();
    for r in &results {
        t.row(&[
            r.policy.name().into(),
            f(r.bandwidth_gbps / base.bandwidth_gbps),
            f(r.avg_latency_ns / base.avg_latency_ns),
            f(r.invalidations as f64 / base.invalidations.max(1) as f64),
        ]);
    }
    t.note("paper: LIFO +5% bw, -15% latency, -16% invalidations vs FIFO; LFI cuts invalidations ~15% but trails LIFO/MRU");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifo_beats_fifo_on_skewed_pattern() {
        let fifo = run_policy(VictimPolicy::Fifo, true);
        let lifo = run_policy(VictimPolicy::Lifo, true);
        assert!(
            lifo.invalidations < fifo.invalidations,
            "LIFO invalidations {} should be below FIFO {}",
            lifo.invalidations,
            fifo.invalidations
        );
        assert!(
            lifo.avg_latency_ns <= fifo.avg_latency_ns * 1.02,
            "LIFO latency {} should not exceed FIFO {}",
            lifo.avg_latency_ns,
            fifo.avg_latency_ns
        );
    }

    #[test]
    fn fifo_and_lru_behave_similarly() {
        // Little reuse reaches the SF, so FIFO ~ LRU (paper).
        let fifo = run_policy(VictimPolicy::Fifo, true);
        let lru = run_policy(VictimPolicy::Lru, true);
        let rel = (fifo.invalidations as f64 - lru.invalidations as f64).abs()
            / fifo.invalidations.max(1) as f64;
        assert!(rel < 0.15, "FIFO vs LRU invalidation gap {rel:.2}");
    }
}
