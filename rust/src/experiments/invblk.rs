//! InvBlk experiment (paper §V-C, Fig 15): block back-invalidation of
//! contiguous cachelines. Two requesters issue sequential requests; the
//! memory device's SF uses the block-length-prioritized victim policy with
//! the maximum run length limited to 1..4.

use crate::config::{BackendKind, SystemCfg};
use crate::devices::{Pattern, VictimPolicy};
use crate::engine::time::ns;
use crate::interconnect::{Duplex, LinkCfg, TopologyKind};
use crate::metrics::{aggregate, memdev_sum};
use crate::util::table::{f, Table};

#[derive(Clone, Debug)]
pub struct InvBlkResult {
    pub len: u8,
    pub bandwidth_gbps: f64,
    pub avg_latency_ns: f64,
    pub avg_inv_wait_ns: f64,
    pub bisnp_sent: u64,
}

pub fn run_len(max_len: u8, quick: bool) -> InvBlkResult {
    use crate::config::build_on_fabric;
    use crate::interconnect::{Fabric, NodeKind, Routing, Topology};
    let footprint: u64 = 20_000;
    let cache_lines = (footprint / 5) as usize;
    let sf_cap = cache_lines; // one endpoint: SF sized to the cache
    let mut cfg = SystemCfg::new(TopologyKind::Chain, 1); // placeholder kind
    cfg.pattern = Pattern::Stream;
    cfg.read_ratio = 0.7;
    cfg.footprint_lines = footprint;
    cfg.cache_lines = cache_lines;
    cfg.queue_capacity = 16;
    cfg.issue_interval = ns(6.0);
    cfg.requests_per_endpoint = if quick { 6000 } else { 16000 };
    cfg.warmup_fraction = 1.0;
    cfg.backend = BackendKind::Fixed(45.0);
    cfg.link = LinkCfg {
        bandwidth_gbps: 64.0,
        latency: ns(1.0),
        duplex: Duplex::Full,
        turnaround: 0,
        header_bytes: 16,
    };
    cfg.snoop_filter = Some((sf_cap, VictimPolicy::BlockLen { max_len }));

    // Two requesters -- one bus each -- one SF-equipped memory device.
    let mut topo = Topology::new();
    let r0 = topo.add_node("r0", NodeKind::Requester);
    let r1 = topo.add_node("r1", NodeKind::Requester);
    let m = topo.add_node("mem", NodeKind::Memory);
    topo.add_link(r0, m, cfg.link);
    topo.add_link(r1, m, cfg.link);
    let routing = Routing::build_bfs(&topo);
    let fabric = Fabric {
        topo,
        requesters: vec![r0, r1],
        memories: vec![m],
        switches: vec![],
    };
    let mut sys = build_on_fabric(&cfg, fabric, routing, &mut |idx, mut rc| {
        // offset the second requester's stream so the SF sees two fronts
        if idx == 1 {
            rc.seed ^= 0x9e37;
        }
        rc
    });
    sys.engine.run(u64::MAX);
    let a = aggregate(&sys);
    let waits = memdev_sum(&sys, |m| m.stats.inv_waits);
    let wait_sum = memdev_sum(&sys, |m| m.stats.inv_wait_sum as u64);
    InvBlkResult {
        len: max_len,
        bandwidth_gbps: a.bandwidth_gbps(),
        avg_latency_ns: a.avg_latency_ns(),
        avg_inv_wait_ns: if waits == 0 {
            0.0
        } else {
            wait_sum as f64 / waits as f64 / 1000.0
        },
        bisnp_sent: memdev_sum(&sys, |m| m.stats.bisnp_sent),
    }
}

/// Fig 15: bandwidth / latency / invalidation-wait vs InvBlk length,
/// normalized to length = 1. One sweep cell per length; the len=1 cell
/// doubles as the normalization base.
pub fn fig15(quick: bool, jobs: usize) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 15 — InvBlk length (normalized to len=1)",
        &["len", "bandwidth", "avg latency", "inv wait", "BISnp msgs"],
    );
    let results = crate::sweep::map_sweep((1..=4u8).collect(), jobs, |len| run_len(len, quick));
    let base = results[0].clone();
    for r in &results {
        t.row(&[
            r.len.to_string(),
            f(r.bandwidth_gbps / base.bandwidth_gbps),
            f(r.avg_latency_ns / base.avg_latency_ns),
            f(r.avg_inv_wait_ns / base.avg_inv_wait_ns.max(1e-9)),
            r.bisnp_sent.to_string(),
        ]);
    }
    t.note("paper: len=2 cuts waiting and lifts bandwidth; len>2 shows no further gain (cache access + payload competition)");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invblk_reduces_bisnp_messages() {
        let l1 = run_len(1, true);
        let l2 = run_len(2, true);
        assert!(
            l2.bisnp_sent * 3 < l1.bisnp_sent * 2,
            "len=2 should send ~half the BISnp: {} vs {}",
            l2.bisnp_sent,
            l1.bisnp_sent
        );
    }

    #[test]
    fn invblk_len2_reduces_wait() {
        let l1 = run_len(1, true);
        let l2 = run_len(2, true);
        assert!(
            l2.avg_inv_wait_ns < l1.avg_inv_wait_ns,
            "len=2 wait {} should be below len=1 {}",
            l2.avg_inv_wait_ns,
            l1.avg_inv_wait_ns
        );
    }
}
