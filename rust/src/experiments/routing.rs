//! Routing strategy experiment (paper §V-A, Fig 13): Oblivious vs
//! Adaptive next-hop selection in a spine-leaf fabric, observing one
//! fixed-rate host under eight noisy neighbors.

use crate::config::{build_system_with, BackendKind, RoutingSource, SystemCfg};
use crate::devices::{Pattern, Requester};
use crate::engine::time::ns;
use crate::interconnect::{Duplex, LinkCfg, Strategy, TopologyKind};
use crate::util::table::{f, Table};

pub const PORT_GBPS: f64 = 32.0;

/// Run the noisy-neighbor system; returns the observed host's bandwidth
/// normalized to port bandwidth.
///
/// Setup (paper §V-A): spine-leaf fabric, eight memory endpoints, eight
/// noisy neighbors that intensively access the memories, and one observed
/// host at a fixed rate. Each noisy neighbor hammers *its own* endpoint
/// (hotspot flows), so the two spine planes carry uneven static loads —
/// an oblivious host flow hashed onto a hot plane starves, while adaptive
/// forwarding drains onto whichever plane currently has slack.
pub fn observed_host_bandwidth(strategy: Strategy, quick: bool) -> f64 {
    use crate::devices::Interleave;
    // 9 requester/memory pairs: requesters 0..8 are the noisy neighbors,
    // requester 8 is the observed host (fixed moderate rate).
    let mut cfg = SystemCfg::new(TopologyKind::SpineLeaf, 9);
    cfg.link = LinkCfg {
        bandwidth_gbps: PORT_GBPS,
        latency: ns(1.0),
        duplex: Duplex::Full,
        turnaround: 0,
        header_bytes: 0,
    };
    cfg.strategy = strategy;
    cfg.pattern = Pattern::Random;
    cfg.read_ratio = 1.0;
    cfg.backend = BackendKind::Fixed(20.0);
    cfg.requests_per_endpoint = if quick { 400 } else { 2000 };
    cfg.warmup_fraction = 0.25;
    let mut sys = build_system_with(&cfg, RoutingSource::Native, |idx, mut rc| {
        if idx == 8 {
            // The observed host: fixed moderate rate across 8 endpoints,
            // finite request queue (MSHR-like) — when its flows are
            // pinned behind a saturated route, throughput collapses to
            // queue_capacity / sojourn-time.
            rc.issue_interval = ns(4.0);
            rc.queue_capacity = 96;
            // the 8 endpoints NOT owned by the elephant: the host shares
            // only fabric links (spine planes) with it
            rc.endpoints.remove(0);
            rc.interleave = Interleave::Line;
            rc.total_requests *= 2;
            rc.window_every = 64; // completion timeline for the bw window
        } else if idx == 0 {
            // "Elephant" neighbor: offers ~36 GB/s at one endpoint — more
            // than one uplink's capacity. Oblivious pins the whole flow
            // onto one spine plane (unbounded queue growth there);
            // adaptive spreads it across both planes, where it fits.
            rc.issue_interval = ns(1.78);
            rc.queue_capacity = 256;
            rc.interleave = Interleave::Fixed(0);
            let warmup = rc.warmup_requests;
            rc.total_requests *= 16;
            rc.warmup_requests = warmup;
        } else {
            // light noise on the remaining endpoints
            rc.issue_interval = ns(12.0);
            rc.queue_capacity = 32;
            rc.interleave = Interleave::Fixed(idx);
            let warmup = rc.warmup_requests;
            rc.total_requests *= 4;
            rc.warmup_requests = warmup;
        }
        rc
    });
    sys.engine.run(u64::MAX);
    let host = sys.requesters[8];
    let rq: &Requester = sys.engine.component(host).unwrap();
    // The noise outlives the host by design; measure the host over ITS
    // active window (epoch start .. its last completion), not the whole
    // simulation span.
    let start = sys.engine.shared.net.epoch_start;
    let end = rq.stats.window_marks.last().copied().unwrap_or(start + 1);
    let span_ns = crate::engine::time::to_ns(end.saturating_sub(start).max(1));
    (rq.stats.bytes as f64 / span_ns) / PORT_GBPS
}

/// Fig 13: observed-host bandwidth, Oblivious vs Adaptive. One sweep
/// cell per strategy.
pub fn fig13(quick: bool, jobs: usize) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 13 — observed host bandwidth under noisy neighbors (x port bw)",
        &["routing strategy", "host bandwidth"],
    );
    let vals = crate::sweep::map_sweep(
        vec![Strategy::Oblivious, Strategy::Adaptive],
        jobs,
        |strategy| observed_host_bandwidth(strategy, quick),
    );
    let (ob, ad) = (vals[0], vals[1]);
    t.row(&["Oblivious".into(), f(ob)]);
    t.row(&["Adaptive".into(), f(ad)]);
    t.note(format!(
        "adaptive/oblivious = {:.2}x (paper: adaptive drastically improves the host)",
        ad / ob.max(1e-9)
    ));
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_beats_oblivious_under_noise() {
        let ob = observed_host_bandwidth(Strategy::Oblivious, true);
        let ad = observed_host_bandwidth(Strategy::Adaptive, true);
        assert!(
            ad > ob * 1.1,
            "adaptive {ad:.3} should beat oblivious {ob:.3} by >10%"
        );
    }
}
