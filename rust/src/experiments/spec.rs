//! SPEC CPU2017 experiments (paper §IV, Table IV + Table V).
//!
//! Table IV: execution-time overhead incurred by CXL memory (vs local
//! DRAM) for gcc and mcf, as seen by: the hardware (here: the HwReference
//! analytic model), ESF standalone (trace -> cache hierarchy -> ESF),
//! gem5-ESF (nested-engine wrapper with MSHR-style overlap), NUMA
//! emulation, and a gem5-garnet-like on-chip-network integration.
//!
//! Table V: host-side simulation-time overhead each integration adds to
//! the vanilla CPU simulation.

use super::validation::HwReference;
use crate::config::BackendKind;
use crate::cpu::wrapper::{CxlMemWrapper, GarnetLikeWrapper, NumaEmulator};
use crate::cpu::{Hierarchy, TraceCore};
use crate::dram::DramCfg;
use crate::engine::time::ns;
use crate::interconnect::LinkCfg;
use crate::util::table::Table;
use crate::workloads::spec::SpecWorkload;

fn trace_len(quick: bool) -> usize {
    if quick {
        200_000
    } else {
        1_000_000
    }
}

fn hierarchy() -> Hierarchy {
    Hierarchy::xeon_6416h()
}

/// Local-DRAM memory model shared by all platforms' baselines.
fn local_model() -> impl FnMut(u64, bool, u64) -> u64 {
    let mut dram = crate::dram::DramBackend::new(DramCfg::ddr5_4800());
    let path = ns(60.0); // on-socket path to the controller and back
    move |addr, is_write, at| {
        use crate::devices::memdev::MemBackend;
        let done = dram.access(addr, is_write, at + path / 2);
        (done - at) + path / 2
    }
}

/// Execution-time overhead (T_cxl - T_local) / T_local for one platform.
pub struct PlatformResult {
    pub overhead: f64,
    pub wall_cxl_ns: f64,
    pub wall_local_ns: f64,
}

/// Generate a doubled trace; the first half warms the cache hierarchy
/// (compulsory misses excluded from the measurement, mirroring the
/// paper's warm-up protocol) and the second half is measured.
fn halves(w: SpecWorkload, quick: bool) -> (Vec<crate::cpu::CpuOp>, Vec<crate::cpu::CpuOp>) {
    let mut ops = w.generate(2 * trace_len(quick), 17);
    let tail = ops.split_off(trace_len(quick));
    (ops, tail)
}

fn run_platform(
    w: SpecWorkload,
    quick: bool,
    mlp: f64,
    mut cxl_model: impl FnMut(u64, bool, u64) -> u64,
) -> PlatformResult {
    let (warm, measure) = halves(w, quick);
    let mut core = TraceCore::new(hierarchy());
    core.mlp = mlp;
    let mut local_mem = local_model();
    core.run(&warm, &mut local_mem);
    let local = core.run(&measure, &mut local_mem);
    let mut core2 = TraceCore::new(hierarchy());
    core2.mlp = mlp;
    core2.run(&warm, &mut cxl_model);
    let cxl = core2.run(&measure, &mut cxl_model);
    PlatformResult {
        overhead: (cxl.cycles as f64 - local.cycles as f64) / local.cycles as f64,
        wall_cxl_ns: cxl.wall_ns,
        wall_local_ns: local.wall_ns,
    }
}

/// The "hardware" ground truth: analytic CXL latency with load-dependent
/// queueing (HwReference), run through the same core model.
fn hw_overhead(w: SpecWorkload, quick: bool) -> f64 {
    let hw = HwReference::cxl();
    // Estimate miss intensity first (local run), then use the loaded
    // latency at that offered load.
    let (warm, measure) = halves(w, quick);
    let mut probe = TraceCore::new(hierarchy());
    let mut local_mem = local_model();
    probe.run(&warm, &mut local_mem);
    let local = probe.run(&measure, &mut local_mem);
    let sim_ns = local.cycles as f64 / probe.freq_ghz;
    let offered_gbps = local.llc_misses as f64 * 64.0 / sim_ns.max(1.0);
    let lat = hw.loaded_latency_ns(offered_gbps, 0.85);
    let r = run_platform(w, quick, 1.0, move |_a, _w, _t| ns(lat));
    r.overhead
}

/// Table IV: simulated execution-time overhead incurred by CXL memory.
/// The (platform x workload) grid is one sweep; every cell constructs
/// its own wrapper/core state, so cells stay share-nothing.
pub fn tab4(quick: bool, jobs: usize) -> Vec<Table> {
    let mut t = Table::new(
        "Table IV — CXL execution-time overhead (err vs hardware reference)",
        &["platform", "gcc", "mcf"],
    );
    // Platforms in row order: 0 hw-ref, 1 ESF standalone (serialized
    // misses through the full DES wrapper), 2 gem5-ESF (same nested
    // engine with gem5's MSHR overlap), 3 NUMA emulation (flat remote
    // latency + UPI bandwidth cap), 4 gem5-garnet-like (flit-level NoC,
    // flat memory).
    let grid: Vec<(usize, SpecWorkload)> = (0..5usize)
        .flat_map(|p| SpecWorkload::ALL.iter().map(move |&w| (p, w)))
        .collect();
    let cells = crate::sweep::map_sweep(grid, jobs, |(p, w)| {
        let link = LinkCfg::default();
        let backend = BackendKind::Dram(DramCfg::ddr5_4800());
        match p {
            0 => hw_overhead(w, quick),
            1 => {
                let mut wr = CxlMemWrapper::new(&backend, link, 3);
                run_platform(w, quick, 1.0, move |a, iw, t| wr.access(a, iw, t)).overhead
            }
            2 => {
                let mut wr = CxlMemWrapper::new(&backend, link, 3);
                run_platform(w, quick, 1.4, move |a, iw, t| wr.access(a, iw, t)).overhead
            }
            3 => {
                let mut n = NumaEmulator::new(ns(140.0), 20.0);
                run_platform(w, quick, 1.0, move |a, iw, t| n.access(a, iw, t)).overhead
            }
            _ => {
                let mut g = GarnetLikeWrapper::new();
                run_platform(w, quick, 1.4, move |a, iw, t| g.access(a, iw, t)).overhead
            }
        }
    });
    let nw = SpecWorkload::ALL.len();
    let hw = &cells[0..nw];
    let esf = &cells[nw..2 * nw];
    let gem5_esf = &cells[2 * nw..3 * nw];
    let numa = &cells[3 * nw..4 * nw];
    let garnet = &cells[4 * nw..5 * nw];

    let pctf = |v: f64| format!("{:.1}%", v * 100.0);
    let errf = |v: f64, h: f64| format!("{} ({:+.1}%)", pctf(v), (v - h) * 100.0);
    t.row(&[
        "CXL hardware (ref model)".into(),
        format!("{} (0%)", pctf(hw[0])),
        format!("{} (0%)", pctf(hw[1])),
    ]);
    t.row(&["ESF standalone".into(), errf(esf[0], hw[0]), errf(esf[1], hw[1])]);
    t.row(&["gem5-ESF".into(), errf(gem5_esf[0], hw[0]), errf(gem5_esf[1], hw[1])]);
    t.row(&["NUMA emulation".into(), errf(numa[0], hw[0]), errf(numa[1], hw[1])]);
    t.row(&["gem5-garnet (like)".into(), errf(garnet[0], hw[0]), errf(garnet[1], hw[1])]);
    t.note("paper: hw gcc 18.0% / mcf 24.2%; ESF errors within ~6%, NUMA/garnet up to ~9%");
    vec![t]
}

/// Table V: simulation-time overhead each integration adds to the vanilla
/// CPU simulation (host wallclock). Deliberately NOT sharded over worker
/// threads: co-running cells would contend for cores and corrupt the
/// wall-clock measurement this table exists to report.
pub fn tab5(quick: bool, _jobs: usize) -> Vec<Table> {
    let mut t = Table::new(
        "Table V — simulation time overhead vs vanilla CPU sim",
        &["workload", "gem5-ESF", "gem5-garnet (like)"],
    );
    let link = LinkCfg::default();
    let backend = BackendKind::Dram(DramCfg::ddr5_4800());
    for w in SpecWorkload::ALL {
        let ops = w.generate(trace_len(quick), 17);
        // vanilla: flat memory function, no integration machinery.
        let mut core = TraceCore::new(hierarchy());
        let vanilla = core.run(&ops, |_a, _w, _t| ns(95.0));
        let _ = &vanilla;
        // best of 1 run each is noisy; take min of 3 for stability
        let mut esf_wall = f64::MAX;
        let mut gar_wall = f64::MAX;
        let mut van_wall = vanilla.wall_ns;
        for _ in 0..3 {
            let mut core_v = TraceCore::new(hierarchy());
            van_wall = van_wall.min(core_v.run(&ops, |_a, _w, _t| ns(95.0)).wall_ns);
            let mut wr = CxlMemWrapper::new(&backend, link, 3);
            let mut core_e = TraceCore::new(hierarchy());
            esf_wall = esf_wall.min(core_e.run(&ops, |a, iw, t| wr.access(a, iw, t)).wall_ns);
            let mut g = GarnetLikeWrapper::new();
            let mut core_g = TraceCore::new(hierarchy());
            gar_wall = gar_wall.min(core_g.run(&ops, |a, iw, t| g.access(a, iw, t)).wall_ns);
        }
        let ovh = |x: f64| format!("{:.1}%", (x - van_wall) / van_wall * 100.0);
        t.row(&[w.name().into(), ovh(esf_wall), ovh(gar_wall)]);
    }
    t.note("paper: gem5-ESF ~2% average, gem5-garnet ~22.5%");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mcf_overhead_exceeds_gcc() {
        // mcf is memory-bound: CXL must hurt it more.
        let g = hw_overhead(SpecWorkload::Gcc, true);
        let m = hw_overhead(SpecWorkload::Mcf, true);
        assert!(m > g, "mcf {m:.3} should exceed gcc {g:.3}");
        assert!(g > 0.02 && g < 0.6, "gcc overhead {g:.3} out of band");
        assert!(m > 0.05 && m < 1.0, "mcf overhead {m:.3} out of band");
    }

    #[test]
    fn esf_standalone_tracks_hardware_reference() {
        let link = LinkCfg::default();
        let backend = BackendKind::Dram(DramCfg::ddr5_4800());
        for w in SpecWorkload::ALL {
            let hw = hw_overhead(w, true);
            let mut wr = CxlMemWrapper::new(&backend, link, 3);
            let esf = run_platform(w, true, 1.0, move |a, iw, t| wr.access(a, iw, t)).overhead;
            assert!(
                (esf - hw).abs() < 0.15,
                "{}: ESF {esf:.3} vs hw {hw:.3}",
                w.name()
            );
        }
    }

    #[test]
    fn garnet_like_less_accurate_than_esf() {
        let link = LinkCfg::default();
        let backend = BackendKind::Dram(DramCfg::ddr5_4800());
        let w = SpecWorkload::Mcf;
        let hw = hw_overhead(w, true);
        let mut wr = CxlMemWrapper::new(&backend, link, 3);
        let esf = run_platform(w, true, 1.0, move |a, iw, t| wr.access(a, iw, t)).overhead;
        let mut g = GarnetLikeWrapper::new();
        let gar = run_platform(w, true, 1.4, move |a, iw, t| g.access(a, iw, t)).overhead;
        assert!(
            (gar - hw).abs() > (esf - hw).abs(),
            "garnet err {:.3} should exceed ESF err {:.3}",
            (gar - hw).abs(),
            (esf - hw).abs()
        );
    }
}
