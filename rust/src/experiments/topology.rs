//! Topology experiments (paper §V-A, Fig 10/11/12).
//!
//! N requesters and N memory devices connected through PBR switches in
//! five topologies; requesters issue random reads to all endpoints at
//! saturating intensity. Bandwidth is normalized to the (constant) switch
//! port bandwidth.

use crate::config::{build_system, BackendKind, SystemCfg};
use crate::devices::Pattern;
use crate::engine::time::ns;
use crate::interconnect::{Duplex, LinkCfg, TopologyKind};
use crate::metrics::{aggregate, hop_breakdown};
use crate::sweep::map_sweep;
use crate::util::table::{f, Table};

pub const PORT_GBPS: f64 = 32.0;

fn topo_link() -> LinkCfg {
    LinkCfg {
        bandwidth_gbps: PORT_GBPS,
        latency: ns(1.0),
        duplex: Duplex::Full,
        turnaround: 0,
        // Headers off so "normalized to port bandwidth" is exact (the
        // paper's normalization; Fig 16 studies headers separately).
        header_bytes: 0,
    }
}

pub fn topo_cfg(kind: TopologyKind, n: usize, quick: bool) -> SystemCfg {
    let mut cfg = SystemCfg::new(kind, n);
    cfg.link = topo_link();
    cfg.pattern = Pattern::Random;
    cfg.read_ratio = 1.0;
    // Saturating: issue as fast as the queue allows.
    cfg.issue_interval = ns(1.0);
    cfg.queue_capacity = 128;
    cfg.requests_per_endpoint = if quick { 400 } else { 4000 };
    cfg.warmup_fraction = 0.25;
    // Fast media so the fabric, not the endpoint, is the bottleneck.
    cfg.backend = BackendKind::Fixed(20.0);
    cfg.footprint_lines = 1 << 16;
    cfg
}

/// Run one (topology, scale) cell; returns bandwidth normalized to port.
pub fn run_cell(kind: TopologyKind, n: usize, quick: bool) -> f64 {
    let cfg = topo_cfg(kind, n, quick);
    let mut sys = build_system(&cfg);
    sys.engine.run(u64::MAX);
    aggregate(&sys).bandwidth_gbps() / PORT_GBPS
}

/// Fig 10: normalized system bandwidth across topologies and scales.
/// The (topology x scale) grid is data handed to the sweep driver.
pub fn fig10(quick: bool, jobs: usize) -> Vec<Table> {
    let scales: &[usize] = if quick { &[2, 4, 8] } else { &[2, 4, 8, 16] };
    let grid: Vec<(TopologyKind, usize)> = TopologyKind::ALL
        .iter()
        .flat_map(|&kind| scales.iter().map(move |&n| (kind, n)))
        .collect();
    let vals = map_sweep(grid, jobs, |(kind, n)| run_cell(kind, n, quick));
    let mut t = Table::new(
        "Fig 10 — system bandwidth (x port bandwidth) by topology and scale",
        &{
            let mut h = vec!["topology"];
            h.extend(scales.iter().map(|n| match n {
                2 => "scale 4",
                4 => "scale 8",
                8 => "scale 16",
                16 => "scale 32",
                _ => "scale ?",
            }));
            h
        },
    );
    for (ki, kind) in TopologyKind::ALL.iter().enumerate() {
        let mut row = vec![kind.name().to_string()];
        for si in 0..scales.len() {
            row.push(f(vals[ki * scales.len() + si]));
        }
        t.row(&row);
    }
    t.note("paper: chain/tree ~1x, ring ~2x, spine-leaf ~N/2 x, fully-connected ~N x");
    vec![t]
}

/// Fig 11: average latency by hop count (scale 16), with the
/// queue/switch/bus decomposition. One sweep cell per topology.
pub fn fig11(quick: bool, jobs: usize) -> Vec<Table> {
    let n = if quick { 4 } else { 8 };
    let breakdowns = map_sweep(TopologyKind::ALL.to_vec(), jobs, |kind| {
        let cfg = topo_cfg(kind, n, quick);
        let mut sys = build_system(&cfg);
        sys.engine.run(u64::MAX);
        hop_breakdown(&sys)
    });
    let mut out = Vec::new();
    for (kind, hb) in TopologyKind::ALL.iter().zip(breakdowns) {
        let mut t = Table::new(
            &format!("Fig 11 — latency by hops ({}, scale {})", kind.name(), 2 * n),
            &["hops", "requests", "avg lat (ns)", "queue", "switch", "bus", "device"],
        );
        for (hops, count, lat, q, sw, bus, dev) in hb {
            t.row(&[
                hops.to_string(),
                count.to_string(),
                f(lat),
                f(q),
                f(sw),
                f(bus),
                f(dev),
            ]);
        }
        out.push(t);
    }
    out
}

/// Fig 12: latency by hop count under iso-bisection-bandwidth
/// configuration (per-topology port bandwidth scaled so every system has
/// the same requester->memory cut bandwidth).
pub fn fig12(quick: bool, jobs: usize) -> Vec<Table> {
    let n = if quick { 4 } else { 8 };
    let target_bisection = PORT_GBPS * n as f64; // FC-class cut
    let mut t = Table::new(
        "Fig 12 — avg latency by hops under iso-bisection bandwidth (ns)",
        &["topology", "port GB/s", "min-hops lat", "max-hops lat", "max/min", "overall avg"],
    );
    let rows = map_sweep(TopologyKind::ALL.to_vec(), jobs, |kind| {
        // Measure the requester/memory cut of the default build.
        let probe = crate::interconnect::build(kind, n, topo_link());
        let mut left: Vec<usize> = probe.requesters.clone();
        // requester-side switches: those strictly closer to requesters
        let routing = crate::interconnect::Routing::build_bfs(&probe.topo);
        for &s in &probe.switches {
            let dr: u32 = probe.requesters.iter().map(|&r| routing.dist(s, r) as u32).sum();
            let dm: u32 = probe.memories.iter().map(|&m| routing.dist(s, m) as u32).sum();
            if dr < dm {
                left.push(s);
            }
        }
        let cut = probe.topo.cut_bandwidth(&left).max(PORT_GBPS);
        let scale_bw = target_bisection / cut;
        let mut cfg = topo_cfg(kind, n, quick);
        cfg.link.bandwidth_gbps = PORT_GBPS * scale_bw;
        let mut sys = build_system(&cfg);
        sys.engine.run(u64::MAX);
        let hb = hop_breakdown(&sys);
        if hb.is_empty() {
            return None;
        }
        let minl = hb.first().unwrap().2;
        let maxl = hb.last().unwrap().2;
        let total: u64 = hb.iter().map(|r| r.1).sum();
        let avg: f64 = hb.iter().map(|r| r.2 * r.1 as f64).sum::<f64>() / total.max(1) as f64;
        Some(vec![
            kind.name().into(),
            f(PORT_GBPS * scale_bw),
            f(minl),
            f(maxl),
            f(maxl / minl.max(1e-9)),
            f(avg),
        ])
    });
    for row in rows.into_iter().flatten() {
        t.row(&row);
    }
    t.note("paper: chain ~2x min-hop latency at max hops, tree/ring ~1x extra; SL/FC stay flat");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's headline Fig 10 shape at scale 16: chain ~= tree ~= 1x,
    /// ring ~= 2x, spine-leaf ~= N/2, fully-connected ~= N.
    #[test]
    fn fig10_shape_scale16() {
        let n = 8;
        let chain = run_cell(TopologyKind::Chain, n, true);
        let tree = run_cell(TopologyKind::Tree, n, true);
        let ring = run_cell(TopologyKind::Ring, n, true);
        let sl = run_cell(TopologyKind::SpineLeaf, n, true);
        let fc = run_cell(TopologyKind::FullyConnected, n, true);
        assert!(chain > 0.6 && chain < 1.5, "chain {chain}");
        assert!(tree > 0.6 && tree < 1.5, "tree {tree}");
        assert!(ring > 1.4 * chain && ring < 3.0, "ring {ring} vs chain {chain}");
        assert!(sl > 2.5 && sl < 6.5, "spine-leaf {sl} (want ~N/2 = 4)");
        assert!(fc > 5.5, "fully-connected {fc} (want ~N = 8)");
        assert!(fc > sl && sl > ring && ring > chain, "ordering");
    }

    #[test]
    fn chain_bandwidth_does_not_scale() {
        let b4 = run_cell(TopologyKind::Chain, 2, true);
        let b16 = run_cell(TopologyKind::Chain, 8, true);
        assert!(
            (b16 - b4).abs() < 0.5,
            "chain should stay ~flat: {b4} vs {b16}"
        );
    }

    #[test]
    fn fc_bandwidth_scales_with_n() {
        let b8 = run_cell(TopologyKind::FullyConnected, 4, true);
        let b16 = run_cell(TopologyKind::FullyConnected, 8, true);
        assert!(b16 > 1.6 * b8, "FC should scale: {b8} -> {b16}");
    }

    #[test]
    fn fig11_latency_grows_with_hops() {
        let cfg = topo_cfg(TopologyKind::Chain, 4, true);
        let mut sys = build_system(&cfg);
        sys.engine.run(u64::MAX);
        let hb = hop_breakdown(&sys);
        assert!(hb.len() >= 3, "chain should spread hop counts");
        let first = hb.first().unwrap().2;
        let last = hb.last().unwrap().2;
        assert!(last > first, "latency should grow with hops: {first} vs {last}");
    }
}
