//! Full-duplex transmission experiments (paper §V-D, Fig 16/17).
//!
//! One requester, one bus, four memory endpoints; sweep the read:write
//! ratio and the link header overhead (normalized to the 64B payload),
//! full- vs half-duplex. Bandwidth per header setting is normalized to
//! the read-only scenario.

use crate::config::{build_on_fabric, BackendKind, SystemCfg};
use crate::devices::Pattern;
use crate::engine::time::ns;
use crate::interconnect::{Duplex, Fabric, LinkCfg, NodeKind, Routing, Topology, TopologyKind};
use crate::metrics::aggregate;
use crate::sweep::map_sweep;
use crate::util::table::{f, Table};

const HEADERS: [u64; 4] = [0, 16, 32, 64];
const DUPLEXES: [Duplex; 2] = [Duplex::Full, Duplex::Half];

/// The (duplex x header x ratio) grid both Fig 16 and Fig 17 walk, in
/// row-major order (ratio fastest).
fn grid() -> Vec<(Duplex, u64, f64)> {
    DUPLEXES
        .iter()
        .flat_map(|&d| {
            HEADERS
                .iter()
                .flat_map(move |&h| RATIOS.iter().map(move |&(_, rr)| (d, h, rr)))
        })
        .collect()
}

pub const RATIOS: [(&str, f64); 4] = [
    ("1:0", 1.0),
    ("3:1", 0.75),
    ("2:1", 2.0 / 3.0),
    ("1:1", 0.5),
];

pub struct DuplexResult {
    pub bandwidth_gbps: f64,
    pub bus_utility: f64,
    pub efficiency: f64,
}

/// One cell: (duplex, read_ratio, header bytes).
pub fn run_cell(duplex: Duplex, read_ratio: f64, header_bytes: u64, quick: bool) -> DuplexResult {
    let link = LinkCfg {
        bandwidth_gbps: 32.0,
        latency: ns(1.0),
        duplex,
        turnaround: ns(2.0),
        header_bytes,
    };
    let mut cfg = SystemCfg::new(TopologyKind::Chain, 1); // kind unused
    cfg.link = link;
    cfg.pattern = Pattern::Random;
    cfg.read_ratio = read_ratio;
    cfg.queue_capacity = 512;
    cfg.issue_interval = ns(0.25);
    cfg.requests_per_endpoint = if quick { 1000 } else { 4000 };
    cfg.warmup_fraction = 0.25;
    cfg.backend = BackendKind::Fixed(20.0);

    // requester -- ONE shared bus -- fan-out behind a switch-less root:
    // the paper's system is "a requester, a bus, four memory devices";
    // model the shared bus with a single link to a zero-latency splitter
    // switch, then infinite-bandwidth stubs to the endpoints.
    let mut topo = Topology::new();
    let r = topo.add_node("host", NodeKind::Requester);
    let hub = topo.add_node("rootport", NodeKind::Switch);
    topo.add_link(r, hub, link); // the measured bus
    let stub = LinkCfg {
        bandwidth_gbps: 0.0,
        latency: 0,
        duplex: Duplex::Full,
        turnaround: 0,
        header_bytes: 0,
    };
    let mut memories = Vec::new();
    for i in 0..4 {
        let m = topo.add_node(format!("m{i}"), NodeKind::Memory);
        topo.add_link(hub, m, stub);
        memories.push(m);
    }
    let routing = Routing::build_bfs(&topo);
    let fabric = Fabric {
        topo,
        requesters: vec![r],
        memories,
        switches: vec![hub],
    };
    let mut sys = build_on_fabric(&cfg, fabric, routing, &mut |_i, rc| rc);
    // Zero-cost splitter: the hub adds no latency.
    // (switch defaults would distort the bus-only measurement)
    // Rebuild hub component config: cheaper to patch latency via cfg —
    // instead we accept the constant offsets; they affect latency, not
    // the bandwidth/utility ratios under study.
    sys.engine.run(u64::MAX);
    let a = aggregate(&sys);
    // The measured bus is link 0 (requester -- hub).
    let net = &sys.engine.shared.net;
    DuplexResult {
        bandwidth_gbps: a.bandwidth_gbps(),
        bus_utility: net.bus_utility(0),
        efficiency: net.transmission_efficiency(0),
    }
}

/// Fig 16: bandwidth vs R:W ratio and header overhead, normalized to the
/// read-only scenario of each header setting; full vs half duplex. The
/// whole grid runs through the sweep driver; the 1:0 cell of each row
/// doubles as its normalization base.
pub fn fig16(quick: bool, jobs: usize) -> Vec<Table> {
    let cells = map_sweep(grid(), jobs, |(d, h, rr)| {
        run_cell(d, rr, h, quick).bandwidth_gbps
    });
    let ncols = RATIOS.len();
    let mut out = Vec::new();
    for (di, &duplex) in DUPLEXES.iter().enumerate() {
        let dname = if duplex == Duplex::Full { "full" } else { "half" };
        let mut t = Table::new(
            &format!("Fig 16 — bandwidth vs R:W mix, {dname}-duplex (normalized to 1:0)"),
            &["header/payload", "1:0", "3:1", "2:1", "1:1"],
        );
        for (hi, &h) in HEADERS.iter().enumerate() {
            let row_start = (di * HEADERS.len() + hi) * ncols;
            let base = cells[row_start]; // RATIOS[0] is the 1:0 cell
            let mut row = vec![format!("{:.2}", h as f64 / 64.0)];
            for ri in 0..ncols {
                row.push(f(cells[row_start + ri] / base));
            }
            t.row(&row);
        }
        if duplex == Duplex::Full {
            t.note("paper: zero header + 1:1 mix ~2x; gain vanishes as header -> payload size");
        } else {
            t.note("paper: half-duplex bandwidth ~flat across mixes");
        }
        out.push(t);
    }
    out
}

/// Fig 17: bus utility and transmission efficiency over the same grid.
pub fn fig17(quick: bool, jobs: usize) -> Vec<Table> {
    let cells = map_sweep(grid(), jobs, |(d, h, rr)| run_cell(d, rr, h, quick));
    let ncols = RATIOS.len();
    let mut ut = Table::new(
        "Fig 17a — bus utility",
        &["duplex", "header/payload", "1:0", "3:1", "2:1", "1:1"],
    );
    let mut ef = Table::new(
        "Fig 17b — transmission efficiency",
        &["duplex", "header/payload", "1:0", "3:1", "2:1", "1:1"],
    );
    for (di, &duplex) in DUPLEXES.iter().enumerate() {
        let dname = if duplex == Duplex::Full { "full" } else { "half" };
        for (hi, &h) in HEADERS.iter().enumerate() {
            let row_start = (di * HEADERS.len() + hi) * ncols;
            let mut urow = vec![dname.to_string(), format!("{:.2}", h as f64 / 64.0)];
            let mut erow = urow.clone();
            for ri in 0..ncols {
                let r = &cells[row_start + ri];
                urow.push(f(r.bus_utility));
                erow.push(f(r.efficiency));
            }
            ut.row(&urow);
            ef.row(&erow);
        }
    }
    ut.note("paper: half-duplex ~fully utilized throughout; full-duplex utility rises from ~0.5 to ~1 with mixing at zero header");
    ef.note("paper: efficiency falls as header overhead rises");
    vec![ut, ef]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixing_doubles_full_duplex_at_zero_header() {
        let ro = run_cell(Duplex::Full, 1.0, 0, true);
        let mix = run_cell(Duplex::Full, 0.5, 0, true);
        let gain = mix.bandwidth_gbps / ro.bandwidth_gbps;
        assert!(gain > 1.6, "1:1 gain {gain:.2} should approach 2x");
    }

    #[test]
    fn half_duplex_is_mix_insensitive() {
        let ro = run_cell(Duplex::Half, 1.0, 16, true);
        let mix = run_cell(Duplex::Half, 0.5, 16, true);
        let gain = mix.bandwidth_gbps / ro.bandwidth_gbps;
        assert!(
            (gain - 1.0).abs() < 0.15,
            "half-duplex gain {gain:.2} should be ~1"
        );
    }

    #[test]
    fn equal_header_kills_the_gain() {
        let ro = run_cell(Duplex::Full, 1.0, 64, true);
        let mix = run_cell(Duplex::Full, 0.5, 64, true);
        let gain = mix.bandwidth_gbps / ro.bandwidth_gbps;
        assert!(
            gain < 1.15,
            "header==payload gain {gain:.2} should collapse toward 1"
        );
    }

    #[test]
    fn full_duplex_utility_rises_with_mix() {
        let ro = run_cell(Duplex::Full, 1.0, 0, true);
        let mix = run_cell(Duplex::Full, 0.5, 0, true);
        assert!(ro.bus_utility < 0.7, "read-only utility {}", ro.bus_utility);
        assert!(
            mix.bus_utility > ro.bus_utility + 0.2,
            "mix utility {} vs ro {}",
            mix.bus_utility,
            ro.bus_utility
        );
    }

    #[test]
    fn efficiency_tracks_header_overhead() {
        let h0 = run_cell(Duplex::Full, 0.5, 0, true);
        let h64 = run_cell(Duplex::Full, 0.5, 64, true);
        assert!(h0.efficiency > 0.9);
        assert!(h64.efficiency < 0.6);
    }
}
