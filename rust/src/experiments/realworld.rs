//! Real-world workload experiments (paper §V-E, Fig 18/19/20).
//!
//! Replays the five (synthesized — see `workloads`) traces through ESF:
//!  * Fig 18/19 — throughput and latency across the five topologies,
//!    normalized to chain.
//!  * Fig 20a — full- vs half-duplex execution speedup vs mix degree.
//!  * Fig 20b — per-1000-access window bandwidth vs window mix degree
//!    (correlation), with window statistics computed by the AOT Pallas
//!    `tracestats` kernel through PJRT when artifacts are present (native
//!    fallback otherwise).

use crate::config::{build_system_with, BackendKind, RoutingSource, SystemCfg};
use crate::devices::{Pattern, Requester};
use crate::engine::time::ns;
use crate::interconnect::{Duplex, LinkCfg, TopologyKind};
use crate::metrics::aggregate;
use crate::sweep::map_sweep;
use crate::util::table::{f, Table};
use crate::workloads::{RealWorkload, Trace};
use std::sync::Arc;

/// The (workload x topology) grid Fig 18/19 walk (topology fastest).
fn trace_grid() -> Vec<(RealWorkload, TopologyKind)> {
    RealWorkload::ALL
        .iter()
        .flat_map(|&w| TopologyKind::ALL.iter().map(move |&k| (w, k)))
        .collect()
}

fn trace_len(quick: bool) -> usize {
    if quick {
        30_000
    } else {
        200_000
    }
}

/// Run one (workload, topology) cell; returns (throughput Maccess/s,
/// avg latency ns, exact p95 latency ns).
pub fn run_cell(w: RealWorkload, kind: TopologyKind, quick: bool) -> (f64, f64, f64) {
    let n = if quick { 4 } else { 8 };
    let trace = w.generate(trace_len(quick), 21);
    let ops = Arc::new(trace.ops);
    let mut cfg = SystemCfg::new(kind, n);
    cfg.link = LinkCfg {
        bandwidth_gbps: 32.0,
        latency: ns(1.0),
        duplex: Duplex::Full,
        turnaround: 0,
        header_bytes: 16,
    };
    cfg.issue_interval = ns(1.0);
    cfg.queue_capacity = 128;
    cfg.requests_per_endpoint = (trace_len(quick) / n / 4) as u64;
    cfg.warmup_fraction = 0.25;
    cfg.backend = BackendKind::Fixed(30.0);
    cfg.cache_lines = 0;
    let mut sys = build_system_with(&cfg, RoutingSource::Native, |idx, mut rc| {
        rc.pattern = Pattern::Trace(ops.clone());
        // decorrelate the requesters: start at different trace offsets by
        // rotating the seed (trace_pos starts at 0; emulate offsets by
        // seed-dependent skip below through issue jitter instead)
        rc.seed ^= idx as u64;
        rc
    });
    // offset each requester's starting position in the shared trace
    for (idx, &r) in sys.requesters.clone().iter().enumerate() {
        let rq = sys.engine.component_mut::<Requester>(r).unwrap();
        rq.skip_trace(idx * trace_len(quick) / (n * 2));
    }
    sys.engine.run(u64::MAX);
    let a = aggregate(&sys);
    let p95 = crate::metrics::latency_dist(&sys).percentile_ns(0.95);
    (a.throughput_maps(), a.avg_latency_ns(), p95)
}

/// Fig 18: trace throughput across topologies, normalized to chain.
pub fn fig18(quick: bool, jobs: usize) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 18 — real-world trace throughput (normalized to chain)",
        &["workload", "chain", "tree", "ring", "spine-leaf", "fully-connected"],
    );
    let cells = map_sweep(trace_grid(), jobs, |(w, k)| run_cell(w, k, quick).0);
    let nt = TopologyKind::ALL.len();
    let mut means = vec![0.0; nt];
    for (wi, w) in RealWorkload::ALL.iter().enumerate() {
        let vals = &cells[wi * nt..(wi + 1) * nt];
        let base = vals[0].max(1e-9);
        let mut row = vec![w.name().to_string()];
        for (i, v) in vals.iter().enumerate() {
            means[i] += v / base / 5.0;
            row.push(f(v / base));
        }
        t.row(&row);
    }
    t.note(format!(
        "geomean-ish: ring {:.2}x, SL {:.2}x, FC {:.2}x (paper: 1.72x, 2.27x, 3.63x)",
        means[2], means[3], means[4]
    ));
    vec![t]
}

/// Fig 19: average memory latency across topologies, normalized to
/// chain, plus a tail-latency companion table (exact p95 from the
/// recorded latency histogram — the percentile the sweep engine reports).
pub fn fig19(quick: bool, jobs: usize) -> Vec<Table> {
    let mut t = Table::new(
        "Fig 19 — real-world trace avg latency (normalized to chain)",
        &["workload", "chain", "tree", "ring", "spine-leaf", "fully-connected"],
    );
    let mut tail = Table::new(
        "Fig 19b — real-world trace p95 latency (ns, exact)",
        &["workload", "chain", "tree", "ring", "spine-leaf", "fully-connected"],
    );
    let cells = map_sweep(trace_grid(), jobs, |(w, k)| {
        let (_, avg, p95) = run_cell(w, k, quick);
        (avg, p95)
    });
    let nt = TopologyKind::ALL.len();
    for (wi, w) in RealWorkload::ALL.iter().enumerate() {
        let vals = &cells[wi * nt..(wi + 1) * nt];
        let base = vals[0].0.max(1e-9);
        let mut row = vec![w.name().to_string()];
        let mut tail_row = vec![w.name().to_string()];
        for (avg, p95) in vals {
            row.push(f(avg / base));
            tail_row.push(f(*p95));
        }
        t.row(&row);
        tail.row(&tail_row);
    }
    t.note("paper: ring 0.57x, spine-leaf 0.44x, fully-connected 0.28x of chain");
    tail.note("exact nearest-rank p95 over all measured completions");
    vec![t, tail]
}

/// Single-requester trace replay on a duplex-configurable bus; returns
/// (execution span ns, requester window marks, trace).
fn duplex_run(w: RealWorkload, duplex: Duplex, quick: bool, window: u64) -> (f64, Vec<u64>, Trace) {
    use crate::config::build_on_fabric;
    use crate::interconnect::{Fabric, NodeKind, Routing, Topology};
    let trace = w.generate(trace_len(quick), 33);
    let ops = Arc::new(trace.ops.clone());
    let link = LinkCfg {
        bandwidth_gbps: 10.0,
        latency: ns(1.0),
        duplex,
        turnaround: ns(2.0),
        header_bytes: 16,
    };
    let mut cfg = SystemCfg::new(TopologyKind::Chain, 1);
    cfg.link = link;
    cfg.issue_interval = ns(0.8);
    cfg.queue_capacity = 64;
    cfg.requests_per_endpoint = (trace_len(quick) / 4) as u64;
    cfg.warmup_fraction = 0.1;
    cfg.backend = BackendKind::Fixed(25.0);
    let mut topo = Topology::new();
    let r = topo.add_node("host", NodeKind::Requester);
    let mut memories = Vec::new();
    for i in 0..4 {
        let m = topo.add_node(format!("m{i}"), NodeKind::Memory);
        topo.add_link(r, m, link);
        memories.push(m);
    }
    let routing = Routing::build_bfs(&topo);
    let fabric = Fabric {
        topo,
        requesters: vec![r],
        memories,
        switches: vec![],
    };
    let mut sys = build_on_fabric(&cfg, fabric, routing, &mut |_i, mut rc| {
        rc.pattern = Pattern::Trace(ops.clone());
        rc.window_every = window;
        rc
    });
    sys.engine.run(u64::MAX);
    let span = crate::engine::time::to_ns(sys.engine.shared.epoch_span());
    let marks = sys
        .engine
        .component::<Requester>(0)
        .unwrap()
        .stats
        .window_marks
        .clone();
    (span, marks, trace)
}

/// Fig 20a: full-duplex speedup vs half-duplex, per workload, with the
/// workload's mix degree. The (workload x duplex) grid is one sweep.
pub fn fig20(quick: bool, jobs: usize) -> Vec<Table> {
    let mut a = Table::new(
        "Fig 20a — full-duplex speedup vs mix degree",
        &["workload", "mix degree", "speedup (half/full time)"],
    );
    let grid: Vec<(RealWorkload, Duplex)> = RealWorkload::ALL
        .iter()
        .flat_map(|&w| [Duplex::Full, Duplex::Half].into_iter().map(move |d| (w, d)))
        .collect();
    let runs = map_sweep(grid, jobs, |(w, d)| duplex_run(w, d, quick, 0));
    let mut pairs = Vec::new();
    for (wi, w) in RealWorkload::ALL.iter().enumerate() {
        let (full, _, trace) = &runs[wi * 2];
        let (half, _, _) = &runs[wi * 2 + 1];
        let mix = trace.mix_degree();
        let speedup = half / full.max(1e-9);
        pairs.push((mix, speedup));
        a.row(&[w.name().into(), f(mix), f(speedup)]);
    }
    pairs.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
    let monotone = pairs.windows(2).filter(|p| p[1].1 >= p[0].1 - 0.03).count();
    a.note(format!(
        "speedup rises with mix degree in {}/{} adjacent pairs (paper: monotone)",
        monotone,
        pairs.len() - 1
    ));

    // Fig 20b: per-window bandwidth vs window mix degree for silo.
    let window = 1000u64;
    let (_, marks, trace) = duplex_run(RealWorkload::Redis, Duplex::Full, quick, window);
    // Completion marks count MEASURED completions, which begin after the
    // warm-up slice of the trace — align the issue-order windows to it.
    let warmup = (trace.len() as f64 * 0.1) as usize;
    let measured = Trace {
        name: trace.name.clone(),
        ops: trace.ops[warmup..].to_vec(),
    };
    let wstats = window_stats(&measured, window as usize);
    let mut b = Table::new(
        "Fig 20b — per-window bandwidth vs mix degree (redis)",
        &["windows", "corr(mix, bw)", "bw gain per +0.1 mix"],
    );
    // Window k spans marks[k-1]..marks[k]; bandwidth = window*64B/span.
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for k in 1..marks.len().min(wstats.len()) {
        let span_ns = (marks[k] - marks[k - 1]) as f64 / 1000.0;
        if span_ns <= 0.0 {
            continue;
        }
        let bw = window as f64 * 64.0 / span_ns; // GB/s
        let (r, w, _) = wstats[k];
        let mix = (w as f64 / window as f64).min(r as f64 / window as f64);
        xs.push(mix);
        ys.push(bw);
    }
    let (corr, slope) = corr_slope(&xs, &ys);
    let mean_bw = ys.iter().sum::<f64>() / ys.len().max(1) as f64;
    b.row(&[
        xs.len().to_string(),
        f(corr),
        format!("{:+.1}%", slope * 0.1 / mean_bw * 100.0),
    ]);
    b.note("paper: high positive correlation; +0.1 mix degree => ~+9% bandwidth");
    vec![a, b]
}

/// Window statistics through the AOT tracestats kernel (PJRT) when
/// available, native otherwise. Both paths are cross-checked in tests.
pub fn window_stats(trace: &Trace, window: usize) -> Vec<(u64, u64, u64)> {
    let native = trace.windowed_stats(window);
    if let Ok(mut rt) = crate::runtime::Runtime::load_default() {
        let w = native.len();
        if w > 0 {
            let mut is_write = vec![0f32; w * window];
            let mut bytes = vec![0f32; w * window];
            for i in 0..w * window {
                is_write[i] = if trace.ops[i].is_write { 1.0 } else { 0.0 };
                bytes[i] = 64.0;
            }
            if let Ok(rows) = rt.tracestats(&is_write, &bytes, w, window) {
                return rows
                    .into_iter()
                    .map(|[r, wr, b]| (r as u64, wr as u64, b as u64))
                    .collect();
            }
        }
    }
    native
}

/// Pearson correlation and least-squares slope.
pub fn corr_slope(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let n = xs.len().min(ys.len());
    if n < 2 {
        return (0.0, 0.0);
    }
    let nf = n as f64;
    let mx = xs.iter().sum::<f64>() / nf;
    let my = ys.iter().sum::<f64>() / nf;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx <= 0.0 || syy <= 0.0 {
        return (0.0, 0.0);
    }
    (sxy / (sxx * syy).sqrt(), sxy / sxx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corr_slope_basics() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (c, s) = corr_slope(&xs, &ys);
        assert!((c - 1.0).abs() < 1e-9);
        assert!((s - 2.0).abs() < 1e-9);
        let inv = [7.0, 5.0, 3.0, 1.0];
        assert!(corr_slope(&xs, &inv).0 < -0.99);
    }

    #[test]
    fn fc_beats_chain_on_traces() {
        let (chain_tp, chain_lat, chain_p95) =
            run_cell(RealWorkload::Redis, TopologyKind::Chain, true);
        let (fc_tp, fc_lat, fc_p95) =
            run_cell(RealWorkload::Redis, TopologyKind::FullyConnected, true);
        assert!(fc_tp > 1.5 * chain_tp, "fc {fc_tp} vs chain {chain_tp}");
        assert!(fc_lat < chain_lat, "fc lat {fc_lat} vs chain {chain_lat}");
        // Tail latency is reported and consistent with the averages.
        assert!(fc_p95 > 0.0 && chain_p95 > 0.0);
        assert!(fc_p95 >= fc_lat * 0.5, "p95 {fc_p95} vs avg {fc_lat}");
    }

    #[test]
    fn high_mix_workload_gains_more_from_duplex() {
        let (silo_full, _, st) = duplex_run(RealWorkload::Silo, Duplex::Full, true, 0);
        let (silo_half, _, _) = duplex_run(RealWorkload::Silo, Duplex::Half, true, 0);
        let (bt_full, _, bt) = duplex_run(RealWorkload::BTree, Duplex::Full, true, 0);
        let (bt_half, _, _) = duplex_run(RealWorkload::BTree, Duplex::Half, true, 0);
        assert!(st.mix_degree() > bt.mix_degree());
        let silo_speedup = silo_half / silo_full;
        let bt_speedup = bt_half / bt_full;
        assert!(
            silo_speedup > bt_speedup,
            "silo speedup {silo_speedup:.2} should exceed btree {bt_speedup:.2}"
        );
    }

    #[test]
    fn window_stats_native_matches_manual() {
        let t = RealWorkload::Redis.generate(5000, 3);
        let w = t.windowed_stats(1000);
        assert_eq!(w.len(), 5);
        for (r, wr, b) in w {
            assert_eq!(r + wr, 1000);
            assert_eq!(b, 64_000);
        }
    }
}
