//! Experiment harnesses: one module per table/figure of the paper's
//! evaluation (§IV Validation, §V Design Space Exploration). Each harness
//! returns `util::table::Table`s whose rows mirror the series the paper
//! plots; `esf exp <id>` and `cargo bench` print them.

pub mod duplex;
pub mod invblk;
pub mod realworld;
pub mod routing;
pub mod snoopfilter;
pub mod spec;
pub mod topology;
pub mod validation;

use crate::util::table::Table;

/// All experiment ids with a one-line description.
pub fn list() -> Vec<(&'static str, &'static str)> {
    vec![
        ("fig7", "validation: idle latency + peak bandwidth vs R:W ratio"),
        ("fig8", "validation: loaded-latency curves (read, write)"),
        ("tab4", "SPEC-like CXL execution-time overhead across platforms"),
        ("tab5", "simulation-time overhead of integrations"),
        ("fig10", "system bandwidth by topology and scale"),
        ("fig11", "latency by hop count per topology (scale 16)"),
        ("fig12", "latency under iso-bisection bandwidth"),
        ("fig13", "oblivious vs adaptive routing under noisy neighbors"),
        ("fig14", "snoop filter victim selection policies"),
        ("fig15", "InvBlk block-invalidation lengths"),
        ("fig16", "bandwidth vs R:W mix and header overhead (duplex)"),
        ("fig17", "bus utility and transmission efficiency"),
        ("fig18", "real-world trace throughput across topologies"),
        ("fig19", "real-world trace latency across topologies"),
        ("fig20", "full-duplex speedup and mix-degree correlation"),
    ]
}

/// Run one experiment by id; `quick` shrinks request counts for fast
/// iteration (benches use quick=false by default where feasible).
/// Serial — see [`run_jobs`] for the parallel path.
pub fn run(id: &str, quick: bool) -> Option<Vec<Table>> {
    run_jobs(id, quick, 1)
}

/// Run one experiment by id with its config grid sharded over `jobs`
/// worker threads (0 = all cores) through the sweep driver
/// (`crate::sweep`). Each harness expresses its grid as data, so results
/// are identical for any job count; only wall-clock changes.
pub fn run_jobs(id: &str, quick: bool, jobs: usize) -> Option<Vec<Table>> {
    Some(match id {
        "fig7" => validation::fig7(quick, jobs),
        "fig8" => validation::fig8(quick, jobs),
        "tab4" => spec::tab4(quick, jobs),
        "tab5" => spec::tab5(quick, jobs),
        "fig10" => topology::fig10(quick, jobs),
        "fig11" => topology::fig11(quick, jobs),
        "fig12" => topology::fig12(quick, jobs),
        "fig13" => routing::fig13(quick, jobs),
        "fig14" => snoopfilter::fig14(quick, jobs),
        "fig15" => invblk::fig15(quick, jobs),
        "fig16" => duplex::fig16(quick, jobs),
        "fig17" => duplex::fig17(quick, jobs),
        "fig18" => realworld::fig18(quick, jobs),
        "fig19" => realworld::fig19(quick, jobs),
        "fig20" => realworld::fig20(quick, jobs),
        _ => return None,
    })
}
