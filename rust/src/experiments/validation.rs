//! Validation experiments (paper §IV, Fig 7 + Fig 8).
//!
//! The paper validates ESF against a dual-socket Xeon 6416H platform with
//! a Montage MXC CXL memory expander, using Intel MLC. Neither is
//! available here, so the "hardware" column is `HwReference`: an
//! *independent analytic model* of the same platform built from the paper's
//! Table III constants plus published CXL/NUMA measurements ([40], [55]).
//! The DES and the analytic model share calibration constants but compute
//! latency/bandwidth by entirely different means (event simulation vs
//! closed-form queueing), so the residual error is a meaningful accuracy
//! signal — the paper reports 0.1-10% bandwidth error and <=12% (avg 4.3%)
//! loaded-latency error; we report ours in EXPERIMENTS.md.

use crate::config::{BackendKind, SystemCfg};
use crate::devices::Pattern;
use crate::dram::DramCfg;
use crate::engine::time::ns;
use crate::interconnect::TopologyKind;
use crate::metrics::aggregate;
use crate::sweep::map_sweep;
use crate::util::table::{f, Table};

/// Analytic model of the validation platform ("the hardware").
pub struct HwReference {
    /// One-way fixed path latency (ns): requester + ports + controller.
    pub path_ns: f64,
    /// Media (DRAM) service mean (ns).
    pub media_ns: f64,
    /// Link bandwidth per direction (GB/s).
    pub link_gbps: f64,
    /// Header bytes per message.
    pub header: f64,
    /// Full duplex?
    pub full_duplex: bool,
    /// Media aggregate bandwidth cap (GB/s).
    pub media_gbps: f64,
}

impl HwReference {
    /// CXL memory expander (MXC-class device on PCIe 5.0 x16).
    pub fn cxl() -> HwReference {
        HwReference {
            // Table III composition along the DES path: 10 (req process)
            // + 25 (req port) + 1 (bus) + 20+25 (root-port switching) + 25
            // (dev port) + 40 (controller) + 25 (dev egress port) + 1
            // (bus) + 25 (req ingress port) = 197 ns fixed path.
            // (the root-port switch is traversed by request AND response)
            path_ns: 242.0,
            media_ns: 18.5, // DDR5 row-buffer-hit service (idle streams
                            // keep the small hot footprint's rows open)
            link_gbps: 64.0,
            header: 16.0,
            full_duplex: true,
            media_gbps: 4.0 * 38.4, // 4 DDR5-4800 DIMMs behind the MXC
        }
    }

    /// Local DDR5 DRAM (same socket).
    pub fn local_dram() -> HwReference {
        HwReference {
            path_ns: 60.0,
            media_ns: 30.0,
            link_gbps: 150.0, // aggregate DDR5 channels
            header: 0.0,
            full_duplex: false, // DDR bus is shared/bidirectional
            media_gbps: 150.0,
        }
    }

    /// Remote-socket DRAM over UPI (the NUMA emulator's substrate).
    pub fn remote_dram() -> HwReference {
        HwReference {
            path_ns: 100.0,
            media_ns: 30.0,
            link_gbps: 62.4, // 3x UPI 2.0 links
            header: 8.0,
            full_duplex: false,
            media_gbps: 100.0,
        }
    }

    pub fn idle_latency_ns(&self) -> f64 {
        // request header one way + data payload back (wire-size model:
        // data messages are pure payload, header-only messages cost the
        // header bytes — see interconnect::links).
        let ser = (64.0 + self.header) / self.link_gbps;
        self.path_ns + self.media_ns + ser
    }

    /// Peak payload bandwidth (GB/s) at `read_ratio` reads.
    ///
    /// Full duplex: a read puts a header-only request downstream and a
    /// payload response upstream; a write puts payload downstream and a
    /// header-only completion upstream. The binding direction is the
    /// busier one. Half duplex: one medium carries everything plus a
    /// turnaround tax growing with interleaving.
    pub fn peak_bandwidth_gbps(&self, read_ratio: f64) -> f64 {
        let r = read_ratio;
        let w = 1.0 - r;
        let pl = 64.0;
        let h = self.header;
        if self.full_duplex {
            let up = r * pl + w * h;
            let down = w * pl + r * h;
            let per_access = up.max(down);
            let link_bound = self.link_gbps * pl / per_access;
            // DDR write recovery (tWR) derates media throughput as the
            // write share grows.
            let media_eff = self.media_gbps * (1.0 - 0.85 * w);
            link_bound.min(media_eff)
        } else {
            // All bytes share one medium; direction changes cost a
            // turnaround tax growing with the mix.
            let bytes = pl + h;
            let mix = r.min(w);
            let turnaround_tax = 1.0 + 0.25 * mix;
            let link_bound = self.link_gbps * pl / (bytes * turnaround_tax);
            link_bound.min(self.media_gbps)
        }
    }

    /// Loaded latency via an M/D/1 waiting-time approximation at a given
    /// utilization of the peak.
    pub fn loaded_latency_ns(&self, offered_gbps: f64, read_ratio: f64) -> f64 {
        let peak = self.peak_bandwidth_gbps(read_ratio);
        let rho = (offered_gbps / peak).min(0.98);
        // M/D/1: Wq = rho * S / (2 (1 - rho)); service ~ media time.
        let s = self.media_ns;
        let wq = rho * s / (2.0 * (1.0 - rho));
        self.idle_latency_ns() + wq
    }
}

/// The validation DES system: one requester, a bus, four DRAM endpoints
/// (paper §IV methodology; DIMM count matched at four).
fn validation_cfg(read_ratio: f64, issue_interval_ns: f64, quick: bool) -> SystemCfg {
    let mut cfg = SystemCfg::new(TopologyKind::Chain, 1);
    // Chain preset with n=1 gives r0 - s0 - s1 - m0; we want the paper's
    // direct bus topology, so use a dedicated build below instead.
    cfg.read_ratio = read_ratio;
    cfg.issue_interval = ns(issue_interval_ns);
    cfg.requests_per_endpoint = if quick { 1000 } else { 4000 };
    cfg.warmup_fraction = if quick { 0.25 } else { 1.0 } ;
    cfg.backend = BackendKind::Dram(DramCfg::ddr5_4800());
    cfg.pattern = Pattern::Random;
    cfg.footprint_lines = 1 << 14;
    cfg
}

/// Build the paper's validation system: host -- ONE shared PCIe bus --
/// root-port fanout -- 4 memory endpoints (matching "a requester, an
/// interconnect bus, and four memory endpoints"; fanout stubs are
/// infinite-bandwidth so the shared bus is the only serialization point).
fn build_validation(
    read_ratio: f64,
    issue_interval_ns: f64,
    queue: usize,
    quick: bool,
) -> crate::config::System {
    use crate::config::build_on_fabric;
    use crate::interconnect::{Duplex, Fabric, LinkCfg, NodeKind, Routing, Topology};
    let mut cfg = validation_cfg(read_ratio, issue_interval_ns, quick);
    cfg.queue_capacity = queue;
    let link = LinkCfg::default(); // PCIe-class, 64 GB/s, 16B header
    let mut topo = Topology::new();
    let r = topo.add_node("host", NodeKind::Requester);
    let hub = topo.add_node("rootport", NodeKind::Switch);
    topo.add_link(r, hub, link); // the shared bus
    let stub = LinkCfg {
        bandwidth_gbps: 0.0,
        latency: 0,
        duplex: Duplex::Full,
        turnaround: 0,
        header_bytes: 0,
    };
    let mut memories = Vec::new();
    for i in 0..4 {
        let m = topo.add_node(format!("mxc{i}"), NodeKind::Memory);
        topo.add_link(hub, m, stub);
        memories.push(m);
    }
    let routing = Routing::build_bfs(&topo);
    let fabric = Fabric {
        topo,
        requesters: vec![r],
        memories,
        switches: vec![hub],
    };
    build_on_fabric(&cfg, fabric, routing, &mut |_i, rc| rc)
}

/// Fig 7: idle latency and peak bandwidth under different R:W ratios, for
/// CXL hardware (reference model), ESF, local DRAM, remote DRAM. The
/// four peak-bandwidth cells run as one sweep.
pub fn fig7(quick: bool, jobs: usize) -> Vec<Table> {
    let mut lat = Table::new(
        "Fig 7a — idle latency (ns)",
        &["platform", "idle latency", "vs hw"],
    );
    // ESF idle: single outstanding request, long interval.
    let mut sys = build_validation(1.0, 400.0, 1, quick);
    sys.engine.run(u64::MAX);
    let esf_idle = aggregate(&sys).avg_latency_ns();
    let hw = HwReference::cxl();
    let hw_idle = hw.idle_latency_ns();
    lat.row(&["CXL hardware (ref model)".into(), f(hw_idle), "-".into()]);
    lat.row(&[
        "ESF".into(),
        f(esf_idle),
        format!("{:+.1}%", (esf_idle - hw_idle) / hw_idle * 100.0),
    ]);
    lat.row(&[
        "local DRAM (ref model)".into(),
        f(HwReference::local_dram().idle_latency_ns()),
        "-".into(),
    ]);
    lat.row(&[
        "remote DRAM (ref model)".into(),
        f(HwReference::remote_dram().idle_latency_ns()),
        "-".into(),
    ]);

    let mut bw = Table::new(
        "Fig 7b — peak bandwidth vs R:W ratio (GB/s)",
        &["R:W", "CXL hw (ref)", "ESF", "err", "local (ref)", "remote (ref)"],
    );
    let ratios = [("1:0", 1.0), ("3:1", 0.75), ("2:1", 2.0 / 3.0), ("1:1", 0.5)];
    let esf_bws = map_sweep(ratios.to_vec(), jobs, |(_, rr)| {
        let mut sys = build_validation(rr, 0.25, 512, quick);
        sys.engine.run(u64::MAX);
        aggregate(&sys).bandwidth_gbps()
    });
    for ((label, rr), esf_bw) in ratios.into_iter().zip(esf_bws) {
        let hw_bw = hw.peak_bandwidth_gbps(rr);
        bw.row(&[
            label.into(),
            f(hw_bw),
            f(esf_bw),
            format!("{:+.1}%", (esf_bw - hw_bw) / hw_bw * 100.0),
            f(HwReference::local_dram().peak_bandwidth_gbps(rr)),
            f(HwReference::remote_dram().peak_bandwidth_gbps(rr)),
        ]);
    }
    bw.note("paper: ESF bandwidth error 0.1%-10%; CXL bandwidth rises with mixing, local/remote fall");
    vec![lat, bw]
}

/// Fig 8: latency-bandwidth curves under increasing intensity (loaded
/// latency), reads and writes. Each intensity level is a sweep cell.
pub fn fig8(quick: bool, jobs: usize) -> Vec<Table> {
    let hw = HwReference::cxl();
    let mut out = Vec::new();
    for &(label, rr) in &[("read", 1.0), ("write", 0.0)] {
        let mut t = Table::new(
            &format!("Fig 8 — loaded latency ({label})"),
            &["intensity (GB/s offered)", "ESF bw", "ESF lat (ns)", "hw-ref lat (ns)", "err"],
        );
        let intervals = if quick {
            vec![200.0, 50.0, 16.0, 8.0, 4.0, 2.0, 1.2, 1.0]
        } else {
            vec![400.0, 100.0, 50.0, 24.0, 16.0, 8.0, 4.0, 2.0, 1.4, 1.0, 0.9]
        };
        let cells = map_sweep(intervals.clone(), jobs, |itv| {
            let mut sys = build_validation(rr, itv, 64, quick);
            sys.engine.run(u64::MAX);
            let a = aggregate(&sys);
            (a.bandwidth_gbps(), a.avg_latency_ns())
        });
        let mut errs = Vec::new();
        for (itv, (esf_bw, esf_lat)) in intervals.into_iter().zip(cells) {
            let ref_lat = hw.loaded_latency_ns(esf_bw, rr);
            let err = (esf_lat - ref_lat) / ref_lat * 100.0;
            errs.push(err.abs());
            t.row(&[
                format!("{:.1}", 64.0 / itv),
                f(esf_bw),
                f(esf_lat),
                f(ref_lat),
                format!("{err:+.1}%"),
            ]);
        }
        let avg = errs.iter().sum::<f64>() / errs.len() as f64;
        let max = errs.iter().cloned().fold(0.0, f64::max);
        t.note(format!(
            "avg |err| {avg:.1}% (paper 4.3%), max {max:.1}% (paper 12%)"
        ));
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hw_reference_duplex_shapes() {
        let cxl = HwReference::cxl();
        // CXL bandwidth must rise with mixing...
        assert!(cxl.peak_bandwidth_gbps(0.5) > cxl.peak_bandwidth_gbps(1.0));
        // ...while the shared-bus platforms fall.
        let local = HwReference::local_dram();
        assert!(local.peak_bandwidth_gbps(0.5) < local.peak_bandwidth_gbps(1.0));
    }

    #[test]
    fn hw_reference_loaded_latency_monotone() {
        let cxl = HwReference::cxl();
        let peak = cxl.peak_bandwidth_gbps(1.0);
        let l1 = cxl.loaded_latency_ns(0.1 * peak, 1.0);
        let l2 = cxl.loaded_latency_ns(0.8 * peak, 1.0);
        assert!(l2 > l1);
        assert!(l1 >= cxl.idle_latency_ns());
    }

    #[test]
    fn esf_idle_latency_close_to_reference() {
        let mut sys = build_validation(1.0, 400.0, 1, true);
        sys.engine.run(u64::MAX);
        let esf = aggregate(&sys).avg_latency_ns();
        let hw = HwReference::cxl().idle_latency_ns();
        let err = (esf - hw).abs() / hw;
        assert!(
            err < 0.12,
            "idle latency error {:.1}% (esf {esf:.0} vs hw {hw:.0})",
            err * 100.0
        );
    }

    #[test]
    fn esf_bandwidth_rises_with_mixing() {
        let run = |rr: f64| {
            let mut sys = build_validation(rr, 0.25, 512, true);
            sys.engine.run(u64::MAX);
            aggregate(&sys).bandwidth_gbps()
        };
        let ro = run(1.0);
        let mixed = run(0.5);
        assert!(
            mixed > ro * 1.3,
            "1:1 mix {mixed:.1} should beat read-only {ro:.1} by >30%"
        );
    }

    #[test]
    fn fig7_tables_render() {
        let tables = fig7(true, 2);
        assert_eq!(tables.len(), 2);
        assert!(tables[1].rows.len() == 4);
    }
}
