//! Tiny CLI argument parser (std-only; `clap` is unavailable offline).
//!
//! Grammar: `esf <command> [positionals...] [--flag] [--key value]...`

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value`, `--key value`, or bare `--flag`
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(key.to_string(), v);
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_positionals_flags() {
        let a = Args::parse(v(&["exp", "fig10", "--seed", "7", "--quiet", "--k=v"]));
        assert_eq!(a.command.as_deref(), Some("exp"));
        assert_eq!(a.positional, vec!["fig10"]);
        assert_eq!(a.u64_or("seed", 0), 7);
        assert!(a.has("quiet"));
        assert_eq!(a.str_or("k", ""), "v");
    }

    #[test]
    fn bare_flag_at_end() {
        let a = Args::parse(v(&["run", "--verbose"]));
        assert!(a.has("verbose"));
        assert_eq!(a.str_or("verbose", ""), "true");
    }

    #[test]
    fn defaults() {
        let a = Args::parse(v(&[]));
        assert!(a.command.is_none());
        assert_eq!(a.u64_or("x", 5), 5);
        assert_eq!(a.f64_or("y", 0.5), 0.5);
    }
}
