//! ASCII table formatting for experiment output — every `esf exp <id>`
//! harness prints the same rows/series the paper's table or figure reports.

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:>w$} | ", w = w));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let sep: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        out.push_str(&"-".repeat(sep));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    /// CSV emission for plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float to 3 significant-ish decimals for table cells.
pub fn f(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 1.0 {
        format!("{v:.2}")
    } else {
        format!("{v:.4}")
    }
}

/// Format a percentage cell.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("longer"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("demo", &["x", "y"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "x,y\n1,2\n");
    }

    #[test]
    fn float_formats() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(123.456), "123.5");
        assert_eq!(f(1.234), "1.23");
        assert_eq!(f(0.1234), "0.1234");
        assert_eq!(pct(0.155), "15.5%");
    }
}
