//! Inline small-vector (std-only; the offline crate set has no
//! `smallvec`). Stores up to `N` elements in-place — the common case for
//! snoop-filter owner lists and other per-entry sets — and spills to a
//! heap `Vec` only beyond that, so the hot path allocates nothing.

/// A vector of `Copy` elements with inline storage for the first `N`.
///
/// On the first push past `N` the inline elements are copied into the
/// spill `Vec` and all elements live there from then on, so `as_slice()`
/// is always one contiguous slice.
#[derive(Clone, Debug)]
pub struct InlineVec<T: Copy + Default, const N: usize> {
    inline: [T; N],
    /// Length while inline; once spilled, `spill.len()` is authoritative.
    inline_len: usize,
    spill: Vec<T>,
}

impl<T: Copy + Default, const N: usize> Default for InlineVec<T, N> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T: Copy + Default, const N: usize> InlineVec<T, N> {
    pub fn new() -> InlineVec<T, N> {
        InlineVec {
            inline: [T::default(); N],
            inline_len: 0,
            spill: Vec::new(),
        }
    }

    pub fn push(&mut self, v: T) {
        if self.spill.is_empty() {
            if self.inline_len < N {
                self.inline[self.inline_len] = v;
                self.inline_len += 1;
                return;
            }
            // First spill: move the inline prefix onto the heap.
            self.spill.reserve(N + 1);
            self.spill.extend_from_slice(&self.inline[..self.inline_len]);
            self.inline_len = 0;
        }
        self.spill.push(v);
    }

    pub fn as_slice(&self) -> &[T] {
        if self.spill.is_empty() {
            &self.inline[..self.inline_len]
        } else {
            &self.spill
        }
    }

    pub fn len(&self) -> usize {
        if self.spill.is_empty() {
            self.inline_len
        } else {
            self.spill.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Keeps the spill allocation for reuse.
    pub fn clear(&mut self) {
        self.inline_len = 0;
        self.spill.clear();
    }

    pub fn contains(&self, v: &T) -> bool
    where
        T: PartialEq,
    {
        self.as_slice().contains(v)
    }

    pub fn to_vec(&self) -> Vec<T> {
        self.as_slice().to_vec()
    }

    pub fn iter(&self) -> std::slice::Iter<'_, T> {
        self.as_slice().iter()
    }
}

impl<T: Copy + Default, const N: usize> std::ops::Deref for InlineVec<T, N> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<'a, T: Copy + Default, const N: usize> IntoIterator for &'a InlineVec<T, N> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stays_inline_up_to_capacity() {
        let mut v: InlineVec<u32, 4> = InlineVec::new();
        for i in 0..4 {
            v.push(i);
        }
        assert_eq!(v.len(), 4);
        assert_eq!(v.as_slice(), &[0, 1, 2, 3]);
        assert!(v.spill.is_empty(), "must not have spilled yet");
    }

    #[test]
    fn spills_preserving_order() {
        let mut v: InlineVec<u32, 2> = InlineVec::new();
        for i in 0..7 {
            v.push(i);
        }
        assert_eq!(v.as_slice(), &[0, 1, 2, 3, 4, 5, 6]);
        assert_eq!(v.len(), 7);
        assert!(v.contains(&6));
        assert!(!v.contains(&7));
    }

    #[test]
    fn clear_resets_both_regions() {
        let mut v: InlineVec<u32, 2> = InlineVec::new();
        for i in 0..5 {
            v.push(i);
        }
        v.clear();
        assert!(v.is_empty());
        v.push(9);
        assert_eq!(v.as_slice(), &[9]);
    }

    #[test]
    fn deref_and_iter() {
        let mut v: InlineVec<u64, 4> = InlineVec::new();
        v.push(3);
        v.push(1);
        let sum: u64 = v.iter().sum();
        assert_eq!(sum, 4);
        assert_eq!(v[0], 3);
        assert_eq!(v.to_vec(), vec![3, 1]);
    }
}
