//! Std-only utility modules (the offline crate set has no `rand`, `serde`,
//! `clap`, or `proptest`; these are the in-tree replacements).

pub mod args;
pub mod flatmap;
pub mod inline;
pub mod json;
pub mod prop;
pub mod rng;
pub mod table;
