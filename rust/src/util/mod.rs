//! Std-only utility modules (the offline crate set has no `rand`, `serde`,
//! `clap`, or `proptest`; these are the in-tree replacements).

pub mod args;
pub mod flatmap;
pub mod inline;
pub mod json;
pub mod prop;
pub mod rng;
pub mod snap;
pub mod table;

/// Incremental FNV-1a (64-bit) — the repo-wide content/result digest
/// primitive (sweep cache keys, golden-test digests, trace fingerprints).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
    }

    pub fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }

    pub fn word(&mut self, w: u64) {
        self.bytes(&w.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot FNV-1a 64 over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut f = Fnv64::new();
    f.bytes(bytes);
    f.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Reference FNV-1a 64 values.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn fnv_incremental_matches_oneshot() {
        let mut f = Fnv64::new();
        f.bytes(b"foo");
        f.bytes(b"bar");
        assert_eq!(f.finish(), fnv1a64(b"foobar"));
        let mut w = Fnv64::new();
        w.word(0x1122_3344_5566_7788);
        assert_eq!(w.finish(), fnv1a64(&0x1122_3344_5566_7788u64.to_le_bytes()));
    }
}
