//! Std-only utility modules (the offline crate set has no `rand`, `serde`,
//! `clap`, or `proptest`; these are the in-tree replacements).

pub mod args;
pub mod flatmap;
pub mod inline;
pub mod json;
pub mod prop;
pub mod rng;
pub mod snap;
pub mod table;

/// Atomic file write: temp-with-pid + rename.
///
/// The repo's durability discipline in one place (previously hand-rolled
/// three times: sweep cache cells, warm-start snapshots, `--checkpoint`
/// files). The temp file lives in the target's directory — `rename(2)` is
/// only atomic within one filesystem — and its name embeds both the
/// process id (two processes sharing a cache dir can never rename each
/// other's half-written bytes into place) and a caller-chosen `tag`
/// (disambiguates concurrent writers inside one process). The name starts
/// with `.tmp-`, the prefix [`crate::sweep::SweepCache::open`] sweeps for
/// stale leftovers of killed writers.
///
/// A kill between write and rename leaves the previous file untouched —
/// for every caller, an older intact artifact is strictly more useful
/// than a torn fresh one. On rename failure the temp file is removed.
pub fn atomic_write(path: &std::path::Path, bytes: &[u8], tag: u64) -> std::io::Result<()> {
    let file = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "out".to_string());
    let tmp_name = format!(".tmp-{file}-{}-{tag}", std::process::id());
    let tmp = match path.parent() {
        Some(dir) if !dir.as_os_str().is_empty() => dir.join(tmp_name),
        _ => std::path::PathBuf::from(tmp_name),
    };
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path).map_err(|e| {
        let _ = std::fs::remove_file(&tmp);
        e
    })
}

/// Incremental FNV-1a (64-bit) — the repo-wide content/result digest
/// primitive (sweep cache keys, golden-test digests, trace fingerprints).
#[derive(Clone, Copy, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
    }

    pub fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }

    pub fn word(&mut self, w: u64) {
        self.bytes(&w.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot FNV-1a 64 over a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut f = Fnv64::new();
    f.bytes(bytes);
    f.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_known_vectors() {
        // Reference FNV-1a 64 values.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!("esf-atomic-write-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("cell.json");
        atomic_write(&target, b"first", 0).unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"first");
        // Overwrite is atomic: the new bytes fully replace the old.
        atomic_write(&target, b"second", 1).unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"second");
        // No `.tmp-*` residue after successful writes.
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.starts_with(".tmp-"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fnv_incremental_matches_oneshot() {
        let mut f = Fnv64::new();
        f.bytes(b"foo");
        f.bytes(b"bar");
        assert_eq!(f.finish(), fnv1a64(b"foobar"));
        let mut w = Fnv64::new();
        w.word(0x1122_3344_5566_7788);
        assert_eq!(w.finish(), fnv1a64(&0x1122_3344_5566_7788u64.to_le_bytes()));
    }
}
