//! Flat open-addressing `u64 -> u64` counter table (std-only stand-in for
//! a fast hash map). Linear probing over two parallel arrays — no
//! per-entry allocation, no tree rebalancing — built for the snoop
//! filter's LFI global insertion counters, which are increment-only.

/// Increment-only counter map with power-of-two capacity and linear
/// probing. Deterministic: iteration order is never exposed, only point
/// lookups.
#[derive(Clone, Debug)]
pub struct FlatCounter {
    keys: Vec<u64>,
    vals: Vec<u64>,
    used: Vec<bool>,
    len: usize,
    mask: usize,
}

impl Default for FlatCounter {
    fn default() -> FlatCounter {
        FlatCounter::with_capacity(16)
    }
}

impl FlatCounter {
    pub fn new() -> FlatCounter {
        FlatCounter::default()
    }

    /// `cap` is rounded up to a power of two (minimum 8).
    pub fn with_capacity(cap: usize) -> FlatCounter {
        let cap = cap.max(8).next_power_of_two();
        FlatCounter {
            keys: vec![0; cap],
            vals: vec![0; cap],
            used: vec![false; cap],
            len: 0,
            mask: cap - 1,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn slot_of(&self, key: u64) -> usize {
        let mut i = hash(key) as usize & self.mask;
        while self.used[i] && self.keys[i] != key {
            i = (i + 1) & self.mask;
        }
        i
    }

    /// Current count for `key` (0 if never incremented).
    pub fn get(&self, key: u64) -> u64 {
        let i = self.slot_of(key);
        if self.used[i] {
            self.vals[i]
        } else {
            0
        }
    }

    /// Add 1 to `key`'s count and return the new value.
    pub fn increment(&mut self, key: u64) -> u64 {
        if self.len * 4 >= (self.mask + 1) * 3 {
            self.grow();
        }
        let i = self.slot_of(key);
        if self.used[i] {
            self.vals[i] += 1;
        } else {
            self.used[i] = true;
            self.keys[i] = key;
            self.vals[i] = 1;
            self.len += 1;
        }
        self.vals[i]
    }

    /// All `(key, count)` entries sorted by key — the canonical order for
    /// snapshot serialization (slot layout is capacity-dependent and never
    /// part of observable state).
    pub fn sorted_pairs(&self) -> Vec<(u64, u64)> {
        let mut out: Vec<(u64, u64)> = (0..self.keys.len())
            .filter(|&i| self.used[i])
            .map(|i| (self.keys[i], self.vals[i]))
            .collect();
        out.sort_unstable_by_key(|&(k, _)| k);
        out
    }

    /// Set `key`'s count outright (snapshot restore; counts observable via
    /// `get` are identical regardless of insertion order).
    pub fn set(&mut self, key: u64, val: u64) {
        if self.len * 4 >= (self.mask + 1) * 3 {
            self.grow();
        }
        let i = self.slot_of(key);
        if !self.used[i] {
            self.used[i] = true;
            self.keys[i] = key;
            self.len += 1;
        }
        self.vals[i] = val;
    }

    fn grow(&mut self) {
        let new_cap = (self.mask + 1) * 2;
        let old_keys = std::mem::replace(&mut self.keys, vec![0; new_cap]);
        let old_vals = std::mem::replace(&mut self.vals, vec![0; new_cap]);
        let old_used = std::mem::replace(&mut self.used, vec![false; new_cap]);
        self.mask = new_cap - 1;
        self.len = 0;
        for i in 0..old_keys.len() {
            if old_used[i] {
                let j = self.slot_of(old_keys[i]);
                self.used[j] = true;
                self.keys[j] = old_keys[i];
                self.vals[j] = old_vals[i];
                self.len += 1;
            }
        }
    }
}

/// SplitMix64 avalanche — same mixer the deterministic RNG seeds with.
fn hash(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_accumulate_per_key() {
        let mut m = FlatCounter::new();
        assert_eq!(m.get(7), 0);
        assert_eq!(m.increment(7), 1);
        assert_eq!(m.increment(7), 2);
        assert_eq!(m.increment(9), 1);
        assert_eq!(m.get(7), 2);
        assert_eq!(m.get(9), 1);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn grows_past_load_factor_without_losing_counts() {
        let mut m = FlatCounter::with_capacity(8);
        for k in 0..1000u64 {
            for _ in 0..=(k % 3) {
                m.increment(k * 64);
            }
        }
        assert_eq!(m.len(), 1000);
        for k in 0..1000u64 {
            assert_eq!(m.get(k * 64), k % 3 + 1, "key {k}");
        }
    }

    #[test]
    fn matches_btreemap_reference_on_random_streams() {
        use crate::util::prop::forall;
        use std::collections::BTreeMap;
        forall(
            "flat counter vs btreemap",
            30,
            |rng| {
                (0..500)
                    .map(|_| rng.gen_range(64) * 64)
                    .collect::<Vec<u64>>()
            },
            |keys| {
                let mut flat = FlatCounter::new();
                let mut reference: BTreeMap<u64, u64> = BTreeMap::new();
                for &k in keys {
                    let r = {
                        let c = reference.entry(k).or_insert(0);
                        *c += 1;
                        *c
                    };
                    if flat.increment(k) != r {
                        return Err(format!("count diverged for key {k}"));
                    }
                }
                for (&k, &v) in &reference {
                    if flat.get(k) != v {
                        return Err(format!("get({k}) = {} != {v}", flat.get(k)));
                    }
                }
                Ok(())
            },
        );
    }
}
