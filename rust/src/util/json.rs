//! Minimal JSON parser/serializer (std-only; `serde` is unavailable in the
//! offline crate set). Supports the full JSON grammar minus exotic number
//! forms; used by the config system (`config::`) and the artifacts manifest
//! loader (`runtime::`).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.get(key)` with typed extraction and a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(Json::as_u64).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }

    /// Build an object from `(key, value)` pairs. The underlying map is a
    /// `BTreeMap`, so the serialized form is canonical (keys sorted) —
    /// which is what makes JSON dumps and cache cells byte-stable.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8.
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    let end = start + width;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].str_or("b", ""),
            "c"
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null},"e":"q\"uote"}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"héllo \\u00e9\"").unwrap();
        assert_eq!(v, Json::Str("héllo é".to_string()));
    }

    #[test]
    fn obj_builder_is_canonical() {
        let j = Json::obj(vec![
            ("zeta", Json::Num(1.0)),
            ("alpha", Json::Str("x".into())),
        ]);
        assert_eq!(j.to_string(), r#"{"alpha":"x","zeta":1}"#);
        // f64 Display is shortest-roundtrip: parse(format(x)) == x exactly.
        let v = Json::Num(0.1 + 0.2);
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": false}"#).unwrap();
        assert_eq!(v.u64_or("n", 0), 3);
        assert_eq!(v.u64_or("missing", 9), 9);
        assert_eq!(v.str_or("s", "-"), "x");
        assert!(!v.bool_or("b", true));
    }
}
