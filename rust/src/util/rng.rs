//! Deterministic pseudo-random number generation.
//!
//! The simulator must be bit-reproducible across runs (same seed => same
//! event trace), and the offline crate set has no `rand`, so we carry our
//! own small generators: SplitMix64 for seeding and PCG32 (XSH-RR) for the
//! per-component streams.

/// SplitMix64: used to expand one u64 seed into independent streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xDA94_2042_E4DD_58B5));
        let mut rng = Self {
            state: 0,
            inc: (sm.next_u64() << 1) | 1,
        };
        rng.state = sm.next_u64();
        rng.next_u32();
        rng
    }

    /// Raw generator registers `(state, inc)` for snapshot serialization.
    pub fn save_state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from [`Pcg32::save_state`] registers; the
    /// restored stream continues exactly where the saved one stopped.
    pub fn from_state(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, bound) without modulo bias (Lemire).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be > 0");
        if bound == 1 {
            return 0;
        }
        // 64-bit Lemire rejection.
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, bound);
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick an element index weighted uniformly.
    pub fn choice(&mut self, len: usize) -> usize {
        self.gen_range(len as u64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should not correlate, {same} equal draws");
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut rng = Pcg32::new(7, 3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg32::new(9, 0);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(1, 1);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
