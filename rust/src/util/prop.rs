//! Mini property-testing harness (std-only; `proptest` is unavailable in
//! the offline crate set).
//!
//! ```ignore
//! forall("routing next hop decreases distance", 200, |rng| gen_graph(rng), |g| {
//!     // return Err(String) to fail with a counterexample dump
//!     Ok(())
//! });
//! ```
//!
//! On failure the panic message carries the iteration index and the seed so
//! the case can be replayed deterministically (`PROP_SEED=<seed>`).

use super::rng::Pcg32;
use std::fmt::Debug;

/// Number of cases per property; override with env `PROP_CASES`.
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0xE5F_C0DE)
}

/// Run `prop` over `cases` inputs drawn by `gen`. Panics with the seed and a
/// Debug dump of the counterexample on first failure.
pub fn forall<T: Debug>(
    name: &str,
    cases: u64,
    mut gen: impl FnMut(&mut Pcg32) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = base_seed();
    for i in 0..cases {
        let mut rng = Pcg32::new(seed, i);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {i}/{cases} (PROP_SEED={seed}):\n  \
                 {msg}\n  counterexample: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", 50, |rng| rng.gen_range(100), |_| Ok(()));
        forall(
            "counted",
            50,
            |rng| rng.gen_range(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_panics_with_counterexample() {
        forall(
            "must fail",
            50,
            |rng| rng.gen_range(10),
            |v| {
                if *v < 9 {
                    Ok(())
                } else {
                    Err(format!("{v} too big"))
                }
            },
        );
    }
}
