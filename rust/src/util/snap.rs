//! Length-checked little-endian byte plumbing for engine snapshots.
//!
//! `SnapWriter`/`SnapReader` are the dumb transport layer under
//! `engine::snapshot`: fixed-width little-endian scalars, length-prefixed
//! byte strings, and read errors that carry the exact byte offset so
//! ESF-C014 can report a precise locus for truncated or corrupt files.
//! No framing decisions live here — magic numbers, versioning, and the
//! trailing digest are the snapshot format's business.

/// Append-only little-endian encoder.
#[derive(Default)]
pub struct SnapWriter {
    buf: Vec<u8>,
}

impl SnapWriter {
    pub fn new() -> SnapWriter {
        SnapWriter { buf: Vec::new() }
    }

    /// Reuse an existing allocation: the buffer is cleared but keeps its
    /// capacity, so repeated captures into the same `Vec` (engine
    /// `snapshot_into`, the speculative engine's per-domain rollback
    /// checkpoints) stop paying an allocation per capture.
    pub fn reuse(mut buf: Vec<u8>) -> SnapWriter {
        buf.clear();
        SnapWriter { buf }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.buf
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    /// IEEE-754 bit pattern; round-trips NaN payloads and -0.0 exactly.
    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    /// Raw bytes, no length prefix (caller frames them).
    pub fn raw(&mut self, bs: &[u8]) {
        self.buf.extend_from_slice(bs);
    }

    /// Length-prefixed byte string (u64 length, then the bytes).
    pub fn bytes(&mut self, bs: &[u8]) {
        self.u64(bs.len() as u64);
        self.buf.extend_from_slice(bs);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }
}

/// Bounds-checked little-endian decoder over a borrowed buffer.
pub struct SnapReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapReader<'a> {
    pub fn new(buf: &'a [u8]) -> SnapReader<'a> {
        SnapReader { buf, pos: 0 }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated at byte {}: need {n} bytes for {what}, {} left",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1, "u8")?[0])
    }

    pub fn u32(&mut self) -> Result<u32, String> {
        let bs = self.take(4, "u32")?;
        Ok(u32::from_le_bytes(bs.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, String> {
        let bs = self.take(8, "u64")?;
        Ok(u64::from_le_bytes(bs.try_into().unwrap()))
    }

    pub fn u128(&mut self) -> Result<u128, String> {
        let bs = self.take(16, "u128")?;
        Ok(u128::from_le_bytes(bs.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize, String> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| format!("byte {}: length {v} exceeds usize", self.pos))
    }

    pub fn bool(&mut self) -> Result<bool, String> {
        let at = self.pos;
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(format!("byte {at}: invalid bool tag {b}")),
        }
    }

    pub fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Length-prefixed byte string written by [`SnapWriter::bytes`].
    pub fn bytes(&mut self) -> Result<&'a [u8], String> {
        let n = self.usize()?;
        self.take(n, "byte string")
    }

    /// Length-prefixed UTF-8 string written by [`SnapWriter::str`].
    pub fn str(&mut self) -> Result<String, String> {
        let at = self.pos;
        let bs = self.bytes()?;
        String::from_utf8(bs.to_vec()).map_err(|_| format!("byte {at}: string is not UTF-8"))
    }

    /// Fail unless the whole buffer was consumed.
    pub fn expect_eof(&self) -> Result<(), String> {
        if self.remaining() != 0 {
            return Err(format!(
                "trailing garbage: {} unread bytes at byte {}",
                self.remaining(),
                self.pos
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_round_trip() {
        let mut w = SnapWriter::new();
        w.u8(0xAB);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.u128(u128::MAX / 3);
        w.bool(true);
        w.bool(false);
        w.f64(-0.0);
        w.f64(f64::NAN);
        w.str("héllo");
        w.bytes(&[1, 2, 3]);
        let bytes = w.into_bytes();
        let mut r = SnapReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 0xAB);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.u128().unwrap(), u128::MAX / 3);
        assert!(r.bool().unwrap());
        assert!(!r.bool().unwrap());
        assert_eq!(r.f64().unwrap().to_bits(), (-0.0f64).to_bits());
        assert!(r.f64().unwrap().is_nan());
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.bytes().unwrap(), &[1, 2, 3]);
        r.expect_eof().unwrap();
    }

    #[test]
    fn truncation_reports_offset() {
        let mut w = SnapWriter::new();
        w.u64(7);
        let mut bytes = w.into_bytes();
        bytes.truncate(5);
        let mut r = SnapReader::new(&bytes);
        let err = r.u64().unwrap_err();
        assert!(err.contains("truncated at byte 0"), "{err}");
    }

    #[test]
    fn bad_bool_and_trailing_bytes_rejected() {
        let mut r = SnapReader::new(&[7]);
        assert!(r.bool().unwrap_err().contains("invalid bool tag 7"));
        let r = SnapReader::new(&[0, 0]);
        assert!(r.expect_eof().unwrap_err().contains("2 unread bytes"));
    }

    #[test]
    fn string_length_prefix_guards_truncation() {
        let mut w = SnapWriter::new();
        w.str("abcdef");
        let mut bytes = w.into_bytes();
        bytes.truncate(10);
        let mut r = SnapReader::new(&bytes);
        assert!(r.str().unwrap_err().contains("truncated"));
    }
}
