//! Fabric partitioning for the partitioned event-domain engine.
//!
//! `Partition::compute` graph-cuts the fabric into up to `max_domains`
//! node sets, one per worker thread, under the constraints conservative
//! parallel simulation needs:
//!
//!  * **No shared link state across a cut.** Half-duplex links share one
//!    medium (`busy_until` of both directions plus the turnaround
//!    direction memory), so both endpoints must land in one domain;
//!    zero-latency links provide no lookahead at all. Both are contracted
//!    (union-find) before cutting, which guarantees `lookahead > 0`.
//!  * **Cut lookahead.** The engine's conservative barrier advances in
//!    windows of the minimum propagation latency over cut links — every
//!    cross-domain packet departs at `>= window start` and arrives
//!    `>= window start + lookahead`, i.e. never inside the current window.
//!  * **Balance + cheap cuts.** Contracted groups are grown around
//!    spread-out seeds (farthest-point in hop distance); the smallest
//!    region absorbs the frontier group it is most cohesive with, where
//!    cohesion weights links inversely to latency — low-latency links bind
//!    tightly (cutting them would shrink the lookahead window), long
//!    links are the natural cut points.
//!  * **Stable numbering.** Domains are renumbered by their minimum node
//!    id and node lists kept sorted, so the assignment is a pure function
//!    of the topology — the partitioned engine's determinism starts here.

use super::topology::{Duplex, LinkId, Topology};
use crate::engine::time::Ps;
use crate::proto::NodeId;
use std::collections::BTreeMap;

/// A computed fabric partition (see module docs).
#[derive(Clone, Debug)]
pub struct Partition {
    /// node -> domain index.
    pub domain_of: Vec<u32>,
    /// Domain -> sorted member node ids (every node in exactly one).
    pub domains: Vec<Vec<NodeId>>,
    /// Links whose endpoints live in different domains.
    pub cut_links: Vec<LinkId>,
    /// Minimum propagation latency over `cut_links` — the conservative
    /// barrier window. `Ps::MAX` when nothing is cut (single domain).
    pub lookahead: Ps,
}

/// Union-find with path halving.
struct Uf(Vec<usize>);

impl Uf {
    fn new(n: usize) -> Uf {
        Uf((0..n).collect())
    }
    fn find(&mut self, mut x: usize) -> usize {
        while self.0[x] != x {
            self.0[x] = self.0[self.0[x]];
            x = self.0[x];
        }
        x
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            // Deterministic: lower root wins.
            let (lo, hi) = (ra.min(rb), ra.max(rb));
            self.0[hi] = lo;
        }
    }
}

/// Link cohesion weight: how strongly a link binds its endpoint groups
/// together. Inverse in latency — cutting a low-latency link would force
/// a tiny barrier window, so the partitioner treats it as near-uncuttable;
/// long links are cheap cuts. Fixed-point to stay bit-deterministic.
fn cohesion(latency: Ps) -> u128 {
    (1u128 << 40) / (latency as u128 + 1)
}

impl Partition {
    /// Everything in one domain (the sequential fallback).
    pub fn single(topo: &Topology) -> Partition {
        Partition {
            domain_of: vec![0; topo.n()],
            domains: vec![(0..topo.n()).collect()],
            cut_links: Vec::new(),
            lookahead: Ps::MAX,
        }
    }

    /// Cut `topo` into at most `max_domains` event domains. Returns a
    /// single domain when the fabric cannot be split (everything
    /// contracted together, or `max_domains <= 1`).
    pub fn compute(topo: &Topology, max_domains: usize) -> Partition {
        let n = topo.n();
        if max_domains <= 1 || n <= 1 {
            return Partition::single(topo);
        }
        // 1. Contract un-cuttable links.
        let mut uf = Uf::new(n);
        for l in &topo.links {
            if l.cfg.latency == 0 || l.cfg.duplex == Duplex::Half {
                uf.union(l.a, l.b);
            }
        }
        // 2. Stable group list: groups ordered by their minimum node id.
        let mut members: BTreeMap<usize, Vec<NodeId>> = BTreeMap::new();
        for node in 0..n {
            let root = uf.find(node);
            members.entry(root).or_default().push(node);
        }
        let groups: Vec<Vec<NodeId>> = members.into_values().collect();
        let ng = groups.len();
        let ndom = max_domains.min(ng);
        if ndom <= 1 {
            return Partition::single(topo);
        }
        let mut group_of = vec![0usize; n];
        for (gi, g) in groups.iter().enumerate() {
            for &node in g {
                group_of[node] = gi;
            }
        }
        // 3. Quotient graph over groups: cohesion-weighted adjacency.
        let mut adj: Vec<BTreeMap<usize, u128>> = vec![BTreeMap::new(); ng];
        for l in &topo.links {
            let (ga, gb) = (group_of[l.a], group_of[l.b]);
            if ga != gb {
                let w = cohesion(l.cfg.latency);
                *adj[ga].entry(gb).or_insert(0) += w;
                *adj[gb].entry(ga).or_insert(0) += w;
            }
        }
        // 4. Seeds: farthest-point sampling in quotient hop distance,
        // starting from the heaviest group (ties: lowest id).
        let seed0 = (0..ng)
            .max_by_key(|&g| (groups[g].len(), usize::MAX - g))
            .expect("non-empty fabric");
        let mut seeds = vec![seed0];
        while seeds.len() < ndom {
            let dist = bfs_hops(&adj, &seeds);
            // Farthest reachable group not already a seed; unreachable
            // groups (disconnected fabrics) count as infinitely far.
            let next = (0..ng)
                .filter(|g| !seeds.contains(g))
                .max_by_key(|&g| (dist[g], usize::MAX - g));
            match next {
                Some(g) => seeds.push(g),
                None => break,
            }
        }
        // 5. Region growth: the lightest region absorbs the unassigned
        // frontier group it is most cohesive with.
        let mut dom_of_group: Vec<Option<u32>> = vec![None; ng];
        let mut weight = vec![0usize; seeds.len()];
        for (d, &s) in seeds.iter().enumerate() {
            dom_of_group[s] = Some(d as u32);
            weight[d] = groups[s].len();
        }
        let mut assigned = seeds.len();
        while assigned < ng {
            // Visit regions lightest-first (ties: lowest domain id).
            let mut order: Vec<usize> = (0..seeds.len()).collect();
            order.sort_by_key(|&d| (weight[d], d));
            let mut placed = false;
            for &d in &order {
                // Frontier: unassigned groups adjacent to region d with
                // their total cohesion toward it; pick the max (ties:
                // lowest group id).
                let mut cand: BTreeMap<usize, u128> = BTreeMap::new();
                for g in 0..ng {
                    if dom_of_group[g] != Some(d as u32) {
                        continue;
                    }
                    for (&nb, &w) in &adj[g] {
                        if dom_of_group[nb].is_none() {
                            *cand.entry(nb).or_insert(0) += w;
                        }
                    }
                }
                let best = cand
                    .iter()
                    .max_by_key(|&(&g, &w)| (w, usize::MAX - g))
                    .map(|(&g, _)| g);
                if let Some(g) = best {
                    dom_of_group[g] = Some(d as u32);
                    weight[d] += groups[g].len();
                    assigned += 1;
                    placed = true;
                    break;
                }
            }
            if !placed {
                // Disconnected remainder: hand the lowest-id unassigned
                // group to the lightest region.
                let g = (0..ng)
                    .find(|&g| dom_of_group[g].is_none())
                    .expect("unassigned group exists");
                let d = *order.first().expect("at least one region");
                dom_of_group[g] = Some(d as u32);
                weight[d] += groups[g].len();
                assigned += 1;
            }
        }
        // 6. Stable renumbering by minimum member node id.
        let mut domain_of = vec![0u32; n];
        for node in 0..n {
            domain_of[node] = dom_of_group[group_of[node]].expect("every group assigned");
        }
        let used = seeds.len();
        let mut min_node = vec![usize::MAX; used];
        for node in 0..n {
            let d = domain_of[node] as usize;
            min_node[d] = min_node[d].min(node);
        }
        let mut renum: Vec<usize> = (0..used).collect();
        renum.sort_by_key(|&d| min_node[d]);
        let mut new_id = vec![0u32; used];
        for (fresh, &old) in renum.iter().enumerate() {
            new_id[old] = fresh as u32;
        }
        let mut domains: Vec<Vec<NodeId>> = vec![Vec::new(); used];
        for node in 0..n {
            let d = new_id[domain_of[node] as usize];
            domain_of[node] = d;
            domains[d as usize].push(node); // ascending node order
        }
        // 7. Cut set + lookahead.
        let mut cut_links = Vec::new();
        let mut lookahead = Ps::MAX;
        for (id, l) in topo.links.iter().enumerate() {
            if domain_of[l.a] != domain_of[l.b] {
                debug_assert!(
                    l.cfg.latency > 0 && l.cfg.duplex == Duplex::Full,
                    "contraction must keep zero-latency/half-duplex links uncut"
                );
                lookahead = lookahead.min(l.cfg.latency);
                cut_links.push(id);
            }
        }
        if domains.len() <= 1 {
            return Partition::single(topo);
        }
        Partition {
            domain_of,
            domains,
            cut_links,
            lookahead,
        }
    }

    pub fn n_domains(&self) -> usize {
        self.domains.len()
    }
}

/// Multi-source BFS hop distances over the quotient graph (cohesion
/// ignored — seed spreading only needs topology distance).
fn bfs_hops(adj: &[BTreeMap<usize, u128>], sources: &[usize]) -> Vec<usize> {
    let mut dist = vec![usize::MAX; adj.len()];
    let mut q = std::collections::VecDeque::new();
    for &s in sources {
        dist[s] = 0;
        q.push_back(s);
    }
    while let Some(u) = q.pop_front() {
        for &v in adj[u].keys() {
            if dist[v] == usize::MAX {
                dist[v] = dist[u] + 1;
                q.push_back(v);
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::builders::{build, TopologyKind};
    use crate::interconnect::topology::{LinkCfg, NodeKind};

    fn check_partition(p: &Partition, topo: &Topology) {
        // Every node in exactly one domain, lists sorted + consistent.
        let mut seen = vec![false; topo.n()];
        for (d, nodes) in p.domains.iter().enumerate() {
            assert!(!nodes.is_empty(), "empty domain {d}");
            assert!(nodes.windows(2).all(|w| w[0] < w[1]), "unsorted domain");
            for &node in nodes {
                assert!(!seen[node], "node {node} assigned twice");
                seen[node] = true;
                assert_eq!(p.domain_of[node], d as u32);
            }
        }
        assert!(seen.iter().all(|&s| s), "node missing from all domains");
        // Cut set matches assignment; lookahead positive and minimal.
        let mut min_lat = Ps::MAX;
        for (id, l) in topo.links.iter().enumerate() {
            let cut = p.domain_of[l.a] != p.domain_of[l.b];
            assert_eq!(cut, p.cut_links.contains(&id));
            if cut {
                assert!(l.cfg.latency > 0, "cut zero-latency link {id}");
                assert_ne!(l.cfg.duplex, Duplex::Half, "cut half-duplex link {id}");
                min_lat = min_lat.min(l.cfg.latency);
            }
        }
        assert_eq!(p.lookahead, min_lat);
        if p.domains.len() > 1 {
            assert!(p.lookahead > 0);
        }
    }

    #[test]
    fn presets_partition_cleanly_at_every_domain_count() {
        for kind in TopologyKind::ALL {
            for n in [2, 4, 8, 16] {
                let f = build(kind, n, LinkCfg::default());
                for jobs in [1, 2, 3, 4, 8] {
                    let p = Partition::compute(&f.topo, jobs);
                    check_partition(&p, &f.topo);
                    assert!(p.n_domains() <= jobs.max(1));
                    if jobs > 1 && f.topo.n() >= 8 {
                        assert!(p.n_domains() > 1, "{} n={n} jobs={jobs} not split", kind.name());
                    }
                }
            }
        }
    }

    /// Non-tree fabric: a 4x4 switch mesh (grid with a wrap link = cycles
    /// galore) plus endpoints; the pass must still cover every node once
    /// and keep the cut lookahead positive.
    #[test]
    fn mesh_with_cycles_partitions() {
        let mut t = Topology::new();
        let mut sw = Vec::new();
        for i in 0..16 {
            sw.push(t.add_node(format!("s{i}"), NodeKind::Switch));
        }
        for r in 0..4 {
            for c in 0..4 {
                if c + 1 < 4 {
                    t.add_link(sw[r * 4 + c], sw[r * 4 + c + 1], LinkCfg::default());
                }
                if r + 1 < 4 {
                    t.add_link(sw[r * 4 + c], sw[(r + 1) * 4 + c], LinkCfg::default());
                }
            }
        }
        t.add_link(sw[0], sw[15], LinkCfg::default()); // wrap: non-planar-ish cycle
        for i in 0..8 {
            let r = t.add_node(format!("r{i}"), NodeKind::Requester);
            t.add_link(r, sw[i], LinkCfg::default());
            let m = t.add_node(format!("m{i}"), NodeKind::Memory);
            t.add_link(m, sw[15 - i], LinkCfg::default());
        }
        for jobs in [2, 4, 8] {
            let p = Partition::compute(&t, jobs);
            check_partition(&p, &t);
            assert!(p.n_domains() > 1);
            // Balance: no domain hoards more than ~3/4 of the fabric.
            let max = p.domains.iter().map(Vec::len).max().unwrap();
            assert!(max * 4 <= t.n() * 3, "degenerate balance: {max}/{}", t.n());
        }
    }

    #[test]
    fn half_duplex_and_zero_latency_links_are_never_cut() {
        // Chain a-b-c-d where a-b is half duplex and c-d has zero
        // latency: only the b-c link is cuttable.
        let mut t = Topology::new();
        let a = t.add_node("a", NodeKind::Requester);
        let b = t.add_node("b", NodeKind::Switch);
        let c = t.add_node("c", NodeKind::Switch);
        let d = t.add_node("d", NodeKind::Memory);
        let half = LinkCfg {
            duplex: Duplex::Half,
            ..LinkCfg::default()
        };
        let zero = LinkCfg {
            latency: 0,
            ..LinkCfg::default()
        };
        t.add_link(a, b, half);
        t.add_link(b, c, LinkCfg::default());
        t.add_link(c, d, zero);
        let p = Partition::compute(&t, 4);
        check_partition(&p, &t);
        assert_eq!(p.n_domains(), 2);
        assert_eq!(p.domain_of[a], p.domain_of[b]);
        assert_eq!(p.domain_of[c], p.domain_of[d]);
        assert_eq!(p.cut_links, vec![1]);
        assert_eq!(p.lookahead, t.links[1].cfg.latency);
    }

    #[test]
    fn fully_contracted_fabric_falls_back_to_single_domain() {
        let mut t = Topology::new();
        let a = t.add_node("a", NodeKind::Requester);
        let b = t.add_node("b", NodeKind::Memory);
        t.add_link(
            a,
            b,
            LinkCfg {
                duplex: Duplex::Half,
                ..LinkCfg::default()
            },
        );
        let p = Partition::compute(&t, 8);
        assert_eq!(p.n_domains(), 1);
        assert_eq!(p.lookahead, Ps::MAX);
        assert!(p.cut_links.is_empty());
    }

    #[test]
    fn stable_numbering_is_deterministic() {
        let f = build(TopologyKind::SpineLeaf, 16, LinkCfg::default());
        let a = Partition::compute(&f.topo, 4);
        let b = Partition::compute(&f.topo, 4);
        assert_eq!(a.domain_of, b.domain_of);
        assert_eq!(a.domains, b.domains);
        // Domain 0 owns the lowest node id, and numbering follows min ids.
        let mins: Vec<usize> = a.domains.iter().map(|d| d[0]).collect();
        assert!(mins.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(mins[0], 0);
    }
}
