//! Fabric partitioning for the partitioned event-domain engine.
//!
//! `Partition::compute_weighted` graph-cuts the fabric into up to
//! `max_domains` node sets, one per worker thread, under the constraints
//! conservative parallel simulation needs:
//!
//!  * **No shared link state across a cut.** Half-duplex links share one
//!    medium (`busy_until` of both directions plus the turnaround
//!    direction memory), so both endpoints must land in one domain;
//!    zero-latency links provide no lookahead at all. Both are contracted
//!    (union-find) before cutting, which guarantees `lookahead > 0`
//!    whenever anything is cut.
//!  * **Cut lookahead.** The engine's conservative barrier advances in
//!    windows of the minimum propagation latency over cut links — every
//!    cross-domain packet departs at `>= window start` and arrives
//!    `>= window start + lookahead`, i.e. never inside the current window.
//!    When nothing is cut (single domain, or a multi-domain partition of a
//!    fabric whose components are mutually disconnected) the lookahead is
//!    `Ps::MAX`: consumers must treat it as "unbounded window" and combine
//!    it with saturating arithmetic (`engine::parallel` saturates the
//!    window end), never add it raw.
//!  * **Balance + cheap cuts.** Contracted groups are grown around
//!    spread-out seeds (farthest-point in hop distance); the lightest
//!    region absorbs the frontier group it is most cohesive with, where
//!    cohesion weights links inversely to latency — low-latency links bind
//!    tightly (cutting them would shrink the lookahead window), long
//!    links are the natural cut points. Growth is capped at each domain's
//!    fair share (`total_weight / ndom`, rounded up): a region at its cap
//!    stops absorbing, and remainder groups that no under-cap region can
//!    reach flow to the lightest region even when that leaves the domain
//!    internally disconnected — correctness never needs connected
//!    domains, and hub-and-spoke fabrics (spine-leaf) cannot balance
//!    without this.
//!  * **Load model.** "Lightest" is measured by a pluggable per-node
//!    weight ([`WeightModel`]): the PR 4 node-count weighting (one unit
//!    per node) is kept as the A/B oracle, while the default traffic
//!    weighting estimates each node's event load from its port count and
//!    its routing fan-in ([`Routing::fanin_weights`]) — spine switches
//!    that forward most of the fabric's flows count for far more than
//!    leaf endpoints, so domains equalize *expected traffic* instead of
//!    node count and the barrier stops waiting on one overloaded
//!    spine-heavy domain. Both models are pure integer functions of the
//!    topology (+ routing tables), hence deterministic and seed-stable.
//!  * **Stable numbering.** Domains are renumbered by their minimum node
//!    id and node lists kept sorted, so the assignment is a pure function
//!    of the topology — the partitioned engine's determinism starts here.
//!  * **Two levels at scale.** The flat growth pass rescans the whole
//!    quotient frontier per absorption — O(groups²) once domains stop
//!    being the bottleneck — which blows up on 1k+ node fabrics. Past
//!    [`TWO_LEVEL_MIN_GROUPS`] contracted groups (with >= 4 requested
//!    domains) the pass goes hierarchical: cut the quotient graph into
//!    ~sqrt(domains) super-regions first (farthest-point seeds +
//!    nearest-seed BFS), apportion the domains across supers by weight
//!    (largest remainder, every super keeps at least one), then run the
//!    same seed-and-grow refinement inside each super's restricted
//!    sub-quotient. Small fabrics keep the flat pass bit-for-bit (the
//!    published 162-node domain shapes are pinned in `tests/`).

use super::routing::Routing;
use super::topology::{Duplex, LinkId, Topology};
use crate::engine::time::Ps;
use crate::proto::NodeId;
use std::collections::BTreeMap;

/// How region growth measures domain load (see module docs). The
/// fair-share growth cap applies under every model; the models differ
/// only in what a node weighs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightModel {
    /// One unit per node — PR 4's weighting rule, kept as the A/B oracle.
    NodeCount,
    /// Expected traffic per node from port count + routing fan-in; the
    /// partitioned engine's default.
    Traffic,
}

/// A computed fabric partition (see module docs).
#[derive(Clone, Debug)]
pub struct Partition {
    /// node -> domain index.
    pub domain_of: Vec<u32>,
    /// Domain -> sorted member node ids (every node in exactly one).
    pub domains: Vec<Vec<NodeId>>,
    /// Links whose endpoints live in different domains.
    pub cut_links: Vec<LinkId>,
    /// Minimum propagation latency over `cut_links` — the conservative
    /// barrier window. `Ps::MAX` when nothing is cut (single domain, or
    /// multiple mutually disconnected domains); always combine with
    /// saturating arithmetic.
    pub lookahead: Ps,
}

/// Contracted-group count at which `compute_model` switches from the
/// flat seed-and-grow pass to the two-level (hierarchical) pass. High
/// enough that every published small-fabric partition (162-node
/// spine-leaf included) keeps its exact flat-pass shape.
const TWO_LEVEL_MIN_GROUPS: usize = 256;

/// Union-find with path halving.
struct Uf(Vec<usize>);

impl Uf {
    fn new(n: usize) -> Uf {
        Uf((0..n).collect())
    }
    fn find(&mut self, mut x: usize) -> usize {
        while self.0[x] != x {
            self.0[x] = self.0[self.0[x]];
            x = self.0[x];
        }
        x
    }
    fn union(&mut self, a: usize, b: usize) {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra != rb {
            // Deterministic: lower root wins.
            let (lo, hi) = (ra.min(rb), ra.max(rb));
            self.0[hi] = lo;
        }
    }
}

/// Link cohesion weight: how strongly a link binds its endpoint groups
/// together. Inverse in latency — cutting a low-latency link would force
/// a tiny barrier window, so the partitioner treats it as near-uncuttable;
/// long links are cheap cuts. Fixed-point to stay bit-deterministic.
fn cohesion(latency: Ps) -> u128 {
    (1u128 << 40) / (latency as u128 + 1)
}

/// Per-node expected-traffic weights for [`WeightModel::Traffic`]: a base
/// endpoint share (every node sources/sinks some traffic), one quarter
/// share per attached port (local link activity), and the routing fan-in
/// estimate of forwarded load. All fixed-point integer arithmetic in
/// [`super::routing::FANIN_SCALE`] units — deterministic, seed-stable.
fn traffic_node_weights(topo: &Topology, routing: &Routing) -> Vec<u64> {
    use super::routing::FANIN_SCALE;
    let fanin = routing.fanin_weights();
    (0..topo.n())
        .map(|u| FANIN_SCALE + (topo.adj[u].len() as u64) * (FANIN_SCALE / 4) + fanin[u])
        .collect()
}

impl Partition {
    /// Everything in one domain (the sequential fallback).
    pub fn single(topo: &Topology) -> Partition {
        Partition {
            domain_of: vec![0; topo.n()],
            domains: vec![(0..topo.n()).collect()],
            cut_links: Vec::new(),
            lookahead: Ps::MAX,
        }
    }

    /// Cut `topo` into at most `max_domains` event domains under the
    /// node-count balance rule (one unit per node) — the A/B oracle for
    /// [`Partition::compute_weighted`]'s traffic weighting. Note the
    /// fair-share growth cap applies to every model, so this reproduces
    /// PR 4's *weighting rule*, not its exact (uncapped) domain shapes.
    pub fn compute(topo: &Topology, max_domains: usize) -> Partition {
        Self::compute_model(topo, None, max_domains)
    }

    /// Cut `topo` into at most `max_domains` event domains, balancing by
    /// `model`. [`WeightModel::Traffic`] needs the routing tables to
    /// estimate per-node load; [`WeightModel::NodeCount`] ignores them.
    /// Returns a single domain when the fabric cannot be split
    /// (everything contracted together, or `max_domains <= 1`).
    pub fn compute_weighted(
        topo: &Topology,
        routing: &Routing,
        max_domains: usize,
        model: WeightModel,
    ) -> Partition {
        match model {
            WeightModel::NodeCount => Self::compute_model(topo, None, max_domains),
            WeightModel::Traffic => {
                let w = traffic_node_weights(topo, routing);
                Self::compute_model(topo, Some(&w), max_domains)
            }
        }
    }

    /// Shared cut pass; `node_weight` is the per-node load estimate
    /// (`None` = one unit per node). The contraction, seeding, cohesion,
    /// and numbering logic is identical for every model — only the
    /// "lightest region" / "heaviest seed group" measure changes.
    fn compute_model(topo: &Topology, node_weight: Option<&[u64]>, max_domains: usize) -> Partition {
        let n = topo.n();
        if max_domains <= 1 || n <= 1 {
            return Partition::single(topo);
        }
        let w_of = |node: usize| node_weight.map_or(1u64, |w| w[node]);
        // 1. Contract un-cuttable links.
        let mut uf = Uf::new(n);
        for l in &topo.links {
            if l.cfg.latency == 0 || l.cfg.duplex == Duplex::Half {
                uf.union(l.a, l.b);
            }
        }
        // 2. Stable group list: groups ordered by their minimum node id.
        let mut members: BTreeMap<usize, Vec<NodeId>> = BTreeMap::new();
        for node in 0..n {
            let root = uf.find(node);
            members.entry(root).or_default().push(node);
        }
        let groups: Vec<Vec<NodeId>> = members.into_values().collect();
        let ng = groups.len();
        let ndom = max_domains.min(ng);
        if ndom <= 1 {
            return Partition::single(topo);
        }
        let mut group_of = vec![0usize; n];
        for (gi, g) in groups.iter().enumerate() {
            for &node in g {
                group_of[node] = gi;
            }
        }
        let group_weight: Vec<u64> = groups
            .iter()
            .map(|g| g.iter().map(|&node| w_of(node)).sum())
            .collect();
        // 3. Quotient graph over groups: cohesion-weighted adjacency.
        let mut adj: Vec<BTreeMap<usize, u128>> = vec![BTreeMap::new(); ng];
        for l in &topo.links {
            let (ga, gb) = (group_of[l.a], group_of[l.b]);
            if ga != gb {
                let w = cohesion(l.cfg.latency);
                *adj[ga].entry(gb).or_insert(0) += w;
                *adj[gb].entry(ga).or_insert(0) += w;
            }
        }
        // 4.+5. Seed-and-grow — flat for small quotients, two-level for
        // deep fabrics (see module docs; the hierarchy kicks in only
        // past TWO_LEVEL_MIN_GROUPS so small published shapes never
        // move).
        let (dom_of_group, used) = if ndom >= 4 && ng >= TWO_LEVEL_MIN_GROUPS {
            two_level(&adj, &group_weight, ndom)
        } else {
            seed_and_grow(&adj, &group_weight, ndom)
        };
        // 6. Stable renumbering by minimum member node id.
        let mut domain_of = vec![0u32; n];
        for node in 0..n {
            domain_of[node] = dom_of_group[group_of[node]];
        }
        let mut min_node = vec![usize::MAX; used];
        for node in 0..n {
            let d = domain_of[node] as usize;
            min_node[d] = min_node[d].min(node);
        }
        let mut renum: Vec<usize> = (0..used).collect();
        renum.sort_by_key(|&d| min_node[d]);
        let mut new_id = vec![0u32; used];
        for (fresh, &old) in renum.iter().enumerate() {
            new_id[old] = fresh as u32;
        }
        let mut domains: Vec<Vec<NodeId>> = vec![Vec::new(); used];
        for node in 0..n {
            let d = new_id[domain_of[node] as usize];
            domain_of[node] = d;
            domains[d as usize].push(node); // ascending node order
        }
        // 7. Cut set + lookahead. A multi-domain partition of mutually
        // disconnected components legitimately has an empty cut set — the
        // lookahead then stays Ps::MAX (unbounded windows; callers
        // saturate).
        let mut cut_links = Vec::new();
        let mut lookahead = Ps::MAX;
        for (id, l) in topo.links.iter().enumerate() {
            if domain_of[l.a] != domain_of[l.b] {
                debug_assert!(
                    l.cfg.latency > 0 && l.cfg.duplex == Duplex::Full,
                    "contraction must keep zero-latency/half-duplex links uncut"
                );
                lookahead = lookahead.min(l.cfg.latency);
                cut_links.push(id);
            }
        }
        if domains.len() <= 1 {
            return Partition::single(topo);
        }
        Partition {
            domain_of,
            domains,
            cut_links,
            lookahead,
        }
    }

    pub fn n_domains(&self) -> usize {
        self.domains.len()
    }

    /// Sorted, deduplicated neighbor-domain lists derived from the cut
    /// set: `peers[d]` holds every domain that shares at least one cut
    /// link with `d`. The sparse barrier exchange (`engine::parallel`)
    /// opens channels only between these pairs — cross-domain events can
    /// only be born from a `forward` over a cut link (intra-domain
    /// scheduling never leaves the domain, and contracted links never
    /// cross one), so two domains without a shared cut link can never
    /// exchange an event.
    pub fn exchange_peers(&self, topo: &Topology) -> Vec<Vec<usize>> {
        let mut peers: Vec<Vec<usize>> = vec![Vec::new(); self.n_domains()];
        for &l in &self.cut_links {
            let (da, db) = (
                self.domain_of[topo.links[l].a] as usize,
                self.domain_of[topo.links[l].b] as usize,
            );
            peers[da].push(db);
            peers[db].push(da);
        }
        for p in &mut peers {
            p.sort_unstable();
            p.dedup();
        }
        peers
    }

    /// Per-domain `(peer, minimum cut-link latency)` adjacency — the
    /// edge weights the adaptive barrier's horizon relaxation runs on
    /// (`engine::parallel`, `BarrierMode::Adaptive`): an event relayed
    /// from domain `p` into domain `d` arrives no earlier than `p`'s
    /// earliest activity plus this latency. Peer order matches
    /// [`Partition::exchange_peers`] (ascending domain id), and `esf
    /// check` rule ESF-C013 proves the graph mirrors the physical cut
    /// set exactly — a missing edge or an understated latency here
    /// would let a window widen past a real arrival.
    pub fn horizon_graph(&self, topo: &Topology) -> Vec<Vec<(usize, Ps)>> {
        let mut g: Vec<BTreeMap<usize, Ps>> = vec![BTreeMap::new(); self.n_domains()];
        for &l in &self.cut_links {
            let link = &topo.links[l];
            let (da, db) = (
                self.domain_of[link.a] as usize,
                self.domain_of[link.b] as usize,
            );
            let lat = link.cfg.latency;
            let ea = g[da].entry(db).or_insert(Ps::MAX);
            *ea = (*ea).min(lat);
            let eb = g[db].entry(da).or_insert(Ps::MAX);
            *eb = (*eb).min(lat);
        }
        g.into_iter().map(|m| m.into_iter().collect()).collect()
    }
}

/// Steps 4–5 of the cut pass: farthest-point seed selection followed by
/// capped lightest-first region growth, over an arbitrary
/// (sub-)quotient graph. Returns every group's region id plus the
/// number of regions used (less than `ndom` when the graph has fewer
/// groups).
///
/// The per-region weight cap (`total / ndom`, rounded up) makes a
/// region at or over its fair share stop absorbing, so the remainder
/// flows to lighter regions (possibly as disconnected members, via the
/// fallback below) instead of piling onto whichever region happens to
/// keep a live frontier. This is what lets hub-and-spoke fabrics
/// balance at all: on a spine-leaf cut, leaf regions are only connected
/// through the spines, so uncapped cohesion growth walls them in and
/// the two spine regions hoard the fabric (~[80, 76, 5, 1] of 162
/// nodes); capped, the same pass yields fair shares under either model.
fn seed_and_grow(
    adj: &[BTreeMap<usize, u128>],
    group_weight: &[u64],
    ndom: usize,
) -> (Vec<u32>, usize) {
    let ng = adj.len();
    let ndom = ndom.min(ng).max(1);
    let total_weight: u64 = group_weight.iter().sum();
    let cap = total_weight.div_ceil(ndom as u64);
    // 4. Seeds: farthest-point sampling in quotient hop distance,
    // starting from the heaviest group (ties: lowest id).
    let seed0 = (0..ng)
        .max_by_key(|&g| (group_weight[g], usize::MAX - g))
        .expect("non-empty fabric");
    let mut seeds = vec![seed0];
    while seeds.len() < ndom {
        let dist = bfs_hops(adj, &seeds);
        // Farthest reachable group not already a seed; unreachable
        // groups (disconnected fabrics) count as infinitely far.
        let next = (0..ng)
            .filter(|g| !seeds.contains(g))
            .max_by_key(|&g| (dist[g], usize::MAX - g));
        match next {
            Some(g) => seeds.push(g),
            None => break,
        }
    }
    // 5. Region growth: the lightest region absorbs the unassigned
    // frontier group it is most cohesive with.
    let mut dom_of_group: Vec<Option<u32>> = vec![None; ng];
    let mut weight = vec![0u64; seeds.len()];
    for (d, &s) in seeds.iter().enumerate() {
        dom_of_group[s] = Some(d as u32);
        weight[d] = group_weight[s];
    }
    let mut assigned = seeds.len();
    while assigned < ng {
        // Visit regions lightest-first (ties: lowest domain id).
        let mut order: Vec<usize> = (0..seeds.len()).collect();
        order.sort_by_key(|&d| (weight[d], d));
        let mut placed = false;
        for &d in &order {
            if weight[d] >= cap {
                continue; // fair share reached: leave the rest to others
            }
            // Frontier: unassigned groups adjacent to region d with
            // their total cohesion toward it; pick the max (ties:
            // lowest group id).
            let mut cand: BTreeMap<usize, u128> = BTreeMap::new();
            for g in 0..ng {
                if dom_of_group[g] != Some(d as u32) {
                    continue;
                }
                for (&nb, &w) in &adj[g] {
                    if dom_of_group[nb].is_none() {
                        *cand.entry(nb).or_insert(0) += w;
                    }
                }
            }
            let best = cand
                .iter()
                .max_by_key(|&(&g, &w)| (w, usize::MAX - g))
                .map(|(&g, _)| g);
            if let Some(g) = best {
                dom_of_group[g] = Some(d as u32);
                weight[d] += group_weight[g];
                assigned += 1;
                placed = true;
                break;
            }
        }
        if !placed {
            // Every under-cap region has an empty frontier (the
            // unassigned remainder is disconnected from them, or
            // reachable only through capped regions): hand the
            // lowest-id unassigned group to the lightest region.
            // Computed explicitly instead of reusing `order.first()`
            // — equivalent today (weights cannot change between the
            // sort and a fallback that only fires when nothing was
            // placed; the minimum is always under-cap while groups
            // remain), but stated directly so the pick can never
            // silently inherit staleness from a future growth change
            // that assigns more than one group per sort (pinned by
            // the `disconnected_*` determinism tests).
            let g = (0..ng)
                .find(|&g| dom_of_group[g].is_none())
                .expect("unassigned group exists");
            let d = (0..seeds.len())
                .min_by_key(|&d| (weight[d], d))
                .expect("at least one region");
            dom_of_group[g] = Some(d as u32);
            weight[d] += group_weight[g];
            assigned += 1;
        }
    }
    let used = seeds.len();
    (
        dom_of_group
            .into_iter()
            .map(|d| d.expect("every group assigned"))
            .collect(),
        used,
    )
}

/// Two-level cut for deep fabrics (see module docs): super-regions via
/// farthest-point seeds + nearest-seed BFS, domain apportionment by
/// largest remainder, then flat [`seed_and_grow`] refinement inside
/// each super's restricted sub-quotient. Pure integer function of the
/// quotient graph — exactly as deterministic as the flat pass.
fn two_level(
    adj: &[BTreeMap<usize, u128>],
    group_weight: &[u64],
    ndom: usize,
) -> (Vec<u32>, usize) {
    let ng = adj.len();
    debug_assert!(ndom >= 4 && ng >= ndom);
    // ceil(sqrt(ndom)) super-regions, at least 2.
    let mut s = 1usize;
    while s * s < ndom {
        s += 1;
    }
    let n_super = s.max(2);
    // Super seeds: the flat pass's farthest-point rule.
    let seed0 = (0..ng)
        .max_by_key(|&g| (group_weight[g], usize::MAX - g))
        .expect("non-empty fabric");
    let mut seeds = vec![seed0];
    while seeds.len() < n_super {
        let dist = bfs_hops(adj, &seeds);
        let next = (0..ng)
            .filter(|g| !seeds.contains(g))
            .max_by_key(|&g| (dist[g], usize::MAX - g));
        match next {
            Some(g) => seeds.push(g),
            None => break,
        }
    }
    let n_super = seeds.len();
    // Nearest-seed multi-source BFS over the quotient graph. FIFO order
    // with seeds pushed in index order and ascending-key neighbor
    // iteration makes the equal-distance tie-break (lowest seed wins)
    // deterministic.
    let mut super_of: Vec<Option<u32>> = vec![None; ng];
    let mut q = std::collections::VecDeque::new();
    for (i, &sg) in seeds.iter().enumerate() {
        super_of[sg] = Some(i as u32);
        q.push_back(sg);
    }
    while let Some(u) = q.pop_front() {
        for &v in adj[u].keys() {
            if super_of[v].is_none() {
                super_of[v] = super_of[u];
                q.push_back(v);
            }
        }
    }
    let mut super_weight = vec![0u64; n_super];
    for g in 0..ng {
        if let Some(sp) = super_of[g] {
            super_weight[sp as usize] += group_weight[g];
        }
    }
    // Unreachable groups (disconnected fabrics): lightest super wins, in
    // ascending group order — the flat pass's fallback rule, one level up.
    for g in 0..ng {
        if super_of[g].is_none() {
            let sp = (0..n_super)
                .min_by_key(|&i| (super_weight[i], i))
                .expect("at least one super-region");
            super_of[g] = Some(sp as u32);
            super_weight[sp] += group_weight[g];
        }
    }
    // Member groups per super, ascending group id.
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_super];
    for g in 0..ng {
        members[super_of[g].expect("every group placed") as usize].push(g);
    }
    // Apportion the ndom domains: one guaranteed per super, the spare
    // by largest remainder on super weight (ties: lowest super id).
    let mut alloc = vec![1usize; n_super];
    let spare = ndom - n_super; // n_super = ceil(sqrt(ndom)) <= ndom for ndom >= 4
    let total: u64 = super_weight.iter().sum();
    if spare > 0 && total > 0 {
        let mut given = 0usize;
        let mut remainder: Vec<(u64, usize)> = Vec::with_capacity(n_super);
        for i in 0..n_super {
            let exact = spare as u128 * super_weight[i] as u128;
            let share = (exact / total as u128) as usize;
            alloc[i] += share;
            given += share;
            remainder.push(((exact % total as u128) as u64, i));
        }
        remainder.sort_by_key(|&(r, i)| (u64::MAX - r, i));
        for &(_, i) in remainder.iter().take(spare - given) {
            alloc[i] += 1;
        }
    }
    // A super cannot host more domains than it has groups; push the
    // excess to the supers with spare capacity, heaviest-per-domain
    // first (ties: lowest super id).
    loop {
        let Some(over) = (0..n_super).find(|&i| alloc[i] > members[i].len()) else {
            break;
        };
        let mut excess = alloc[over] - members[over].len();
        alloc[over] = members[over].len();
        while excess > 0 {
            let Some(under) = (0..n_super)
                .filter(|&i| alloc[i] < members[i].len())
                .max_by_key(|&i| (super_weight[i] / alloc[i] as u64, usize::MAX - i))
            else {
                break;
            };
            alloc[under] += 1;
            excess -= 1;
        }
        debug_assert_eq!(excess, 0, "total group capacity covers ndom");
    }
    // Refine each super over its restricted sub-quotient (local group
    // indices; cross-super cohesion is simply dropped — those edges are
    // already super-level cuts).
    let mut dom_of_group = vec![0u32; ng];
    let mut used = 0usize;
    let mut local_of = vec![usize::MAX; ng];
    for (i, m) in members.iter().enumerate() {
        debug_assert!(!m.is_empty(), "every super contains its seed");
        for (li, &g) in m.iter().enumerate() {
            local_of[g] = li;
        }
        let sub_adj: Vec<BTreeMap<usize, u128>> = m
            .iter()
            .map(|&g| {
                adj[g]
                    .iter()
                    .filter(|&(&nb, _)| local_of[nb] != usize::MAX && super_of[nb] == Some(i as u32))
                    .map(|(&nb, &w)| (local_of[nb], w))
                    .collect()
            })
            .collect();
        let sub_w: Vec<u64> = m.iter().map(|&g| group_weight[g]).collect();
        let (sub_dom, sub_used) = seed_and_grow(&sub_adj, &sub_w, alloc[i]);
        for (li, &g) in m.iter().enumerate() {
            dom_of_group[g] = used as u32 + sub_dom[li];
        }
        used += sub_used;
        for &g in m {
            local_of[g] = usize::MAX; // reset the scratch for the next super
        }
    }
    (dom_of_group, used)
}

/// Multi-source BFS hop distances over the quotient graph (cohesion
/// ignored — seed spreading only needs topology distance).
fn bfs_hops(adj: &[BTreeMap<usize, u128>], sources: &[usize]) -> Vec<usize> {
    let mut dist = vec![usize::MAX; adj.len()];
    let mut q = std::collections::VecDeque::new();
    for &s in sources {
        dist[s] = 0;
        q.push_back(s);
    }
    while let Some(u) = q.pop_front() {
        for &v in adj[u].keys() {
            if dist[v] == usize::MAX {
                dist[v] = dist[u] + 1;
                q.push_back(v);
            }
        }
    }
    dist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::builders::{build, TopologyKind};
    use crate::interconnect::topology::{LinkCfg, NodeKind};

    fn check_partition(p: &Partition, topo: &Topology) {
        // Every node in exactly one domain, lists sorted + consistent.
        let mut seen = vec![false; topo.n()];
        for (d, nodes) in p.domains.iter().enumerate() {
            assert!(!nodes.is_empty(), "empty domain {d}");
            assert!(nodes.windows(2).all(|w| w[0] < w[1]), "unsorted domain");
            for &node in nodes {
                assert!(!seen[node], "node {node} assigned twice");
                seen[node] = true;
                assert_eq!(p.domain_of[node], d as u32);
            }
        }
        assert!(seen.iter().all(|&s| s), "node missing from all domains");
        // Cut set matches assignment; lookahead positive and minimal.
        let mut min_lat = Ps::MAX;
        for (id, l) in topo.links.iter().enumerate() {
            let cut = p.domain_of[l.a] != p.domain_of[l.b];
            assert_eq!(cut, p.cut_links.contains(&id));
            if cut {
                assert!(l.cfg.latency > 0, "cut zero-latency link {id}");
                assert_ne!(l.cfg.duplex, Duplex::Half, "cut half-duplex link {id}");
                min_lat = min_lat.min(l.cfg.latency);
            }
        }
        assert_eq!(p.lookahead, min_lat);
        if p.domains.len() > 1 {
            assert!(p.lookahead > 0);
        }
        // Exchange peers mirror the cut set exactly, sorted + symmetric.
        let peers = p.exchange_peers(topo);
        for (d, ps) in peers.iter().enumerate() {
            assert!(ps.windows(2).all(|w| w[0] < w[1]), "peers unsorted/dup");
            for &q in ps {
                assert_ne!(q, d, "domain peered with itself");
                assert!(peers[q].contains(&d), "peer relation not symmetric");
            }
        }
        for &l in &p.cut_links {
            let (da, db) = (
                p.domain_of[topo.links[l].a] as usize,
                p.domain_of[topo.links[l].b] as usize,
            );
            assert!(peers[da].contains(&db));
        }
    }

    /// Both weight models must satisfy every partition invariant.
    fn check_both_models(topo: &Topology, jobs: usize) -> (Partition, Partition) {
        let routing = Routing::build_bfs(topo);
        let nc = Partition::compute_weighted(topo, &routing, jobs, WeightModel::NodeCount);
        let tr = Partition::compute_weighted(topo, &routing, jobs, WeightModel::Traffic);
        check_partition(&nc, topo);
        check_partition(&tr, topo);
        // The `compute` shortcut must stay in sync with the NodeCount
        // model of the weighted entry point (public-API contract; both
        // share `compute_model`, so this pins the wiring, not the
        // algorithm).
        let legacy = Partition::compute(topo, jobs);
        assert_eq!(legacy.domain_of, nc.domain_of);
        assert_eq!(legacy.cut_links, nc.cut_links);
        (nc, tr)
    }

    #[test]
    fn presets_partition_cleanly_at_every_domain_count() {
        for kind in TopologyKind::ALL {
            for n in [2, 4, 8, 16] {
                let f = build(kind, n, LinkCfg::default());
                for jobs in [1, 2, 3, 4, 8] {
                    let (nc, tr) = check_both_models(&f.topo, jobs);
                    for p in [&nc, &tr] {
                        assert!(p.n_domains() <= jobs.max(1));
                        if jobs > 1 && f.topo.n() >= 8 {
                            assert!(p.n_domains() > 1, "{} n={n} jobs={jobs} not split", kind.name());
                        }
                    }
                }
            }
        }
    }

    /// Non-tree fabric: a 4x4 switch mesh (grid with a wrap link = cycles
    /// galore) plus endpoints; the pass must still cover every node once
    /// and keep the cut lookahead positive.
    #[test]
    fn mesh_with_cycles_partitions() {
        let mut t = Topology::new();
        let mut sw = Vec::new();
        for i in 0..16 {
            sw.push(t.add_node(format!("s{i}"), NodeKind::Switch));
        }
        for r in 0..4 {
            for c in 0..4 {
                if c + 1 < 4 {
                    t.add_link(sw[r * 4 + c], sw[r * 4 + c + 1], LinkCfg::default());
                }
                if r + 1 < 4 {
                    t.add_link(sw[r * 4 + c], sw[(r + 1) * 4 + c], LinkCfg::default());
                }
            }
        }
        t.add_link(sw[0], sw[15], LinkCfg::default()); // wrap: non-planar-ish cycle
        for i in 0..8 {
            let r = t.add_node(format!("r{i}"), NodeKind::Requester);
            t.add_link(r, sw[i], LinkCfg::default());
            let m = t.add_node(format!("m{i}"), NodeKind::Memory);
            t.add_link(m, sw[15 - i], LinkCfg::default());
        }
        for jobs in [2, 4, 8] {
            let (nc, tr) = check_both_models(&t, jobs);
            for p in [&nc, &tr] {
                assert!(p.n_domains() > 1);
                // Balance: no domain hoards more than ~3/4 of the fabric.
                let max = p.domains.iter().map(Vec::len).max().unwrap();
                assert!(max * 4 <= t.n() * 3, "degenerate balance: {max}/{}", t.n());
            }
        }
    }

    /// The traffic model's entire point: on a spine-leaf fabric the
    /// switches concentrate routed flows, so the domains holding them
    /// must end up with *fewer* nodes than under node-count balance
    /// (their weight budget is eaten by the switches), while expected
    /// traffic spreads evenly.
    #[test]
    fn traffic_weighting_unloads_spine_domains() {
        let f = build(TopologyKind::SpineLeaf, 16, LinkCfg::default());
        let routing = Routing::build_bfs(&f.topo);
        let w = traffic_node_weights(&f.topo, &routing);
        // Every transit switch (spines AND leaves) must dwarf every
        // endpoint — that is what shifts the balance away from raw node
        // counts. (Whether spines or leaves weigh more flips with scale;
        // both are far above endpoints at any scale.)
        let switch_min: u64 = f.switches.iter().map(|&s| w[s]).min().unwrap();
        for &node in f.requesters.iter().chain(&f.memories) {
            assert!(
                w[node] * 10 < switch_min,
                "endpoint {node} not dwarfed by switches"
            );
        }
        let tr = Partition::compute_weighted(&f.topo, &routing, 4, WeightModel::Traffic);
        check_partition(&tr, &f.topo);
        assert!(tr.n_domains() > 1);
        // Per-domain traffic weight under the model: the heaviest domain
        // carries less than 2x the lightest (node-count balance leaves
        // spine domains far above that on this fabric's weight profile).
        let dom_w: Vec<u64> = tr
            .domains
            .iter()
            .map(|d| d.iter().map(|&n| w[n]).sum())
            .collect();
        let (lo, hi) = (
            *dom_w.iter().min().unwrap(),
            *dom_w.iter().max().unwrap(),
        );
        assert!(hi < 2 * lo, "traffic balance degenerate: {dom_w:?}");
    }

    #[test]
    fn half_duplex_and_zero_latency_links_are_never_cut() {
        // Chain a-b-c-d where a-b is half duplex and c-d has zero
        // latency: only the b-c link is cuttable.
        let mut t = Topology::new();
        let a = t.add_node("a", NodeKind::Requester);
        let b = t.add_node("b", NodeKind::Switch);
        let c = t.add_node("c", NodeKind::Switch);
        let d = t.add_node("d", NodeKind::Memory);
        let half = LinkCfg {
            duplex: Duplex::Half,
            ..LinkCfg::default()
        };
        let zero = LinkCfg {
            latency: 0,
            ..LinkCfg::default()
        };
        t.add_link(a, b, half);
        t.add_link(b, c, LinkCfg::default());
        t.add_link(c, d, zero);
        let (p, tr) = check_both_models(&t, 4);
        for p in [&p, &tr] {
            assert_eq!(p.n_domains(), 2);
            assert_eq!(p.domain_of[a], p.domain_of[b]);
            assert_eq!(p.domain_of[c], p.domain_of[d]);
            assert_eq!(p.cut_links, vec![1]);
            assert_eq!(p.lookahead, t.links[1].cfg.latency);
            assert_eq!(p.exchange_peers(&t), vec![vec![1], vec![0]]);
        }
    }

    #[test]
    fn fully_contracted_fabric_falls_back_to_single_domain() {
        let mut t = Topology::new();
        let a = t.add_node("a", NodeKind::Requester);
        let b = t.add_node("b", NodeKind::Memory);
        t.add_link(
            a,
            b,
            LinkCfg {
                duplex: Duplex::Half,
                ..LinkCfg::default()
            },
        );
        let p = Partition::compute(&t, 8);
        assert_eq!(p.n_domains(), 1);
        assert_eq!(p.lookahead, Ps::MAX);
        assert!(p.cut_links.is_empty());
    }

    /// Disconnected fabric: components with no links between them split
    /// into multiple domains with an EMPTY cut set — the lookahead must
    /// stay `Ps::MAX` (unbounded windows, saturating consumers) and the
    /// exchange peer lists must all be empty. Regression for the
    /// `tmin + lookahead` overflow hazard.
    #[test]
    fn disconnected_components_cut_nothing_and_keep_max_lookahead() {
        let mut t = Topology::new();
        for comp in 0..3 {
            let r = t.add_node(format!("r{comp}"), NodeKind::Requester);
            let s = t.add_node(format!("s{comp}"), NodeKind::Switch);
            let m = t.add_node(format!("m{comp}"), NodeKind::Memory);
            t.add_link(r, s, LinkCfg::default());
            t.add_link(s, m, LinkCfg::default());
        }
        // One domain per component: nothing can be cut, and the
        // lookahead legitimately stays unbounded.
        {
            let (nc, tr) = check_both_models(&t, 3);
            for p in [&nc, &tr] {
                assert!(p.n_domains() > 1, "disconnected fabric must split");
                assert!(p.cut_links.is_empty(), "components share no links");
                assert_eq!(p.lookahead, Ps::MAX);
                assert!(p.lookahead.checked_add(1).is_none(), "MAX must saturate");
                assert!(p.exchange_peers(&t).iter().all(Vec::is_empty));
            }
        }
        // Domain counts that don't divide the components (2) or exceed
        // them (8) may cut inside a component to hold the balance cap —
        // every invariant (positive lookahead when cut, symmetric peer
        // lists) must still hold.
        for jobs in [2, 8] {
            let (nc, tr) = check_both_models(&t, jobs);
            for p in [&nc, &tr] {
                assert!(p.n_domains() > 1);
                if !p.cut_links.is_empty() {
                    assert!(p.lookahead > 0 && p.lookahead < Ps::MAX);
                } else {
                    assert_eq!(p.lookahead, Ps::MAX);
                }
            }
        }
        // At jobs=3 each component is its own domain and weights balance.
        let p = Partition::compute(&t, 3);
        assert_eq!(p.n_domains(), 3);
        assert!(p.domains.iter().all(|d| d.len() == 3));
    }

    /// Determinism of the disconnected-remainder fallback: many isolated
    /// components force repeated fallback assignments; the result must be
    /// stable across runs and spread components over the lightest regions
    /// (never piling everything onto one domain).
    #[test]
    fn disconnected_remainder_fallback_is_deterministic_and_spread() {
        // One connected 4-node chain + 6 isolated 2-node islands of
        // varying latency (weight variety for the traffic model).
        let build_fabric = || {
            let mut t = Topology::new();
            let mut prev = t.add_node("c0", NodeKind::Switch);
            for i in 1..4 {
                let s = t.add_node(format!("c{i}"), NodeKind::Switch);
                t.add_link(prev, s, LinkCfg::default());
                prev = s;
            }
            for i in 0..6 {
                let a = t.add_node(format!("a{i}"), NodeKind::Requester);
                let b = t.add_node(format!("b{i}"), NodeKind::Memory);
                let cfg = LinkCfg {
                    latency: crate::engine::time::ns(1.0 + i as f64),
                    ..LinkCfg::default()
                };
                t.add_link(a, b, cfg);
            }
            t
        };
        let t = build_fabric();
        for jobs in [2, 3, 4] {
            let (nc, tr) = check_both_models(&t, jobs);
            for p in [&nc, &tr] {
                // Node-count spread: islands must not all land in one
                // domain (the chain seeds one region; islands fall back
                // round-robin-by-lightest across all of them).
                let max = p.domains.iter().map(Vec::len).max().unwrap();
                assert!(
                    max <= t.n() - 2 * (jobs - 1),
                    "jobs={jobs}: fallback hoarded {max}/{} nodes",
                    t.n()
                );
            }
            // Byte-stable across a rebuild + recompute.
            let t2 = build_fabric();
            let nc2 = Partition::compute(&t2, jobs);
            assert_eq!(nc.domain_of, nc2.domain_of);
            assert_eq!(nc.domains, nc2.domains);
        }
    }

    /// The horizon graph must mirror `exchange_peers` exactly (same
    /// peers, same order) and carry, per pair, the minimum latency over
    /// the cut links joining them — understating it would let the
    /// adaptive barrier widen past a real arrival, overstating it would
    /// stall progress.
    #[test]
    fn horizon_graph_mirrors_exchange_peers_with_min_cut_latencies() {
        for kind in TopologyKind::ALL {
            let f = build(kind, 16, LinkCfg::default());
            let routing = Routing::build_bfs(&f.topo);
            for jobs in [2, 4, 8] {
                let p = Partition::compute_weighted(&f.topo, &routing, jobs, WeightModel::Traffic);
                let peers = p.exchange_peers(&f.topo);
                let hg = p.horizon_graph(&f.topo);
                assert_eq!(hg.len(), p.n_domains());
                for (d, edges) in hg.iter().enumerate() {
                    let ids: Vec<usize> = edges.iter().map(|&(q, _)| q).collect();
                    assert_eq!(ids, peers[d], "{} jobs={jobs} dom={d}", kind.name());
                    for &(q, lat) in edges {
                        // Recompute the pair minimum from the raw cut set.
                        let expect = p
                            .cut_links
                            .iter()
                            .map(|&l| &f.topo.links[l])
                            .filter(|l| {
                                let (a, b) =
                                    (p.domain_of[l.a] as usize, p.domain_of[l.b] as usize);
                                (a, b) == (d, q) || (a, b) == (q, d)
                            })
                            .map(|l| l.cfg.latency)
                            .min()
                            .expect("peer implies a cut link");
                        assert_eq!(lat, expect);
                        assert!(lat > 0, "zero-latency links are never cut");
                    }
                }
            }
        }
    }

    /// 1k-node spine-leaf: past TWO_LEVEL_MIN_GROUPS groups the pass
    /// goes hierarchical — every partition invariant must still hold,
    /// the requested domain count must materialize, balance must stay
    /// sane, and the result must be byte-stable across recomputation.
    #[test]
    fn two_level_partitions_thousand_node_spine_leaf() {
        let f = build(TopologyKind::SpineLeaf, 400, LinkCfg::default());
        assert!(f.topo.n() > 1000, "scale check: got {}", f.topo.n());
        let routing = Routing::build_bfs(&f.topo);
        for jobs in [4, 8, 16] {
            let (nc, tr) = check_both_models(&f.topo, jobs);
            for p in [&nc, &tr] {
                assert_eq!(p.n_domains(), jobs, "two-level lost domains");
                // No domain hoards: at most 2x the node-count fair share.
                let max = p.domains.iter().map(Vec::len).max().unwrap();
                assert!(
                    max <= 2 * f.topo.n().div_ceil(jobs),
                    "jobs={jobs}: degenerate balance, max domain {max}"
                );
            }
            let again = Partition::compute_weighted(&f.topo, &routing, jobs, WeightModel::Traffic);
            assert_eq!(tr.domain_of, again.domain_of);
            assert_eq!(tr.domains, again.domains);
        }
        // Below the gate (ndom < 4) the flat pass still runs at this
        // scale and must satisfy the same invariants.
        let (nc2, _) = check_both_models(&f.topo, 2);
        assert_eq!(nc2.n_domains(), 2);
    }

    #[test]
    fn stable_numbering_is_deterministic() {
        let f = build(TopologyKind::SpineLeaf, 16, LinkCfg::default());
        let routing = Routing::build_bfs(&f.topo);
        for model in [WeightModel::NodeCount, WeightModel::Traffic] {
            let a = Partition::compute_weighted(&f.topo, &routing, 4, model);
            let b = Partition::compute_weighted(&f.topo, &routing, 4, model);
            assert_eq!(a.domain_of, b.domain_of);
            assert_eq!(a.domains, b.domains);
            // Domain 0 owns the lowest node id, and numbering follows min ids.
            let mins: Vec<usize> = a.domains.iter().map(|d| d[0]).collect();
            assert!(mins.windows(2).all(|w| w[0] < w[1]));
            assert_eq!(mins[0], 0);
        }
    }
}
