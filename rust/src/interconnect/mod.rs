//! The interconnect layer (paper §III-A): topology graph construction,
//! shortest-path routing information, link (bus) state, and the preset
//! system topologies used by the evaluation.

pub mod builders;
pub mod links;
pub mod partition;
pub mod routing;
pub mod topology;

pub use builders::{build, Fabric, TopologyKind};
pub use links::{Dir, NetState, Xmit};
pub use partition::{Partition, WeightModel};
pub use routing::{dir_of, Routing, Strategy, FANIN_SCALE, UNREACHABLE};
pub use topology::{Duplex, Link, LinkCfg, LinkId, NodeInfo, NodeKind, Topology};
