//! Topology graph of the interconnect layer.
//!
//! The interconnect layer receives a set of device pairs configured as
//! directly connected through physical links (paper §III-A), builds the
//! adjacency structure, and later provides routing information to all
//! devices. Nodes are devices (requesters, PBR switches, memory endpoints);
//! edges are PCIe/CXL buses with their own bandwidth/duplex/latency
//! configuration (modelled in `links.rs`).

use crate::engine::time::{ns, Ps};
use crate::proto::NodeId;

pub type LinkId = usize;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Computational component: host or accelerator (issues requests).
    Requester,
    /// PBR-capable CXL switch.
    Switch,
    /// Memory endpoint (type-3 device by default).
    Memory,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Duplex {
    /// Independent bandwidth per direction (PCIe characteristic).
    Full,
    /// One direction at a time, with a turnaround penalty on reversal.
    Half,
}

/// Per-link (bus) physical configuration.
#[derive(Clone, Copy, Debug)]
pub struct LinkCfg {
    /// Per-direction bandwidth in GB/s. `0.0` means infinite (no
    /// serialization delay) — used by experiments isolating other effects.
    pub bandwidth_gbps: f64,
    /// Propagation latency (paper Table III "bus time", 1 ns default).
    pub latency: Ps,
    pub duplex: Duplex,
    /// Half-duplex turnaround overhead applied on direction reversal.
    pub turnaround: Ps,
    /// Link-layer + physical header bytes prepended to every message
    /// (Fig 16/17 sweeps this as a fraction of the 64B payload).
    pub header_bytes: u64,
}

impl Default for LinkCfg {
    fn default() -> Self {
        LinkCfg {
            bandwidth_gbps: 64.0, // PCIe 6.0 x16-class per direction
            latency: ns(1.0),
            duplex: Duplex::Full,
            turnaround: 0,
            header_bytes: 16,
        }
    }
}

#[derive(Clone, Debug)]
pub struct NodeInfo {
    pub name: String,
    pub kind: NodeKind,
}

#[derive(Clone, Debug)]
pub struct Link {
    pub a: NodeId,
    pub b: NodeId,
    pub cfg: LinkCfg,
}

#[derive(Clone, Debug, Default)]
pub struct Topology {
    pub nodes: Vec<NodeInfo>,
    pub links: Vec<Link>,
    /// adjacency: node -> [(neighbor, link)]
    pub adj: Vec<Vec<(NodeId, LinkId)>>,
}

impl Topology {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add_node(&mut self, name: impl Into<String>, kind: NodeKind) -> NodeId {
        let id = self.nodes.len();
        self.nodes.push(NodeInfo {
            name: name.into(),
            kind,
        });
        self.adj.push(Vec::new());
        id
    }

    /// Connect a device pair through a physical link.
    pub fn add_link(&mut self, a: NodeId, b: NodeId, cfg: LinkCfg) -> LinkId {
        assert!(a != b, "self-links not allowed");
        assert!(a < self.nodes.len() && b < self.nodes.len());
        let id = self.links.len();
        self.links.push(Link { a, b, cfg });
        self.adj[a].push((b, id));
        self.adj[b].push((a, id));
        id
    }

    pub fn n(&self) -> usize {
        self.nodes.len()
    }

    pub fn kind(&self, n: NodeId) -> NodeKind {
        self.nodes[n].kind
    }

    pub fn link_between(&self, a: NodeId, b: NodeId) -> Option<LinkId> {
        self.adj[a].iter().find(|(nb, _)| *nb == b).map(|(_, l)| *l)
    }

    pub fn nodes_of_kind(&self, kind: NodeKind) -> Vec<NodeId> {
        (0..self.n()).filter(|&i| self.kind(i) == kind).collect()
    }

    /// Hop-count adjacency matrix in the AOT APSP interchange format:
    /// 0 diagonal, 1.0 per link, `unreach` for absent edges.
    pub fn adjacency_matrix(&self, unreach: f32) -> Vec<f32> {
        let n = self.n();
        let mut m = vec![unreach; n * n];
        for i in 0..n {
            m[i * n + i] = 0.0;
        }
        for l in &self.links {
            m[l.a * n + l.b] = 1.0;
            m[l.b * n + l.a] = 1.0;
        }
        m
    }

    /// Bisection bandwidth estimate: minimum over "natural" cuts of the sum
    /// of link bandwidths crossing the cut. For the preset topologies we
    /// use the requester/memory segregation cut, which is the bottleneck
    /// the paper's iso-bisection experiment (Fig 12) normalizes away.
    pub fn cut_bandwidth(&self, left: &[NodeId]) -> f64 {
        let mut in_left = vec![false; self.n()];
        for &n in left {
            in_left[n] = true;
        }
        self.links
            .iter()
            .filter(|l| in_left[l.a] != in_left[l.b])
            .map(|l| l.cfg.bandwidth_gbps)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tri() -> Topology {
        let mut t = Topology::new();
        let a = t.add_node("r0", NodeKind::Requester);
        let s = t.add_node("s0", NodeKind::Switch);
        let m = t.add_node("m0", NodeKind::Memory);
        t.add_link(a, s, LinkCfg::default());
        t.add_link(s, m, LinkCfg::default());
        t
    }

    #[test]
    fn adjacency_is_symmetric() {
        let t = tri();
        assert_eq!(t.adj[0], vec![(1, 0)]);
        assert_eq!(t.adj[1], vec![(0, 0), (2, 1)]);
        assert_eq!(t.link_between(1, 2), Some(1));
        assert_eq!(t.link_between(0, 2), None);
    }

    #[test]
    fn adjacency_matrix_format() {
        let t = tri();
        let m = t.adjacency_matrix(1e9);
        assert_eq!(m[0 * 3 + 0], 0.0);
        assert_eq!(m[0 * 3 + 1], 1.0);
        assert_eq!(m[1 * 3 + 0], 1.0);
        assert_eq!(m[0 * 3 + 2], 1e9);
    }

    #[test]
    fn nodes_of_kind() {
        let t = tri();
        assert_eq!(t.nodes_of_kind(NodeKind::Requester), vec![0]);
        assert_eq!(t.nodes_of_kind(NodeKind::Memory), vec![2]);
    }

    #[test]
    fn cut_bandwidth_sums_crossing_links() {
        let t = tri();
        assert_eq!(t.cut_bandwidth(&[0]), 64.0);
        assert_eq!(t.cut_bandwidth(&[0, 1]), 64.0);
    }

    #[test]
    #[should_panic(expected = "self-links")]
    fn rejects_self_link() {
        let mut t = tri();
        t.add_link(0, 0, LinkCfg::default());
    }
}
