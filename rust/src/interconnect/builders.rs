//! Preset system topologies (paper Fig 9): chain, tree, ring, spine-leaf
//! (SL), and fully-connected (FC), plus scale-parameterized large-fabric
//! generators (dragonfly, fat-tree) for the 1k–4k node experiments.
//!
//! An "N-N system" has N requesters and N memory devices ("system scale =
//! 2N"). Requesters and memories are segregated across the fabric the way
//! the paper's bandwidth results imply: chain/tree/ring place all
//! requesters on one side and all memories on the other, so the
//! inter-switch "bridge" routes are shared by every flow and cap the
//! aggregate bandwidth at ~1x the port bandwidth (2x for ring's extra
//! route); spine-leaf is built with 2:1 leaf oversubscription (~N/2 x);
//! fully-connected gives every pair a private route (~N x).
//!
//! The generated kinds scale by the same N: dragonfly builds ceil(N/2)
//! routers (4 endpoints each) in ~sqrt groups — full mesh inside a
//! group, one global link per group pair — for exactly 2.5N nodes
//! (N=400/800/1600 -> the 1000/2000/4000-node curve points); fat-tree
//! builds a three-tier leaf/aggregation/core Clos with 4 endpoints per
//! leaf and ECMP at every tier.

use super::topology::{LinkCfg, NodeKind, Topology};
use crate::proto::NodeId;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TopologyKind {
    Chain,
    Tree,
    Ring,
    SpineLeaf,
    FullyConnected,
    Dragonfly,
    FatTree,
}

impl TopologyKind {
    /// The paper's Fig 9 preset grid. Deliberately excludes the
    /// generated large-fabric kinds: the topology/real-world experiment
    /// sweeps iterate this list and their published tables are pinned.
    pub const ALL: [TopologyKind; 5] = [
        TopologyKind::Chain,
        TopologyKind::Tree,
        TopologyKind::Ring,
        TopologyKind::SpineLeaf,
        TopologyKind::FullyConnected,
    ];

    /// Scale-parameterized generators for the large-fabric experiments.
    pub const GENERATED: [TopologyKind; 2] = [TopologyKind::Dragonfly, TopologyKind::FatTree];

    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Chain => "chain",
            TopologyKind::Tree => "tree",
            TopologyKind::Ring => "ring",
            TopologyKind::SpineLeaf => "spine-leaf",
            TopologyKind::FullyConnected => "fully-connected",
            TopologyKind::Dragonfly => "dragonfly",
            TopologyKind::FatTree => "fat-tree",
        }
    }

    pub fn parse(s: &str) -> Option<TopologyKind> {
        match s {
            "chain" => Some(TopologyKind::Chain),
            "tree" => Some(TopologyKind::Tree),
            "ring" => Some(TopologyKind::Ring),
            "spine-leaf" | "sl" | "spineleaf" => Some(TopologyKind::SpineLeaf),
            "fully-connected" | "fc" | "full" => Some(TopologyKind::FullyConnected),
            "dragonfly" | "df" => Some(TopologyKind::Dragonfly),
            "fat-tree" | "ft" | "fattree" => Some(TopologyKind::FatTree),
            _ => None,
        }
    }
}

/// A built fabric: the topology plus the endpoint id lists.
#[derive(Clone, Debug)]
pub struct Fabric {
    pub topo: Topology,
    pub requesters: Vec<NodeId>,
    pub memories: Vec<NodeId>,
    pub switches: Vec<NodeId>,
}

/// Build one of the preset N-N fabrics. Every link uses `link` config
/// (port bandwidth == link bandwidth; the paper constrains PBR switch port
/// bandwidth to a constant).
pub fn build(kind: TopologyKind, n: usize, link: LinkCfg) -> Fabric {
    assert!(n >= 1, "need at least one requester/memory pair");
    match kind {
        TopologyKind::Chain => chain_or_ring(n, link, false),
        TopologyKind::Ring => chain_or_ring(n, link, true),
        TopologyKind::Tree => tree(n, link),
        TopologyKind::SpineLeaf => spine_leaf(n, link),
        TopologyKind::FullyConnected => fully_connected(n, link),
        TopologyKind::Dragonfly => dragonfly(n, link),
        TopologyKind::FatTree => fat_tree(n, link),
    }
}

/// Chain of N switches: first half host the requesters (2 per switch when
/// N >= 2), second half the memories; ring closes the loop.
fn chain_or_ring(n: usize, link: LinkCfg, close: bool) -> Fabric {
    let mut t = Topology::new();
    let n_sw = n.max(2);
    let switches: Vec<NodeId> = (0..n_sw)
        .map(|i| t.add_node(format!("s{i}"), NodeKind::Switch))
        .collect();
    for w in switches.windows(2) {
        t.add_link(w[0], w[1], link);
    }
    if close && n_sw > 2 {
        t.add_link(switches[n_sw - 1], switches[0], link);
    }
    // Requesters on the first half, memories on the second half.
    let half = n_sw / 2;
    let mut requesters = Vec::new();
    let mut memories = Vec::new();
    for i in 0..n {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, switches[i % half], link);
        requesters.push(r);
        let m = t.add_node(format!("m{i}"), NodeKind::Memory);
        t.add_link(m, switches[half + (i % (n_sw - half))], link);
        memories.push(m);
    }
    Fabric {
        topo: t,
        requesters,
        memories,
        switches,
    }
}

/// Binary tree: requester leaves under the root's left child, memory
/// leaves under the right child, so every request crosses the root.
fn tree(n: usize, link: LinkCfg) -> Fabric {
    let mut t = Topology::new();
    let root = t.add_node("root", NodeKind::Switch);
    let mut switches = vec![root];

    // One leaf switch per 2 endpoints per side (at least 1).
    let leaves_per_side = (n / 2).max(1);
    let build_side = |t: &mut Topology, switches: &mut Vec<NodeId>, tag: &str| -> Vec<NodeId> {
        // Build a balanced binary tree over `leaves_per_side` leaves.
        let mut level: Vec<NodeId> = (0..leaves_per_side)
            .map(|i| {
                let s = t.add_node(format!("{tag}l{i}"), NodeKind::Switch);
                switches.push(s);
                s
            })
            .collect();
        let leaves = level.clone();
        let mut lvl = 0;
        while level.len() > 1 {
            let mut up = Vec::new();
            for pair in level.chunks(2) {
                let p = t.add_node(format!("{tag}i{lvl}_{}", up.len()), NodeKind::Switch);
                switches.push(p);
                for &c in pair {
                    t.add_link(p, c, link);
                }
                up.push(p);
            }
            level = up;
            lvl += 1;
        }
        t.add_link(root, level[0], link);
        leaves
    };

    let rleaves = build_side(&mut t, &mut switches, "rq");
    let mleaves = build_side(&mut t, &mut switches, "mm");

    let mut requesters = Vec::new();
    let mut memories = Vec::new();
    for i in 0..n {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, rleaves[i % rleaves.len()], link);
        requesters.push(r);
        let m = t.add_node(format!("m{i}"), NodeKind::Memory);
        t.add_link(m, mleaves[i % mleaves.len()], link);
        memories.push(m);
    }
    Fabric {
        topo: t,
        requesters,
        memories,
        switches,
    }
}

/// Spine-leaf with 2:1 oversubscription: requester leaves and memory
/// leaves hold 4 endpoints each but only 2 uplinks (one per spine).
fn spine_leaf(n: usize, link: LinkCfg) -> Fabric {
    let mut t = Topology::new();
    let n_spines = 2usize;
    let spines: Vec<NodeId> = (0..n_spines)
        .map(|i| t.add_node(format!("spine{i}"), NodeKind::Switch))
        .collect();
    let per_leaf = 4usize;
    let n_leaves_side = n.div_ceil(per_leaf).max(1);
    let mut switches = spines.clone();
    let mk_leaves = |t: &mut Topology, switches: &mut Vec<NodeId>, tag: &str| -> Vec<NodeId> {
        (0..n_leaves_side)
            .map(|i| {
                let l = t.add_node(format!("{tag}leaf{i}"), NodeKind::Switch);
                switches.push(l);
                for &s in &spines {
                    t.add_link(l, s, link);
                }
                l
            })
            .collect()
    };
    let rleaves = mk_leaves(&mut t, &mut switches, "rq");
    let mleaves = mk_leaves(&mut t, &mut switches, "mm");

    let mut requesters = Vec::new();
    let mut memories = Vec::new();
    for i in 0..n {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, rleaves[i / per_leaf % rleaves.len()], link);
        requesters.push(r);
        let m = t.add_node(format!("m{i}"), NodeKind::Memory);
        t.add_link(m, mleaves[i / per_leaf % mleaves.len()], link);
        memories.push(m);
    }
    Fabric {
        topo: t,
        requesters,
        memories,
        switches,
    }
}

/// Fully-connected switch mesh: one switch per requester/memory pair, all
/// switch pairs directly linked.
fn fully_connected(n: usize, link: LinkCfg) -> Fabric {
    let mut t = Topology::new();
    let switches: Vec<NodeId> = (0..n.max(2))
        .map(|i| t.add_node(format!("s{i}"), NodeKind::Switch))
        .collect();
    for i in 0..switches.len() {
        for j in (i + 1)..switches.len() {
            t.add_link(switches[i], switches[j], link);
        }
    }
    let mut requesters = Vec::new();
    let mut memories = Vec::new();
    for i in 0..n {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, switches[i % switches.len()], link);
        requesters.push(r);
        let m = t.add_node(format!("m{i}"), NodeKind::Memory);
        t.add_link(m, switches[i % switches.len()], link);
        memories.push(m);
    }
    Fabric {
        topo: t,
        requesters,
        memories,
        switches,
    }
}

/// Dragonfly: ceil(N/2) routers, each hosting 2 requesters + 2 memories
/// (2.5N nodes total — N=400 builds the 1000-node curve point). Routers
/// split into ~sqrt(routers) groups; full mesh inside a group, one
/// global link per group pair, each landed on a deterministically
/// rotated router so global traffic spreads over a group's members.
fn dragonfly(n: usize, link: LinkCfg) -> Fabric {
    let mut t = Topology::new();
    let n_routers = n.div_ceil(2).max(1);
    let switches: Vec<NodeId> = (0..n_routers)
        .map(|i| t.add_node(format!("rt{i}"), NodeKind::Switch))
        .collect();
    // Integer ceil(sqrt(n_routers)) groups of `per_group` routers each
    // (the last group may run short).
    let mut g = 1usize;
    while g * g < n_routers {
        g += 1;
    }
    let per_group = n_routers.div_ceil(g);
    let groups: Vec<&[NodeId]> = switches.chunks(per_group).collect();
    for members in &groups {
        for i in 0..members.len() {
            for j in (i + 1)..members.len() {
                t.add_link(members[i], members[j], link);
            }
        }
    }
    for gi in 0..groups.len() {
        for gj in (gi + 1)..groups.len() {
            let a = groups[gi][gj % groups[gi].len()];
            let b = groups[gj][gi % groups[gj].len()];
            t.add_link(a, b, link);
        }
    }
    let mut requesters = Vec::new();
    let mut memories = Vec::new();
    for i in 0..n {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, switches[i / 2 % n_routers], link);
        requesters.push(r);
        let m = t.add_node(format!("m{i}"), NodeKind::Memory);
        t.add_link(m, switches[i / 2 % n_routers], link);
        memories.push(m);
    }
    Fabric {
        topo: t,
        requesters,
        memories,
        switches,
    }
}

/// Three-tier fat-tree (leaf / aggregation / core Clos): requester
/// leaves and memory leaves hold 4 endpoints each; pods of 2 leaves get
/// 2 aggregation switches (every leaf uplinks to both — ECMP), and
/// every aggregation switch uplinks to all 4 cores.
fn fat_tree(n: usize, link: LinkCfg) -> Fabric {
    let mut t = Topology::new();
    let per_leaf = 4usize;
    let leaves_side = n.div_ceil(per_leaf).max(1);
    let mut switches = Vec::new();
    let cores: Vec<NodeId> = (0..4)
        .map(|i| t.add_node(format!("core{i}"), NodeKind::Switch))
        .collect();
    switches.extend(&cores);
    let mut mk_leaves = |t: &mut Topology, switches: &mut Vec<NodeId>, tag: &str| -> Vec<NodeId> {
        (0..leaves_side)
            .map(|i| {
                let l = t.add_node(format!("{tag}leaf{i}"), NodeKind::Switch);
                switches.push(l);
                l
            })
            .collect()
    };
    let rleaves = mk_leaves(&mut t, &mut switches, "rq");
    let mleaves = mk_leaves(&mut t, &mut switches, "mm");
    // Pods of 2 leaves over the combined leaf list; 2 aggs per pod.
    let all_leaves: Vec<NodeId> = rleaves.iter().chain(&mleaves).copied().collect();
    for (pi, pod) in all_leaves.chunks(2).enumerate() {
        for ai in 0..2 {
            let agg = t.add_node(format!("agg{pi}_{ai}"), NodeKind::Switch);
            switches.push(agg);
            for &leaf in pod {
                t.add_link(leaf, agg, link);
            }
            for &core in &cores {
                t.add_link(agg, core, link);
            }
        }
    }
    let mut requesters = Vec::new();
    let mut memories = Vec::new();
    for i in 0..n {
        let r = t.add_node(format!("r{i}"), NodeKind::Requester);
        t.add_link(r, rleaves[i / per_leaf % rleaves.len()], link);
        requesters.push(r);
        let m = t.add_node(format!("m{i}"), NodeKind::Memory);
        t.add_link(m, mleaves[i / per_leaf % mleaves.len()], link);
        memories.push(m);
    }
    Fabric {
        topo: t,
        requesters,
        memories,
        switches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::routing::{Routing, UNREACHABLE};

    fn connected(f: &Fabric) -> bool {
        let r = Routing::build_bfs(&f.topo);
        let n = f.topo.n();
        (0..n).all(|i| (0..n).all(|j| r.dist(i, j) != UNREACHABLE))
    }

    #[test]
    fn all_presets_connected_at_all_scales() {
        for kind in TopologyKind::ALL.into_iter().chain(TopologyKind::GENERATED) {
            for n in [1, 2, 4, 8, 16] {
                let f = build(kind, n, LinkCfg::default());
                assert!(connected(&f), "{} n={} disconnected", kind.name(), n);
                assert_eq!(f.requesters.len(), n);
                assert_eq!(f.memories.len(), n);
            }
        }
    }

    /// The headline curve points: dragonfly's 2.5N node count makes
    /// N=400/800/1600 land exactly on 1000/2000/4000 nodes, and the
    /// group structure keeps the fabric connected with a small diameter
    /// (local hop + global hop + local hop, plus endpoint links).
    #[test]
    fn dragonfly_hits_the_large_fabric_node_counts() {
        for (n, nodes) in [(400, 1000), (800, 2000), (1600, 4000)] {
            let f = build(TopologyKind::Dragonfly, n, LinkCfg::default());
            assert_eq!(f.topo.n(), nodes, "n={n}");
            assert_eq!(f.switches.len(), n / 2);
        }
        let f = build(TopologyKind::Dragonfly, 64, LinkCfg::default());
        assert!(connected(&f));
        let r = Routing::build_bfs(&f.topo);
        // Endpoint-to-endpoint: <= 2 endpoint links + 3 router hops.
        for &rq in &f.requesters {
            for &m in &f.memories {
                assert!(r.dist(rq, m) <= 5, "diameter blew up: {}", r.dist(rq, m));
            }
        }
    }

    /// Fat-tree ECMP: a leaf sees both pod aggregation switches toward
    /// a remote leaf, and an aggregation switch sees all 4 cores.
    #[test]
    fn fat_tree_has_ecmp_at_both_tiers() {
        let f = build(TopologyKind::FatTree, 16, LinkCfg::default());
        assert!(connected(&f));
        let r = Routing::build_bfs(&f.topo);
        let rleaf = f.topo.adj[f.requesters[0]][0].0;
        let m = *f.memories.last().unwrap();
        assert_eq!(r.candidates(rleaf, m).len(), 2, "leaf -> both pod aggs");
        // First agg node: linked to its pod leaves + all cores.
        let agg = f.topo.adj[rleaf]
            .iter()
            .map(|&(nb, _)| nb)
            .find(|&nb| f.topo.nodes[nb].name.starts_with("agg"))
            .expect("leaf has an agg uplink");
        assert_eq!(r.candidates(agg, m).len(), 4, "agg -> all four cores");
    }

    #[test]
    fn generated_kinds_parse_with_aliases() {
        assert_eq!(TopologyKind::parse("dragonfly"), Some(TopologyKind::Dragonfly));
        assert_eq!(TopologyKind::parse("df"), Some(TopologyKind::Dragonfly));
        assert_eq!(TopologyKind::parse("fat-tree"), Some(TopologyKind::FatTree));
        assert_eq!(TopologyKind::parse("ft"), Some(TopologyKind::FatTree));
        assert_eq!(TopologyKind::parse("fattree"), Some(TopologyKind::FatTree));
        for k in TopologyKind::GENERATED {
            assert_eq!(TopologyKind::parse(k.name()), Some(k));
        }
    }

    #[test]
    fn chain_max_hops_is_nine_at_scale_16() {
        // Paper Fig 11a: scale 16 (8 requesters) chain shows up to 9 hops.
        let f = build(TopologyKind::Chain, 8, LinkCfg::default());
        let r = Routing::build_bfs(&f.topo);
        let mut max = 0;
        for &rq in &f.requesters {
            for &m in &f.memories {
                max = max.max(r.dist(rq, m));
            }
        }
        assert_eq!(max, 9);
    }

    #[test]
    fn ring_halves_max_distance() {
        let chain = build(TopologyKind::Chain, 8, LinkCfg::default());
        let ring = build(TopologyKind::Ring, 8, LinkCfg::default());
        let rc = Routing::build_bfs(&chain.topo);
        let rr = Routing::build_bfs(&ring.topo);
        fn max_d(f: &Fabric, r: &Routing) -> u16 {
            let mut max = 0;
            for &rq in &f.requesters {
                for &m in &f.memories {
                    max = max.max(r.dist(rq, m));
                }
            }
            max
        }
        assert!(max_d(&ring, &rr) < max_d(&chain, &rc));
    }

    #[test]
    fn fc_all_paths_at_most_four_hops() {
        let f = build(TopologyKind::FullyConnected, 8, LinkCfg::default());
        let r = Routing::build_bfs(&f.topo);
        for &rq in &f.requesters {
            for &m in &f.memories {
                assert!(r.dist(rq, m) <= 4);
            }
        }
    }

    #[test]
    fn spine_leaf_has_ecmp_over_spines() {
        let f = build(TopologyKind::SpineLeaf, 8, LinkCfg::default());
        let r = Routing::build_bfs(&f.topo);
        // A requester leaf routing to a memory leaf should see 2 spine
        // candidates.
        let rleaf = f.topo.adj[f.requesters[0]][0].0;
        let m = f.memories[0];
        assert_eq!(r.candidates(rleaf, m).len(), 2);
    }

    #[test]
    fn tree_routes_cross_root() {
        let f = build(TopologyKind::Tree, 8, LinkCfg::default());
        let r = Routing::build_bfs(&f.topo);
        let root = 0; // first node added
        for &rq in &f.requesters {
            for &m in &f.memories {
                // dist(r, m) == dist(r, root) + dist(root, m)
                assert_eq!(r.dist(rq, m), r.dist(rq, root) + r.dist(root, m));
            }
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in TopologyKind::ALL {
            assert_eq!(TopologyKind::parse(k.name()), Some(k));
        }
        assert_eq!(TopologyKind::parse("sl"), Some(TopologyKind::SpineLeaf));
        assert_eq!(TopologyKind::parse("nope"), None);
    }
}
