//! Link (bus) state: serialization, duplex bandwidth allocation, and
//! utilization accounting.
//!
//! This is the paper's bus component. To reflect the full-duplex feature of
//! PCIe buses, each link allocates full bandwidth to each direction
//! independently; in half-duplex mode both directions share one allocation
//! and a configurable turnaround overhead is charged on direction reversal
//! (paper §III-C). The bus also prepends a configurable link/physical
//! header to every message — the Fig 16/17 experiments sweep this.
//!
//! Links are passive shared state (not event-handling components): a
//! forwarding device calls `NetState::transmit` which returns when the
//! message starts and finishes serializing; the device then schedules the
//! arrival event at the neighbor. This keeps the hot path at two events
//! per hop and makes adaptive routing's congestion lookup a plain read.

use super::topology::{Duplex, LinkCfg, LinkId, Topology};
use crate::engine::time::{ser_time, Ps};

/// Direction on a link: A->B = 0 (Down by convention), B->A = 1 (Up).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    AtoB = 0,
    BtoA = 1,
}

#[derive(Clone, Debug, Default)]
struct DirState {
    busy_until: Ps,
    /// Accumulated busy (serialization) time inside the measurement epoch.
    busy_ps: u64,
    payload_bytes: u64,
    header_bytes: u64,
    messages: u64,
}

#[derive(Clone, Debug)]
struct LinkState {
    cfg: LinkCfg,
    dirs: [DirState; 2],
    /// Half duplex: direction of the last transmission (for turnaround).
    last_dir: Option<Dir>,
}

/// Result of a transmit reservation.
#[derive(Clone, Copy, Debug)]
pub struct Xmit {
    /// When serialization began (>= now; the gap is queueing delay).
    pub start: Ps,
    /// When the last byte arrives at the far end (start + ser + latency).
    pub arrive: Ps,
    /// start - now.
    pub queued: Ps,
}

#[derive(Clone, Debug, Default)]
pub struct NetState {
    links: Vec<LinkState>,
    /// Epoch gate: utilization counters only accumulate when collecting.
    pub collecting: bool,
    pub epoch_start: Ps,
    pub epoch_end: Ps,
}

impl NetState {
    pub fn for_topology(topo: &Topology) -> NetState {
        NetState {
            links: topo
                .links
                .iter()
                .map(|l| LinkState {
                    cfg: l.cfg,
                    dirs: [DirState::default(), DirState::default()],
                    last_dir: None,
                })
                .collect(),
            collecting: false,
            epoch_start: 0,
            epoch_end: 0,
        }
    }

    /// Earliest start plus the turnaround charge a transmission in `dir`
    /// at `now` would pay (half-duplex direction reversal only).
    fn reservation(&self, link: LinkId, dir: Dir, now: Ps) -> (Ps, Ps) {
        let l = &self.links[link];
        match l.cfg.duplex {
            Duplex::Full => (now.max(l.dirs[dir as usize].busy_until), 0),
            Duplex::Half => {
                let shared = l.dirs[0].busy_until.max(l.dirs[1].busy_until);
                let turn = if l.last_dir.is_some() && l.last_dir != Some(dir) {
                    l.cfg.turnaround
                } else {
                    0
                };
                (now.max(shared) + turn, turn)
            }
        }
    }

    /// Earliest time a new message in `dir` could start serializing.
    pub fn earliest_start(&self, link: LinkId, dir: Dir, now: Ps) -> Ps {
        self.reservation(link, dir, now).0
    }

    /// Queue depth proxy for adaptive routing: how long after `now` the
    /// link would start serving a new message in `dir`.
    pub fn backlog(&self, link: LinkId, dir: Dir, now: Ps) -> Ps {
        self.earliest_start(link, dir, now).saturating_sub(now)
    }

    /// Reserve the link for one message of `payload_bytes`; returns timing.
    ///
    /// Wire-size model (matches the paper's bus component, §V-D): data
    /// messages occupy `payload_bytes` of wire time (the protocol header
    /// is folded into the normalized payload unit); **header-only**
    /// messages (read requests, write completions, snoops) occupy
    /// `cfg.header_bytes`. This is what makes a read-only stream leave
    /// the opposite direction to zero-payload headers — the full-duplex
    /// asymmetry Figs 16/17 study.
    pub fn transmit(&mut self, link: LinkId, dir: Dir, payload_bytes: u64, now: Ps) -> Xmit {
        let (start, turn) = self.reservation(link, dir, now);
        let l = &mut self.links[link];
        let header = if payload_bytes > 0 { 0 } else { l.cfg.header_bytes };
        let total = payload_bytes + header;
        let ser = ser_time(total, l.cfg.bandwidth_gbps);
        let d = &mut l.dirs[dir as usize];
        // `start` already includes the turnaround, so `busy_until` blocks
        // the shared medium through both the reversal window and the
        // serialization that follows it.
        d.busy_until = start + ser;
        l.last_dir = Some(dir);
        if self.collecting {
            let d = &mut l.dirs[dir as usize];
            // A half-duplex reversal occupies the medium for the whole
            // turnaround + serialization window; counting `ser` alone
            // undercounted bus_utility on mixed-direction streams.
            d.busy_ps += ser + turn;
            d.payload_bytes += payload_bytes;
            d.header_bytes += header;
            d.messages += 1;
        }
        Xmit {
            start,
            arrive: start + ser + l.cfg.latency,
            queued: start - now,
        }
    }

    pub fn cfg(&self, link: LinkId) -> &LinkCfg {
        &self.links[link].cfg
    }

    /// Begin the measurement epoch: reset accumulators.
    pub fn start_epoch(&mut self, now: Ps) {
        self.collecting = true;
        self.epoch_start = now;
        for l in &mut self.links {
            for d in &mut l.dirs {
                d.busy_ps = 0;
                d.payload_bytes = 0;
                d.header_bytes = 0;
                d.messages = 0;
            }
        }
    }

    pub fn end_epoch(&mut self, now: Ps) {
        self.collecting = false;
        self.epoch_end = now;
    }

    /// Re-open a previously closed epoch without resetting accumulators —
    /// incremental `Engine::run` re-entry (see `engine::Engine::run`).
    pub fn resume_epoch(&mut self) {
        self.collecting = true;
    }

    /// Bus utility (paper Fig 17a): fraction of epoch time the bus was
    /// busy, averaged over all transmission directions of this link.
    pub fn bus_utility(&self, link: LinkId) -> f64 {
        let span = self.epoch_end.saturating_sub(self.epoch_start);
        if span == 0 {
            return 0.0;
        }
        let l = &self.links[link];
        let dirs = match l.cfg.duplex {
            Duplex::Full => 2.0,
            // A half-duplex bus has a single shared medium.
            Duplex::Half => 1.0,
        };
        let busy: u64 = l.dirs.iter().map(|d| d.busy_ps).sum();
        (busy as f64 / span as f64) / dirs
    }

    /// Transmission efficiency (paper Fig 17b): payload bytes / total bytes
    /// actually moved on the link.
    pub fn transmission_efficiency(&self, link: LinkId) -> f64 {
        let l = &self.links[link];
        let payload: u64 = l.dirs.iter().map(|d| d.payload_bytes).sum();
        let total: u64 = l
            .dirs
            .iter()
            .map(|d| d.payload_bytes + d.header_bytes)
            .sum();
        if total == 0 {
            0.0
        } else {
            payload as f64 / total as f64
        }
    }

    /// Bytes of payload delivered on the link during the epoch.
    pub fn payload_bytes(&self, link: LinkId) -> u64 {
        self.links[link].dirs.iter().map(|d| d.payload_bytes).sum()
    }

    pub fn n_links(&self) -> usize {
        self.links.len()
    }

    /// Serialize all mutable link/epoch state (`cfg` is immutable and
    /// rebuilt from the topology). Fixed field order per direction; the
    /// reader below is the format's only consumer.
    pub fn snapshot(&self, w: &mut crate::util::snap::SnapWriter) {
        w.bool(self.collecting);
        w.u64(self.epoch_start);
        w.u64(self.epoch_end);
        w.usize(self.links.len());
        for l in &self.links {
            for d in &l.dirs {
                w.u64(d.busy_until);
                w.u64(d.busy_ps);
                w.u64(d.payload_bytes);
                w.u64(d.header_bytes);
                w.u64(d.messages);
            }
            match l.last_dir {
                None => w.u8(0),
                Some(Dir::AtoB) => w.u8(1),
                Some(Dir::BtoA) => w.u8(2),
            }
        }
    }

    /// Rebuild the state written by [`NetState::snapshot`] onto a
    /// freshly built `NetState` of the same topology.
    pub fn restore(&mut self, r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        self.collecting = r.bool()?;
        self.epoch_start = r.u64()?;
        self.epoch_end = r.u64()?;
        let n = r.usize()?;
        if n != self.links.len() {
            return Err(format!(
                "snapshot has {n} links, topology has {}",
                self.links.len()
            ));
        }
        for l in &mut self.links {
            for d in &mut l.dirs {
                d.busy_until = r.u64()?;
                d.busy_ps = r.u64()?;
                d.payload_bytes = r.u64()?;
                d.header_bytes = r.u64()?;
                d.messages = r.u64()?;
            }
            l.last_dir = match r.u8()? {
                0 => None,
                1 => Some(Dir::AtoB),
                2 => Some(Dir::BtoA),
                t => return Err(format!("invalid last_dir tag {t}")),
            };
        }
        Ok(())
    }

    /// Adopt link-direction state from a partitioned run's domain shard.
    ///
    /// Every transmit happens on the **sending** endpoint's side, so each
    /// direction of each link is mutated by exactly one domain; the merge
    /// copies a direction's state (busy window + epoch accounting) from
    /// the shard that owns it. `last_dir` (half-duplex turnaround memory)
    /// is taken from the A->B owner — half-duplex links are never cut, so
    /// that domain owns the whole medium; on cut (full-duplex) links the
    /// field is never read.
    pub fn adopt_owned(&mut self, shard: &NetState, owns: impl Fn(LinkId, Dir) -> bool) {
        debug_assert_eq!(self.links.len(), shard.links.len());
        for l in 0..self.links.len() {
            if owns(l, Dir::AtoB) {
                self.links[l].dirs[0] = shard.links[l].dirs[0].clone();
                self.links[l].last_dir = shard.links[l].last_dir;
            }
            if owns(l, Dir::BtoA) {
                self.links[l].dirs[1] = shard.links[l].dirs[1].clone();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::time::NS;
    use crate::interconnect::topology::{NodeKind, Topology};

    fn net_one_link(cfg: LinkCfg) -> NetState {
        let mut t = Topology::new();
        let a = t.add_node("a", NodeKind::Requester);
        let b = t.add_node("b", NodeKind::Memory);
        t.add_link(a, b, cfg);
        NetState::for_topology(&t)
    }

    #[test]
    fn full_duplex_directions_independent() {
        let mut net = net_one_link(LinkCfg {
            bandwidth_gbps: 64.0,
            latency: NS,
            duplex: Duplex::Full,
            turnaround: 0,
            header_bytes: 0,
        });
        // 64B at 64GB/s = 1ns serialization each way, simultaneously.
        let x1 = net.transmit(0, Dir::AtoB, 64, 0);
        let x2 = net.transmit(0, Dir::BtoA, 64, 0);
        assert_eq!(x1.start, 0);
        assert_eq!(x2.start, 0);
        assert_eq!(x1.arrive, 2 * NS); // 1ns ser + 1ns latency
        assert_eq!(x2.arrive, 2 * NS);
    }

    #[test]
    fn same_direction_serializes() {
        let mut net = net_one_link(LinkCfg {
            bandwidth_gbps: 64.0,
            latency: 0,
            duplex: Duplex::Full,
            turnaround: 0,
            header_bytes: 0,
        });
        let x1 = net.transmit(0, Dir::AtoB, 64, 0);
        let x2 = net.transmit(0, Dir::AtoB, 64, 0);
        assert_eq!(x1.start, 0);
        assert_eq!(x2.start, NS);
        assert_eq!(x2.queued, NS);
    }

    #[test]
    fn half_duplex_shares_medium_with_turnaround() {
        let mut net = net_one_link(LinkCfg {
            bandwidth_gbps: 64.0,
            latency: 0,
            duplex: Duplex::Half,
            turnaround: 5 * NS,
            header_bytes: 0,
        });
        net.start_epoch(0);
        let x1 = net.transmit(0, Dir::AtoB, 64, 0);
        assert_eq!(x1.start, 0);
        // Opposite direction: waits for the medium AND pays turnaround.
        let x2 = net.transmit(0, Dir::BtoA, 64, 0);
        assert_eq!(x2.start, NS + 5 * NS);
        // Same direction after that: no turnaround.
        let x3 = net.transmit(0, Dir::BtoA, 64, 0);
        assert_eq!(x3.start, x2.start + NS);
        // Reversing again serializes behind the full reservation (medium
        // + turnaround), never inside the previous turnaround window.
        let x4 = net.transmit(0, Dir::AtoB, 64, 0);
        assert_eq!(x4.start, x3.start + NS + 5 * NS);
        assert_eq!(x4.arrive, 14 * NS);

        // Utilization: the medium was never idle over the whole epoch —
        // 4 x 1ns serialization + 2 x 5ns turnarounds = 14ns of occupancy.
        // Turnaround used to be dropped from busy time, reporting 4/14.
        net.end_epoch(x4.arrive);
        assert!(
            (net.bus_utility(0) - 1.0).abs() < 1e-9,
            "half-duplex utility {} should count turnaround occupancy",
            net.bus_utility(0)
        );
    }

    #[test]
    fn header_rides_every_message() {
        let mut net = net_one_link(LinkCfg {
            bandwidth_gbps: 64.0,
            latency: 0,
            duplex: Duplex::Full,
            turnaround: 0,
            header_bytes: 64,
        });
        net.start_epoch(0);
        // header-only message still costs 64B of wire time
        let x = net.transmit(0, Dir::AtoB, 0, 0);
        assert_eq!(x.arrive, NS);
        net.end_epoch(2 * NS);
        assert_eq!(net.transmission_efficiency(0), 0.0);
    }

    #[test]
    fn utility_and_efficiency_accounting() {
        let mut net = net_one_link(LinkCfg {
            bandwidth_gbps: 64.0,
            latency: 0,
            duplex: Duplex::Full,
            turnaround: 0,
            header_bytes: 64,
        });
        net.start_epoch(0);
        net.transmit(0, Dir::AtoB, 0, 0); // header-only: 64B => 1ns down
        net.transmit(0, Dir::BtoA, 64, 0); // data: 64B => 1ns up
        net.end_epoch(NS);
        // both directions busy the whole 1ns epoch => utility 1.0
        assert!((net.bus_utility(0) - 1.0).abs() < 1e-9);
        // payload 64 of 128 total bytes moved
        assert!((net.transmission_efficiency(0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn data_messages_are_pure_payload_on_the_wire() {
        let mut net = net_one_link(LinkCfg {
            bandwidth_gbps: 64.0,
            latency: 0,
            duplex: Duplex::Full,
            turnaround: 0,
            header_bytes: 64,
        });
        // 64B data at 64GB/s = 1ns regardless of header config.
        let x = net.transmit(0, Dir::AtoB, 64, 0);
        assert_eq!(x.arrive, NS);
    }

    #[test]
    fn infinite_bandwidth_link() {
        let mut net = net_one_link(LinkCfg {
            bandwidth_gbps: 0.0,
            latency: NS,
            duplex: Duplex::Full,
            turnaround: 0,
            header_bytes: 16,
        });
        let x = net.transmit(0, Dir::AtoB, 4096, 0);
        assert_eq!(x.arrive, NS); // latency only
    }

    #[test]
    fn backlog_reflects_pending_work() {
        let mut net = net_one_link(LinkCfg {
            bandwidth_gbps: 64.0,
            latency: 0,
            duplex: Duplex::Full,
            turnaround: 0,
            header_bytes: 0,
        });
        assert_eq!(net.backlog(0, Dir::AtoB, 0), 0);
        net.transmit(0, Dir::AtoB, 640, 0); // 10ns
        assert_eq!(net.backlog(0, Dir::AtoB, 0), 10 * NS);
        assert_eq!(net.backlog(0, Dir::BtoA, 0), 0);
        assert_eq!(net.backlog(0, Dir::AtoB, 4 * NS), 6 * NS);
    }
}
