//! Routing: default shortest-path strategy plus PBR next-hop tables.
//!
//! Upon initialization the interconnect layer constructs a topology graph
//! and builds a default routing strategy based on the shortest-path
//! algorithm (paper §III-A). Switches then derive their internal PBR
//! tables from this information.
//!
//! Distances come from either the native BFS (uniform hop cost) or the
//! AOT-compiled Pallas APSP kernel executed through PJRT (`runtime::`);
//! `from_distances` accepts the kernel's f32 matrix so both producers feed
//! the same table builder — tests assert the two agree.

use super::links::{Dir, NetState};
use super::topology::{LinkId, Topology};
use crate::proto::NodeId;
use std::collections::VecDeque;

pub const UNREACHABLE: u16 = u16::MAX;

/// Fixed-point scale of one `(u, dst)` cell's traffic share in
/// [`Routing::fanin_weights`]. Each cell contributes exactly this much,
/// split over its ECMP candidates, so per-node totals stay exact
/// integers and the partitioner's cost model is bit-deterministic.
pub const FANIN_SCALE: u64 = 1024;

/// Packet forwarding strategy (paper Fig 13).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Static per (src, dst) pick among equal-cost next hops.
    Oblivious,
    /// Congestion-aware: at each hop pick the equal-cost next hop whose
    /// outgoing link has the smallest backlog.
    Adaptive,
}

#[derive(Clone, Debug)]
pub struct Routing {
    n: usize,
    /// dist[u * n + v] = hop count.
    dist: Vec<u16>,
    /// Equal-cost next hops in one contiguous CSR arena: the candidates
    /// for (u, v) are `next_flat[next_off[u*n+v] .. next_off[u*n+v+1]]`.
    /// One allocation for the whole table instead of n^2 inner `Vec`s —
    /// `candidates()` is a pure slice of hot, contiguous memory.
    next_off: Vec<u32>,
    next_flat: Vec<(NodeId, LinkId)>,
}

impl Routing {
    /// Native path: BFS from every node (links cost 1 hop).
    pub fn build_bfs(topo: &Topology) -> Routing {
        let n = topo.n();
        let mut dist = vec![UNREACHABLE; n * n];
        // One frontier queue reused across all n source passes: each
        // pass drains it empty, and the fresh row's UNREACHABLE cells
        // double as the visited marker, so no per-pass clearing is
        // needed. The per-source allocation was super-linear in fabric
        // size once the queue outgrew the allocator's small bins
        // (0.9/3.1/11.8 us at n=8/16/32 in `engine_micro`).
        let mut q = VecDeque::with_capacity(n);
        for src in 0..n {
            let row = &mut dist[src * n..(src + 1) * n];
            row[src] = 0;
            debug_assert!(q.is_empty());
            q.push_back(src);
            while let Some(u) = q.pop_front() {
                let du = row[u];
                for &(v, _) in &topo.adj[u] {
                    if row[v] == UNREACHABLE {
                        row[v] = du + 1;
                        q.push_back(v);
                    }
                }
            }
        }
        Self::tables_from_dist(topo, dist)
    }

    /// PJRT path: distances produced by the AOT Pallas min-plus APSP
    /// kernel (f32 matrix, >= unreach/2 means no path).
    pub fn from_distances(topo: &Topology, d: &[f32], unreach: f32) -> Routing {
        let n = topo.n();
        assert!(d.len() >= n * n, "distance matrix too small");
        let mut dist = vec![UNREACHABLE; n * n];
        for i in 0..n {
            for j in 0..n {
                let v = d[i * n + j];
                dist[i * n + j] = if v >= unreach / 2.0 {
                    UNREACHABLE
                } else {
                    v.round() as u16
                };
            }
        }
        Self::tables_from_dist(topo, dist)
    }

    fn tables_from_dist(topo: &Topology, dist: Vec<u16>) -> Routing {
        let n = topo.n();
        let mut next_off = Vec::with_capacity(n * n + 1);
        let mut next_flat: Vec<(NodeId, LinkId)> = Vec::new();
        next_off.push(0);
        for u in 0..n {
            for v in 0..n {
                let seg_start = next_flat.len();
                let d = dist[u * n + v];
                if u != v && d != UNREACHABLE {
                    for &(w, link) in &topo.adj[u] {
                        if dist[w * n + v] + 1 == d {
                            next_flat.push((w, link));
                        }
                    }
                    // Deterministic order regardless of adjacency insert
                    // order (same key the old per-cell Vec sort used).
                    next_flat[seg_start..].sort_unstable();
                }
                next_off.push(next_flat.len() as u32);
            }
        }
        Routing {
            n,
            dist,
            next_off,
            next_flat,
        }
    }

    pub fn dist(&self, u: NodeId, v: NodeId) -> u16 {
        self.dist[u * self.n + v]
    }

    /// Expected-traffic fan-in per node, in fixed-point [`FANIN_SCALE`]
    /// units: every routable `(u, dst)` cell with `u != dst` splits one
    /// `FANIN_SCALE` share evenly across its equal-cost next-hop
    /// candidates (integer division — deterministic, remainder dropped).
    /// A node's total is proportional to how much forwarded traffic it
    /// attracts under uniform all-pairs load: spine switches sit in the
    /// candidate sets of almost every cell and accumulate large fan-in,
    /// leaf endpoints appear only in their neighbors' cells. Pure
    /// function of the routing tables (themselves a pure function of the
    /// topology), so the partitioner's traffic cost model built on it is
    /// seed-stable by construction.
    pub fn fanin_weights(&self) -> Vec<u64> {
        let mut w = vec![0u64; self.n];
        for cell in 0..self.n * self.n {
            let seg =
                &self.next_flat[self.next_off[cell] as usize..self.next_off[cell + 1] as usize];
            if !seg.is_empty() {
                let share = FANIN_SCALE / seg.len() as u64;
                for &(next, _) in seg {
                    w[next] += share;
                }
            }
        }
        w
    }

    pub fn candidates(&self, u: NodeId, v: NodeId) -> &[(NodeId, LinkId)] {
        let i = u * self.n + v;
        &self.next_flat[self.next_off[i] as usize..self.next_off[i + 1] as usize]
    }

    /// Pick the next hop at node `u` for a packet `src -> dst`.
    ///
    /// Oblivious: static hash of (src, dst) over the equal-cost set, so a
    /// given flow always takes the same path. Adaptive: smallest current
    /// backlog on the candidate link, ties broken deterministically.
    pub fn next_hop(
        &self,
        u: NodeId,
        src: NodeId,
        dst: NodeId,
        strategy: Strategy,
        net: &NetState,
        topo: &Topology,
        now: crate::engine::time::Ps,
    ) -> Option<(NodeId, LinkId)> {
        let cands = self.candidates(u, dst);
        if cands.is_empty() {
            return None;
        }
        if cands.len() == 1 {
            return Some(cands[0]);
        }
        match strategy {
            Strategy::Oblivious => {
                let h = flow_hash(src as u64, dst as u64);
                Some(cands[(h % cands.len() as u64) as usize])
            }
            Strategy::Adaptive => {
                let mut best = cands[0];
                let mut best_backlog = u64::MAX;
                for &(w, link) in cands {
                    let dir = dir_of(topo, link, u);
                    let b = net.backlog(link, dir, now);
                    if b < best_backlog {
                        best_backlog = b;
                        best = (w, link);
                    }
                }
                Some(best)
            }
        }
    }
}

/// Direction of travel on `link` when leaving node `u`.
pub fn dir_of(topo: &Topology, link: LinkId, u: NodeId) -> Dir {
    if topo.links[link].a == u {
        Dir::AtoB
    } else {
        debug_assert_eq!(topo.links[link].b, u);
        Dir::BtoA
    }
}

fn flow_hash(a: u64, b: u64) -> u64 {
    // splitmix-style avalanche on the pair
    let mut z = a.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ b.wrapping_add(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::topology::{LinkCfg, NodeKind};

    /// r0 - s0 - s1 - m0 chain plus a parallel s0 - s2 - s1 path.
    fn diamond() -> Topology {
        let mut t = Topology::new();
        let r = t.add_node("r0", NodeKind::Requester);
        let s0 = t.add_node("s0", NodeKind::Switch);
        let s1 = t.add_node("s1", NodeKind::Switch);
        let s2 = t.add_node("s2", NodeKind::Switch);
        let m = t.add_node("m0", NodeKind::Memory);
        t.add_link(r, s0, LinkCfg::default());
        t.add_link(s0, s1, LinkCfg::default());
        t.add_link(s0, s2, LinkCfg::default());
        t.add_link(s2, s1, LinkCfg::default());
        t.add_link(s1, m, LinkCfg::default());
        t
    }

    #[test]
    fn bfs_distances() {
        let t = diamond();
        let r = Routing::build_bfs(&t);
        assert_eq!(r.dist(0, 4), 3); // r0 -> s0 -> s1 -> m0
        assert_eq!(r.dist(0, 3), 2);
        assert_eq!(r.dist(4, 0), 3);
        assert_eq!(r.dist(2, 2), 0);
    }

    #[test]
    fn ecmp_sets_contain_all_shortest_options() {
        let t = diamond();
        let r = Routing::build_bfs(&t);
        // From s0 toward m0: direct via s1 (dist 2) only; s2 is dist 3.
        let c = r.candidates(1, 4);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].0, 2);
        // From s0 toward s1's "far side" both paths tie at... s0->s1 = 1,
        // s0->s2->s1 = 2, so single candidate again:
        assert_eq!(r.candidates(1, 2).len(), 1);
    }

    #[test]
    fn oblivious_is_static_per_flow() {
        let t = diamond();
        let r = Routing::build_bfs(&t);
        let net = NetState::for_topology(&t);
        let a = r.next_hop(1, 0, 4, Strategy::Oblivious, &net, &t, 0);
        let b = r.next_hop(1, 0, 4, Strategy::Oblivious, &net, &t, 999);
        assert_eq!(a, b);
    }

    #[test]
    fn adaptive_avoids_backlogged_link() {
        // square: u connected to dst via two equal-cost 2-hop paths
        let mut t = Topology::new();
        let u = t.add_node("u", NodeKind::Switch);
        let x = t.add_node("x", NodeKind::Switch);
        let y = t.add_node("y", NodeKind::Switch);
        let d = t.add_node("d", NodeKind::Memory);
        let lux = t.add_link(u, x, LinkCfg::default());
        let _luy = t.add_link(u, y, LinkCfg::default());
        t.add_link(x, d, LinkCfg::default());
        t.add_link(y, d, LinkCfg::default());
        let r = Routing::build_bfs(&t);
        assert_eq!(r.candidates(u, d).len(), 2);

        let mut net = NetState::for_topology(&t);
        // Congest u->x heavily.
        for _ in 0..50 {
            net.transmit(lux, Dir::AtoB, 4096, 0);
        }
        let pick = r
            .next_hop(u, u, d, Strategy::Adaptive, &net, &t, 0)
            .unwrap();
        assert_eq!(pick.0, y, "adaptive should avoid the congested path");
    }

    #[test]
    fn from_distances_matches_bfs() {
        let t = diamond();
        let bfs = Routing::build_bfs(&t);
        // Fake the kernel output from BFS distances.
        let n = t.n();
        let unreach = 1e9f32;
        let mut d = vec![0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let v = bfs.dist(i, j);
                d[i * n + j] = if v == UNREACHABLE { unreach } else { v as f32 };
            }
        }
        let r2 = Routing::from_distances(&t, &d, unreach);
        for i in 0..n {
            for j in 0..n {
                assert_eq!(bfs.dist(i, j), r2.dist(i, j));
                assert_eq!(bfs.candidates(i, j), r2.candidates(i, j));
            }
        }
    }

    /// CSR arena invariants: self/unreachable cells are empty slices and
    /// every equal-cost set is sorted and duplicate-free.
    #[test]
    fn csr_arena_partitions_cleanly() {
        let t = diamond();
        let r = Routing::build_bfs(&t);
        let n = t.n();
        for u in 0..n {
            for v in 0..n {
                let c = r.candidates(u, v);
                if u == v || r.dist(u, v) == UNREACHABLE {
                    assert!(c.is_empty(), "({u},{v}) must have no next hop");
                }
                assert!(c.windows(2).all(|w| w[0] < w[1]), "({u},{v}) not sorted");
            }
        }
    }

    /// Fan-in accounting: every routable cell contributes exactly its
    /// (integer-divided) shares, hub nodes outweigh leaves, and the
    /// estimate is a pure function of the topology.
    #[test]
    fn fanin_weights_concentrate_on_transit_nodes() {
        let t = diamond();
        let r = Routing::build_bfs(&t);
        let w = r.fanin_weights();
        assert_eq!(w.len(), t.n());
        // Total = sum over routable non-self cells of FANIN_SCALE minus
        // integer-division remainders (all cells here have 1 or 2
        // candidates, so shares divide exactly).
        let routable = (0..t.n())
            .flat_map(|u| (0..t.n()).map(move |v| (u, v)))
            .filter(|&(u, v)| u != v && r.dist(u, v) != UNREACHABLE)
            .count() as u64;
        assert_eq!(w.iter().sum::<u64>(), routable * FANIN_SCALE);
        // s0 and s1 carry every r0 <-> m0 flow plus their own endpoints'
        // traffic; the stub endpoints r0/m0 only receive their neighbor's
        // final hop. The transit switches must dominate.
        assert!(w[1] > w[0] && w[3] < w[1], "transit nodes must outweigh leaves");
        assert_eq!(w, Routing::build_bfs(&t).fanin_weights(), "not deterministic");
    }

    /// ECMP cells split their share: a node reached through 2 equal-cost
    /// candidates gets half a share from that cell.
    #[test]
    fn fanin_splits_ecmp_shares() {
        // square: u -> {x, y} -> d, both 2-hop paths tie.
        let mut t = Topology::new();
        let u = t.add_node("u", NodeKind::Switch);
        let x = t.add_node("x", NodeKind::Switch);
        let y = t.add_node("y", NodeKind::Switch);
        let d = t.add_node("d", NodeKind::Memory);
        t.add_link(u, x, LinkCfg::default());
        t.add_link(u, y, LinkCfg::default());
        t.add_link(x, d, LinkCfg::default());
        t.add_link(y, d, LinkCfg::default());
        let r = Routing::build_bfs(&t);
        let w = r.fanin_weights();
        // By symmetry x and y attract identical load.
        assert_eq!(w[x], w[y]);
        // Cells feeding x: (u,x) full + (u,d) half + (y,x) full? y->x goes
        // via u or d (dist 2, both candidates)... rather than enumerate,
        // pin the symmetric totals: u and d tie, x and y tie, and the
        // ECMP halves keep every entry a multiple of FANIN_SCALE / 2.
        assert_eq!(w[u], w[d]);
        assert!(w.iter().all(|&v| v % (FANIN_SCALE / 2) == 0));
    }

    #[test]
    fn disconnected_marked_unreachable() {
        let mut t = Topology::new();
        let a = t.add_node("a", NodeKind::Requester);
        let b = t.add_node("b", NodeKind::Memory);
        let _c = t.add_node("c", NodeKind::Memory);
        t.add_link(a, b, LinkCfg::default());
        let r = Routing::build_bfs(&t);
        assert_eq!(r.dist(0, 2), UNREACHABLE);
        assert!(r.candidates(0, 2).is_empty());
    }
}
