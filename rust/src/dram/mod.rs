//! DRAM endpoint timing model — the DRAMsim3 substitute (paper Table I
//! integrates DRAMsim3 for DDRx/HBM endpoints; we provide an in-tree
//! bank/row-state model with the same observable behaviour: row-buffer
//! hit/miss/conflict latency split, per-bank parallelism, and shared data
//! bus serialization).
//!
//! Timing parameters follow DDR5-4800 JEDEC-class values. The model is a
//! first-order FR-FCFS approximation: each bank tracks its open row and
//! next-free time; the channel data bus serializes bursts.

use crate::devices::memdev::MemBackend;
use crate::engine::time::{ns, Ps};

#[derive(Clone, Debug)]
pub struct DramCfg {
    pub banks: usize,
    /// Row (page) size per bank in bytes.
    pub row_bytes: u64,
    /// Activate (row open) delay.
    pub t_rcd: Ps,
    /// Precharge (row close) delay.
    pub t_rp: Ps,
    /// CAS latency (column access).
    pub t_cl: Ps,
    /// Data burst time for one 64B cacheline on the channel bus.
    pub t_burst: Ps,
    /// Write recovery added to write accesses.
    pub t_wr: Ps,
}

impl DramCfg {
    /// DDR5-4800, one channel, 32 banks (8 bank groups x 4).
    pub fn ddr5_4800() -> DramCfg {
        DramCfg {
            banks: 32,
            row_bytes: 8192,
            t_rcd: ns(16.0),
            t_rp: ns(16.0),
            t_cl: ns(16.6),
            t_burst: ns(1.7), // 64B at ~38.4 GB/s per channel
            t_wr: ns(10.0),
        }
    }

    /// HBM2-class stack: more banks, shorter rows, wider bus.
    pub fn hbm2() -> DramCfg {
        DramCfg {
            banks: 128,
            row_bytes: 2048,
            t_rcd: ns(14.0),
            t_rp: ns(14.0),
            t_cl: ns(14.0),
            t_burst: ns(0.25),
            t_wr: ns(8.0),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
struct Bank {
    open_row: Option<u64>,
    busy_until: Ps,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct DramStats {
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    pub reads: u64,
    pub writes: u64,
}

pub struct DramBackend {
    cfg: DramCfg,
    banks: Vec<Bank>,
    /// Shared channel data bus.
    bus_free: Ps,
    pub stats: DramStats,
}

impl DramBackend {
    pub fn new(cfg: DramCfg) -> DramBackend {
        DramBackend {
            banks: vec![Bank::default(); cfg.banks],
            bus_free: 0,
            stats: DramStats::default(),
            cfg,
        }
    }

    fn map(&self, addr: u64) -> (usize, u64) {
        // Row-interleaved bank mapping: consecutive rows rotate banks,
        // consecutive lines within a row stay in the same bank (locality
        // keeps the row buffer hot for streaming patterns).
        let row_global = addr / self.cfg.row_bytes;
        let bank = (row_global % self.cfg.banks as u64) as usize;
        let row = row_global / self.cfg.banks as u64;
        (bank, row)
    }

    pub fn row_hit_rate(&self) -> f64 {
        let total = self.stats.row_hits + self.stats.row_misses + self.stats.row_conflicts;
        if total == 0 {
            0.0
        } else {
            self.stats.row_hits as f64 / total as f64
        }
    }
}

impl MemBackend for DramBackend {
    fn access(&mut self, addr: u64, is_write: bool, at: Ps) -> Ps {
        let (bank_idx, row) = self.map(addr);
        let bank = &mut self.banks[bank_idx];
        let start = at.max(bank.busy_until);
        let prep = match bank.open_row {
            Some(r) if r == row => {
                self.stats.row_hits += 1;
                0
            }
            None => {
                self.stats.row_misses += 1;
                self.cfg.t_rcd
            }
            Some(_) => {
                self.stats.row_conflicts += 1;
                self.cfg.t_rp + self.cfg.t_rcd
            }
        };
        bank.open_row = Some(row);
        let col_ready = start + prep + self.cfg.t_cl;
        // Light channel-bus model: bursts from different banks may not
        // overlap, but a *future* burst must not reserve the bus ahead of
        // time (greedy reservation would serialize every bank behind the
        // deepest queue — accesses are scheduled in arrival order, not
        // completion order). The bus therefore only pushes back bursts
        // that would start inside the previous burst's window.
        let burst_start = if col_ready < self.bus_free
            && self.bus_free - col_ready <= self.cfg.t_burst
        {
            self.bus_free
        } else {
            col_ready
        };
        let done = burst_start + self.cfg.t_burst;
        self.bus_free = self.bus_free.max(done);
        let wr_extra = if is_write {
            self.stats.writes += 1;
            self.cfg.t_wr
        } else {
            self.stats.reads += 1;
            0
        };
        bank.busy_until = done + wr_extra;
        done
    }

    fn name(&self) -> &'static str {
        "dram(ddr-bank-model)"
    }

    fn snapshot(&self, w: &mut crate::util::snap::SnapWriter) {
        w.usize(self.banks.len());
        for b in &self.banks {
            match b.open_row {
                None => w.u8(0),
                Some(row) => {
                    w.u8(1);
                    w.u64(row);
                }
            }
            w.u64(b.busy_until);
        }
        w.u64(self.bus_free);
        w.u64(self.stats.row_hits);
        w.u64(self.stats.row_misses);
        w.u64(self.stats.row_conflicts);
        w.u64(self.stats.reads);
        w.u64(self.stats.writes);
    }

    fn restore(&mut self, r: &mut crate::util::snap::SnapReader<'_>) -> Result<(), String> {
        let n = r.usize()?;
        if n != self.banks.len() {
            return Err(format!(
                "snapshot has {n} DRAM banks, this backend has {}",
                self.banks.len()
            ));
        }
        for b in &mut self.banks {
            b.open_row = match r.u8()? {
                0 => None,
                1 => Some(r.u64()?),
                t => return Err(format!("invalid open-row tag {t}")),
            };
            b.busy_until = r.u64()?;
        }
        self.bus_free = r.u64()?;
        self.stats.row_hits = r.u64()?;
        self.stats.row_misses = r.u64()?;
        self.stats.row_conflicts = r.u64()?;
        self.stats.reads = r.u64()?;
        self.stats.writes = r.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::time::NS;

    #[test]
    fn row_hit_is_faster_than_miss() {
        let mut d = DramBackend::new(DramCfg::ddr5_4800());
        let t1 = d.access(0, false, 0); // miss (cold)
        let t2 = d.access(64, false, t1) - t1; // same row: hit
        let first = t1;
        assert!(t2 < first, "hit {t2} !< miss {first}");
        assert_eq!(d.stats.row_hits, 1);
        assert_eq!(d.stats.row_misses, 1);
    }

    #[test]
    fn row_conflict_pays_precharge() {
        let cfg = DramCfg::ddr5_4800();
        let row_span = cfg.row_bytes * cfg.banks as u64; // same bank, next row
        let mut d = DramBackend::new(cfg.clone());
        let t1 = d.access(0, false, 0);
        let t2 = d.access(row_span, false, t1);
        assert_eq!(d.stats.row_conflicts, 1);
        // conflict latency ~ tRP + tRCD + tCL + burst
        let lat = t2 - t1;
        assert!(lat >= cfg.t_rp + cfg.t_rcd + cfg.t_cl);
    }

    #[test]
    fn banks_operate_in_parallel() {
        let cfg = DramCfg::ddr5_4800();
        let mut d = DramBackend::new(cfg.clone());
        // Two accesses to different banks at t=0: bank prep overlaps; only
        // the bursts serialize.
        let a = d.access(0, false, 0);
        let b = d.access(cfg.row_bytes, false, 0); // next row -> next bank
        assert!(b < 2 * a, "bank parallelism missing: {a} then {b}");
        assert_eq!(b - a, cfg.t_burst);
    }

    #[test]
    fn same_bank_serializes() {
        let cfg = DramCfg::ddr5_4800();
        let mut d = DramBackend::new(cfg.clone());
        let a = d.access(0, false, 0);
        let b = d.access(0, false, 0); // same line, bank busy
        assert!(b > a);
    }

    #[test]
    fn writes_add_recovery() {
        let cfg = DramCfg::ddr5_4800();
        let mut d = DramBackend::new(cfg.clone());
        let t = d.access(0, true, 0);
        // Next access to the same bank must wait for write recovery.
        let t2 = d.access(64, false, t);
        assert!(t2 - t >= cfg.t_wr);
    }

    #[test]
    fn streaming_mostly_row_hits() {
        let mut d = DramBackend::new(DramCfg::ddr5_4800());
        let mut t = 0;
        for i in 0..1000u64 {
            t = d.access(i * 64, false, t);
        }
        assert!(d.row_hit_rate() > 0.9, "hit rate {}", d.row_hit_rate());
    }

    #[test]
    fn random_pattern_hits_less_than_streaming() {
        use crate::util::rng::Pcg32;
        let mut rng = Pcg32::new(3, 0);
        let mut d = DramBackend::new(DramCfg::ddr5_4800());
        let mut t = 0;
        for _ in 0..1000 {
            t = d.access(rng.gen_range(1 << 30) & !63, false, t);
        }
        let random_rate = d.row_hit_rate();
        assert!(random_rate < 0.5, "random hit rate {random_rate}");
    }

    #[test]
    fn idle_latency_matches_ddr5_class() {
        let mut d = DramBackend::new(DramCfg::ddr5_4800());
        let lat = d.access(0, false, 1000 * NS) - 1000 * NS;
        // cold access: tRCD + tCL + burst ~ 34ns; sanity band 20..60ns
        assert!(lat > 20 * NS && lat < 60 * NS, "idle latency {lat}");
    }
}
