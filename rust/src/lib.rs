//! # ESF — Extensible Simulation Framework for CXL-Enabled Systems
//!
//! A discrete-event simulator reproducing "A Novel Extensible Simulation
//! Framework for CXL-Enabled Systems" (CS.AR 2024): interconnect layer
//! (arbitrary topologies, PBR, shortest-path routing — accelerated by an
//! AOT-compiled Pallas min-plus APSP kernel via PJRT), device layer
//! (requesters, full/half-duplex PCIe buses, PBR switches, memory
//! endpoints, device-side inclusive snoop filters), and the substrates the
//! paper's evaluation depends on (DRAM/SSD endpoint timing, a trace-driven
//! CPU + cache hierarchy, workload generators).
//!
//! Start at [`config::SystemCfg`] + [`config::build_system`], or see
//! `examples/quickstart.rs`.
pub mod check;
pub mod config;
pub mod cpu;
pub mod devices;
pub mod dram;
pub mod engine;
pub mod experiments;
pub mod interconnect;
pub mod lint;
pub mod metrics;
pub mod proto;
pub mod runtime;
pub mod server;
pub mod ssd;
pub mod sweep;
pub mod util;
pub mod workloads;
