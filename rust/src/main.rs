//! `esf` — command-line launcher for the ESF simulation framework.
//!
//! ```text
//! esf list                              list experiment ids
//! esf exp <id> [--full] [--csv] [--jobs N]  reproduce a paper table/figure
//! esf all [--full] [--jobs N]           run every experiment
//! esf run --config <file.json> [--intra-jobs N]
//!         [--checkpoint <file>] [--checkpoint-every <ns>] [--restore <file>]
//!                                       simulate a JSON-configured system,
//!                                       optionally writing resumable
//!                                       checkpoints / resuming from one
//! esf sweep --config <grid.json> [--jobs N] [--intra-jobs N] [--csv]
//!           [--json <file|->] [--cache-dir <dir>]
//!                                       parallel scenario-grid sweep with
//!                                       percentile columns + cached resume
//! esf topo --kind <k> --n <N>           inspect a preset fabric + routing
//! esf apsp-check [--n 64]               PJRT Pallas APSP vs native BFS
//! esf lint [--root <dir>] [--json] [--rules]
//!                                       determinism static analysis over
//!                                       the simulator sources (ESF-L*)
//! esf check <config.json|file.snap> [--json]
//!                                       model validation without running:
//!                                       routing loop-freedom, link/partition
//!                                       consistency, txn-id capacity,
//!                                       grid well-formedness, job-spec
//!                                       envelopes, checkpoint integrity
//!                                       (ESF-C*)
//! esf submit <grid.json> [--socket S]   queue a grid on a running esfd
//! esf status [job] [--socket S] [--csv] daemon scheduler + per-job progress
//! esf attach <job> [--socket S] [--csv] [--json <file|->]
//!                                       stream a job's cells as they finish;
//!                                       final output byte-identical to
//!                                       one-shot `esf sweep` on that grid
//! esf shutdown [--socket S]             drain jobs and stop the daemon
//! ```
//!
//! `esf run` and `esf sweep` run the `esf check` rules as a pre-pass, so
//! an inconsistent model is rejected with a located error instead of
//! producing a silently wrong (or nondeterministic) simulation.
//!
//! The daemon quartet (`submit`/`status`/`attach`/`shutdown`) talks to a
//! running `esfd` (the sibling binary, `esf::server`) over its Unix
//! socket: `esfd` owns one machine-wide thread budget, admission control
//! splits it across concurrent jobs, and a shared sweep cache serves
//! repeated grids without re-simulation.
//!
//! `--jobs N` shards independent simulations over N worker threads;
//! `--intra-jobs N` splits ONE simulation into N partitioned event
//! domains (0 = all cores for either). Results are byte-identical for
//! every combination — the sweep driver collects in submission order and
//! the partitioned engine is deterministic (see `esf::sweep`,
//! `esf::engine::parallel`); the two share one thread budget so their
//! product never oversubscribes the machine.

use esf::config::{build_system_with, RoutingSource, SystemCfg};
use esf::metrics::{aggregate, hop_breakdown};
use esf::util::args::Args;
use std::process::ExitCode;

/// Atomic checkpoint write ([`esf::util::atomic_write`]: temp-with-pid +
/// rename), so a kill mid-write never clobbers the previous good
/// checkpoint with a torn one (the embedded digest would catch it, but
/// the older file is strictly more useful than a rejected fresh one).
fn write_snapshot(path: &str, bytes: &[u8]) -> std::io::Result<()> {
    esf::util::atomic_write(std::path::Path::new(path), bytes, 0)
}

/// Socket the daemon subcommands talk to (`--socket` override).
fn daemon_socket(args: &Args) -> std::path::PathBuf {
    std::path::PathBuf::from(args.str_or("socket", esf::server::DEFAULT_SOCKET))
}

fn main() -> ExitCode {
    let args = Args::from_env();
    let quick = !args.has("full");
    match args.command.as_deref() {
        Some("list") => {
            println!("experiments (paper tables/figures):");
            for (id, desc) in esf::experiments::list() {
                println!("  {id:<6} {desc}");
            }
            ExitCode::SUCCESS
        }
        Some("exp") => {
            let Some(id) = args.positional.first() else {
                eprintln!("usage: esf exp <id> [--full] [--csv] [--jobs N]");
                return ExitCode::FAILURE;
            };
            let jobs = args.u64_or("jobs", 1) as usize;
            match esf::experiments::run_jobs(id, quick, jobs) {
                Some(tables) => {
                    for t in tables {
                        if args.has("csv") {
                            println!("{}", t.to_csv());
                        } else {
                            println!("{}", t.render());
                        }
                    }
                    ExitCode::SUCCESS
                }
                None => {
                    eprintln!("unknown experiment '{id}' (try `esf list`)");
                    ExitCode::FAILURE
                }
            }
        }
        Some("all") => {
            let jobs = args.u64_or("jobs", 1) as usize;
            for (id, _) in esf::experiments::list() {
                eprintln!("=== running {id} ===");
                for t in esf::experiments::run_jobs(id, quick, jobs).unwrap() {
                    println!("{}", t.render());
                }
            }
            ExitCode::SUCCESS
        }
        Some("sweep") => {
            let Some(path) = args.get("config") else {
                eprintln!(
                    "usage: esf sweep --config <grid.json> [--jobs N] [--intra-jobs N] \
                     [--barrier adaptive|fixed|speculative] [--csv] \
                     [--json <file|->] [--cache-dir <dir>]"
                );
                return ExitCode::FAILURE;
            };
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("esf: reading {path}: {e}");
                    return ExitCode::FAILURE;
                }
            };
            // Model pre-pass: collect every grid problem with its JSON
            // path before attempting expansion.
            let report = esf::check::grid::check_grid_str(&text);
            if !report.ok() {
                eprintln!("{}", report.to_table().render());
                return ExitCode::FAILURE;
            }
            let grid = match esf::sweep::GridSpec::from_json_str(&text) {
                Ok(g) => g,
                Err(e) => {
                    eprintln!("esf: {e}");
                    return ExitCode::FAILURE;
                }
            };
            // CLI --jobs/--intra-jobs override the file's values; 0 = all
            // cores. The two dimensions share one thread budget.
            let jobs = args.u64_or("jobs", grid.jobs as u64) as usize;
            let intra_req = args.u64_or("intra-jobs", grid.intra_jobs as u64) as usize;
            // --barrier: intra-scenario synchronization protocol; byte-
            // identical across modes, so sweep results (and cache cells)
            // are unaffected — only wall-clock moves.
            let barrier = match args.get("barrier") {
                None => esf::engine::parallel::BarrierMode::default(),
                Some(s) => match esf::engine::parallel::BarrierMode::parse(s) {
                    Some(m) => m,
                    None => {
                        eprintln!(
                            "esf: unknown barrier mode '{s}' (adaptive | fixed | speculative)"
                        );
                        return ExitCode::FAILURE;
                    }
                },
            };
            // Fabric-level model checks (routing loop-freedom, link and
            // partition consistency) per distinct fabric shape — workload
            // axes don't change the fabric, so this stays cheap even for
            // huge grids.
            {
                let mut fabrics = std::collections::BTreeSet::new();
                for sc in &grid.scenarios {
                    // Value/capacity checks are pure arithmetic — run them
                    // on every scenario (axis *combinations* can overflow
                    // txn capacity even when each value alone is fine).
                    let cfg_errs = esf::check::check_config(&sc.cfg);
                    if !cfg_errs.is_empty() {
                        let r = esf::check::CheckReport {
                            errors: cfg_errs,
                            subject: format!("scenario '{}'", sc.label),
                        };
                        eprintln!("{}", r.to_table().render());
                        return ExitCode::FAILURE;
                    }
                    let key = format!("{}|{}|{:?}", sc.cfg.topology.name(), sc.cfg.n, sc.cfg.link);
                    if !fabrics.insert(key) {
                        continue;
                    }
                    let mut probe = sc.cfg.clone();
                    probe.intra_jobs = intra_req; // what the run will use
                    let r = esf::check::check_system(&probe);
                    if !r.ok() {
                        eprintln!("esf: scenario '{}' fails model check:", sc.label);
                        eprintln!("{}", r.to_table().render());
                        return ExitCode::FAILURE;
                    }
                }
            }
            let n = grid.scenarios.len();
            // Display-only resolution; the library splits the budget once
            // (run_scenarios_*_opts) from the same raw requests.
            let (across, intra) =
                esf::sweep::split_thread_budget(jobs, intra_req, esf::sweep::available_jobs());
            let workers = across.min(n.max(1));
            eprintln!(
                "esf: sweeping {n} scenarios on {workers} worker thread(s) \
                 x {intra} intra-scenario domain(s)"
            );
            // det-ok: host-side wall-clock for the operator's "sweep
            // finished in N s" report — never feeds simulated time.
            #[allow(clippy::disallowed_methods)]
            let t0 = std::time::Instant::now();
            // --cache-dir: load finished cells, persist new ones as they
            // complete; an interrupted grid resumes from where it died
            // and produces byte-identical output.
            let results = match args.get("cache-dir") {
                Some(dir) => {
                    let cache = match esf::sweep::SweepCache::open(std::path::Path::new(dir)) {
                        Ok(c) => c,
                        Err(e) => {
                            eprintln!("esf: {e}");
                            return ExitCode::FAILURE;
                        }
                    };
                    esf::sweep::run_scenarios_cached_opts_mode(
                        grid.scenarios,
                        jobs,
                        intra_req,
                        barrier,
                        &cache,
                    )
                }
                None => {
                    esf::sweep::run_scenarios_opts_mode(grid.scenarios, jobs, intra_req, barrier)
                }
            };
            let table = esf::sweep::results_table(&results);
            if args.has("csv") {
                println!("{}", table.to_csv());
            } else {
                println!("{}", table.render());
            }
            // --json: machine-readable dump ("-" = stdout).
            if let Some(out) = args.get("json") {
                let mut dump = esf::sweep::results_json(&results).to_string();
                dump.push('\n');
                if out == "-" {
                    print!("{dump}");
                } else if let Err(e) = std::fs::write(out, dump) {
                    eprintln!("esf: writing {out}: {e}");
                    return ExitCode::FAILURE;
                }
            }
            eprintln!("esf: sweep finished in {:.2}s", t0.elapsed().as_secs_f64());
            ExitCode::SUCCESS
        }
        Some("run") => {
            let Some(path) = args.get("config") else {
                eprintln!(
                    "usage: esf run --config <file.json> [--pjrt] [--intra-jobs N] \
                     [--barrier adaptive|fixed|speculative] [--json]\n\
                     \x20              [--checkpoint <file>] [--checkpoint-every <ns>] \
                     [--restore <file>]"
                );
                return ExitCode::FAILURE;
            };
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("esf: reading {path}: {e}");
                    return ExitCode::FAILURE;
                }
            };
            let cfg = match SystemCfg::from_json_str(&text) {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("esf: {e}");
                    return ExitCode::FAILURE;
                }
            };
            // Model pre-pass: prove routing/link/partition/capacity
            // consistency before spending time simulating (the partition
            // preconditions use the intra-jobs count the run will use).
            let intra_cli = args.u64_or("intra-jobs", cfg.intra_jobs as u64) as usize;
            let mut probe = cfg.clone();
            probe.intra_jobs = intra_cli;
            let report = esf::check::check_system(&probe);
            if !report.ok() {
                eprintln!("{}", report.to_table().render());
                return ExitCode::FAILURE;
            }
            let routing = if args.has("pjrt") {
                RoutingSource::Pjrt
            } else {
                RoutingSource::Native
            };
            let mut sys = build_system_with(&cfg, routing, |_i, rc| rc);
            // --restore: splice a checkpoint into the freshly built
            // system. The ESF-C014 rules run first, so a corrupt or
            // incompatible file is rejected with a located error instead
            // of a torn resume; the restore-then-run contract then makes
            // the continued run byte-identical to one that never stopped.
            let restored = match args.get("restore") {
                None => None,
                Some(file) => {
                    let bytes = match std::fs::read(file) {
                        Ok(b) => b,
                        Err(e) => {
                            eprintln!("esf: reading {file}: {e}");
                            return ExitCode::FAILURE;
                        }
                    };
                    let errors = esf::check::check_snapshot(&bytes, Some(&cfg));
                    if !errors.is_empty() {
                        let r = esf::check::CheckReport {
                            errors,
                            subject: format!("snapshot {file}"),
                        };
                        eprintln!("{}", r.to_table().render());
                        return ExitCode::FAILURE;
                    }
                    match sys.engine.restore(&bytes) {
                        Ok(hdr) => Some(hdr),
                        Err(e) => {
                            eprintln!("esf: restoring {file}: {e}");
                            return ExitCode::FAILURE;
                        }
                    }
                }
            };
            // --intra-jobs overrides the config's "intra_jobs"; the
            // partitioned engine always runs to completion, so an
            // explicit --max-events (or a checkpoint stepping loop, or a
            // mid-run restore) keeps the sequential path.
            let intra = intra_cli;
            // --barrier picks the partitioned engine's synchronization
            // protocol; every mode is byte-identical, so this is a pure
            // wall-clock knob (like --intra-jobs, it never enters the
            // config fingerprint).
            let barrier = match args.get("barrier") {
                None => esf::engine::parallel::BarrierMode::default(),
                Some(s) => match esf::engine::parallel::BarrierMode::parse(s) {
                    Some(m) => m,
                    None => {
                        eprintln!(
                            "esf: unknown barrier mode '{s}' (adaptive | fixed | speculative)"
                        );
                        return ExitCode::FAILURE;
                    }
                },
            };
            let ckpt_path = args.get("checkpoint");
            let ckpt_every = match args.get("checkpoint-every").map(str::parse::<f64>) {
                None => None,
                Some(Ok(v)) if v > 0.0 => Some(v),
                Some(_) => {
                    eprintln!("esf: --checkpoint-every needs a positive simulated-ns period");
                    return ExitCode::FAILURE;
                }
            };
            let meta = esf::engine::snapshot::SnapMeta {
                cfg_fingerprint: cfg.fingerprint(),
                prefix_fingerprint: cfg.prefix_fingerprint(),
                prefix_canon: cfg.prefix_canon(),
                quiescent: false,
            };
            let max_events = args.u64_or("max-events", u64::MAX);
            if let Some(every) = ckpt_every {
                // Periodic mid-run checkpoints: sequential stepping loop,
                // one atomic (temp + rename) snapshot write per simulated
                // time slice — a kill at any instant leaves a loadable
                // file no older than one slice.
                if intra != 1 {
                    eprintln!("esf: --checkpoint-every steps sequentially");
                }
                let file = ckpt_path.unwrap_or("esf-checkpoint.snap");
                let every = esf::engine::time::ns(every);
                let mut bound = sys.engine.shared.now + every;
                loop {
                    sys.engine.run_until(bound);
                    bound += every;
                    if sys.engine.shared.queue.is_empty() {
                        break;
                    }
                    if let Err(e) = write_snapshot(file, &sys.engine.snapshot(&meta)) {
                        eprintln!("esf: writing checkpoint {file}: {e}");
                        return ExitCode::FAILURE;
                    }
                    // --max-events approximates a preemption: stop at the
                    // first slice boundary past the budget, checkpoint
                    // already on disk.
                    if sys.engine.events_processed >= max_events {
                        break;
                    }
                }
            } else if let Some(file) = ckpt_path {
                // Bare --checkpoint: one quiescent snapshot at the
                // warm-up boundary — the fork-capable flavor, restorable
                // by run() AND run_partitioned() (and shareable across
                // prefix-compatible configs).
                if restored.is_none() {
                    if intra != 1 {
                        eprintln!("esf: --checkpoint runs sequentially");
                    }
                    sys.engine.run_until_collecting();
                    let qmeta = esf::engine::snapshot::SnapMeta {
                        quiescent: true,
                        ..meta.clone()
                    };
                    if let Err(e) = write_snapshot(file, &sys.engine.snapshot(&qmeta)) {
                        eprintln!("esf: writing checkpoint {file}: {e}");
                        return ExitCode::FAILURE;
                    }
                } else {
                    eprintln!("esf: --restore given; the warm-up boundary already passed, not checkpointing");
                }
                sys.engine.run(max_events);
            } else {
                let quiescent_ok = restored.as_ref().map_or(true, |h| h.quiescent);
                if intra != 1 && args.get("max-events").is_none() && quiescent_ok {
                    sys.engine.run_partitioned_opts(
                        intra,
                        esf::interconnect::WeightModel::Traffic,
                        barrier,
                    );
                } else {
                    if intra != 1 {
                        if quiescent_ok {
                            eprintln!("esf: --max-events given; running sequentially");
                        } else {
                            eprintln!(
                                "esf: mid-run checkpoint restored; continuing sequentially"
                            );
                        }
                    }
                    sys.engine.run(max_events);
                }
            }
            // Cumulative count: a restored run's snapshot carries the
            // prefix's events, so the report matches an uninterrupted run.
            let events = sys.engine.events_processed;
            let a = aggregate(&sys);
            if args.has("json") {
                // Machine-readable results on stdout. `Json::Obj` is a
                // BTreeMap, so keys serialize in canonical (sorted)
                // order — same convention as the sweep results files.
                use esf::util::json::Json;
                let intra_stats = match sys.engine.intra_stats {
                    None => Json::Null,
                    Some(s) => Json::obj(vec![
                        ("channels", Json::Num(s.channels as f64)),
                        (
                            "committed_frontier_advances",
                            Json::Num(s.committed_frontier_advances as f64),
                        ),
                        ("domains", Json::Num(s.domains as f64)),
                        ("elided_tokens", Json::Num(s.elided_tokens as f64)),
                        ("events_exchanged", Json::Num(s.events_exchanged as f64)),
                        ("messages", Json::Num(s.messages as f64)),
                        ("quiet_messages", Json::Num(s.quiet_messages as f64)),
                        ("rollbacks", Json::Num(s.rollbacks as f64)),
                        ("speculative_windows", Json::Num(s.speculative_windows as f64)),
                        ("wasted_events", Json::Num(s.wasted_events as f64)),
                        ("widened_windows", Json::Num(s.widened_windows as f64)),
                        ("windows", Json::Num(s.windows as f64)),
                    ]),
                };
                let doc = Json::obj(vec![
                    ("aggregate_bw_gbps", Json::Num(a.bandwidth_gbps())),
                    ("avg_latency_ns", Json::Num(a.avg_latency_ns())),
                    ("barrier", Json::Str(barrier.name().into())),
                    ("dropped", Json::Num(sys.engine.shared.dropped as f64)),
                    ("events", Json::Num(events as f64)),
                    ("intra_jobs", Json::Num(intra as f64)),
                    ("intra_stats", intra_stats),
                    ("max_latency_ns", Json::Num(a.lat_max_ns)),
                    ("requests", Json::Num(a.completed as f64)),
                    ("schema", Json::Str("esf-run-results/1".into())),
                ]);
                println!("{doc}");
            } else {
                println!("events processed : {events}");
                println!("requests done    : {}", a.completed);
                println!("aggregate bw     : {:.2} GB/s", a.bandwidth_gbps());
                println!("avg latency      : {:.1} ns", a.avg_latency_ns());
                println!("max latency      : {:.1} ns", a.lat_max_ns);
                println!("dropped packets  : {}", sys.engine.shared.dropped);
                for (hops, n, lat, q, sw, bus, dev) in hop_breakdown(&sys) {
                    println!(
                        "  {hops} hops: {n} reqs, {lat:.1} ns (queue {q:.1} switch {sw:.1} bus {bus:.1} device {dev:.1})"
                    );
                }
            }
            ExitCode::SUCCESS
        }
        Some("topo") => {
            let kind = esf::interconnect::TopologyKind::parse(args.str_or("kind", "spine-leaf"))
                .unwrap_or(esf::interconnect::TopologyKind::SpineLeaf);
            let n = args.u64_or("n", 8) as usize;
            let fabric = esf::interconnect::build(kind, n, esf::interconnect::LinkCfg::default());
            let routing = esf::interconnect::Routing::build_bfs(&fabric.topo);
            println!(
                "{}: {} nodes ({} requesters, {} switches, {} memories), {} links",
                kind.name(),
                fabric.topo.n(),
                fabric.requesters.len(),
                fabric.switches.len(),
                fabric.memories.len(),
                fabric.topo.links.len()
            );
            let mut max_d = 0;
            let mut sum = 0u64;
            let mut cnt = 0u64;
            for &r in &fabric.requesters {
                for &m in &fabric.memories {
                    let d = routing.dist(r, m);
                    max_d = max_d.max(d);
                    sum += d as u64;
                    cnt += 1;
                }
            }
            println!(
                "requester->memory hops: avg {:.2}, max {max_d}",
                sum as f64 / cnt as f64
            );
            ExitCode::SUCCESS
        }
        Some("lint") => {
            if args.has("rules") {
                println!("{}", esf::lint::rules_table().render());
                return ExitCode::SUCCESS;
            }
            // Default root: the simulator sources, whether invoked from
            // the workspace top or from rust/.
            let root = match args.get("root") {
                Some(r) => std::path::PathBuf::from(r),
                None => {
                    let ws = std::path::Path::new("rust/src");
                    if ws.is_dir() {
                        ws.to_path_buf()
                    } else {
                        std::path::PathBuf::from("src")
                    }
                }
            };
            let report = match esf::lint::lint_tree(&root) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("esf: lint {}: {e}", root.display());
                    return ExitCode::FAILURE;
                }
            };
            if args.has("json") {
                println!("{}", esf::lint::report_json(&report));
            } else {
                println!("{}", esf::lint::report_table(&report).render());
            }
            if report.ok() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Some("check") => {
            let path = args.get("config").or_else(|| args.positional.first().map(String::as_str));
            let Some(path) = path else {
                eprintln!("usage: esf check <config.json|grid.json|file.snap> [--json]");
                return ExitCode::FAILURE;
            };
            // A .snap file is a binary engine checkpoint: run the
            // ESF-C014 integrity rules (magic/version/digest/decode)
            // instead of the JSON pipeline. Fork-compatibility against a
            // concrete config is checked where it matters — on `esf run
            // --restore` and in the sweep warm-start path.
            if path.ends_with(".snap") {
                let report = match std::fs::read(path) {
                    Err(e) => {
                        eprintln!("esf: reading {path}: {e}");
                        return ExitCode::FAILURE;
                    }
                    Ok(bytes) => esf::check::CheckReport {
                        errors: esf::check::check_snapshot(&bytes, None),
                        subject: format!("snapshot {path}"),
                    },
                };
                if args.has("json") {
                    println!("{}", report.to_json());
                } else if report.ok() {
                    println!("esf check: {} OK ({})", path, report.subject);
                } else {
                    println!("{}", report.to_table().render());
                }
                return if report.ok() {
                    ExitCode::SUCCESS
                } else {
                    ExitCode::FAILURE
                };
            }
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("esf: reading {path}: {e}");
                    return ExitCode::FAILURE;
                }
            };
            // A "sweep" key means a grid document; anything else is a
            // single-system config (same dispatch as run vs sweep).
            let report = match esf::util::json::Json::parse(&text) {
                Err(e) => esf::check::CheckReport {
                    errors: vec![esf::check::CheckError {
                        rule: "ESF-C000",
                        path: format!("byte {}", e.pos),
                        msg: e.msg,
                    }],
                    subject: path.to_string(),
                },
                Ok(j) if j.get("sweep").is_some() => esf::check::grid::check_grid_json(&j),
                // An "op" key means an esfd protocol request (job spec):
                // the same ESF-C016 pass the daemon runs server-side.
                Ok(j) if j.get("op").is_some() => esf::check::job::check_job_json(&j),
                Ok(j) => match SystemCfg::from_json(&j) {
                    Ok(cfg) => esf::check::check_system(&cfg),
                    Err(e) => esf::check::CheckReport {
                        errors: vec![esf::check::CheckError {
                            rule: "ESF-C012",
                            path: "$".to_string(),
                            msg: e.to_string(),
                        }],
                        subject: path.to_string(),
                    },
                },
            };
            if args.has("json") {
                println!("{}", report.to_json());
            } else if report.ok() {
                println!("esf check: {} OK ({})", path, report.subject);
            } else {
                println!("{}", report.to_table().render());
            }
            if report.ok() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Some("submit") => {
            let Some(path) = args.positional.first() else {
                eprintln!("usage: esf submit <grid.json> [--socket <path>] [--json]");
                return ExitCode::FAILURE;
            };
            let text = match std::fs::read_to_string(path) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("esf: reading {path}: {e}");
                    return ExitCode::FAILURE;
                }
            };
            let grid = match esf::util::json::Json::parse(&text) {
                Ok(j) => j,
                Err(e) => {
                    eprintln!("esf: {path}: byte {}: {}", e.pos, e.msg);
                    return ExitCode::FAILURE;
                }
            };
            // Grid validation happens server-side (ESF-C016 + grid
            // rules); a rejection comes back with every rule id and
            // $.grid-rooted locus and is printed verbatim below.
            let socket = daemon_socket(&args);
            match esf::server::client::submit(&socket, &grid) {
                Ok(resp) => {
                    eprintln!(
                        "esf: submitted {} cell(s) as job {}",
                        resp.u64_or("cells", 0),
                        resp.str_or("job", "?")
                    );
                    if args.has("json") {
                        println!("{resp}");
                    } else {
                        // Bare job id on stdout, so scripts can chain
                        // `esf attach $(esf submit grid.json)`.
                        println!("{}", resp.str_or("job", ""));
                    }
                    ExitCode::SUCCESS
                }
                Err(e) => {
                    eprintln!("esf: {e}");
                    ExitCode::FAILURE
                }
            }
        }
        Some("status") => {
            let socket = daemon_socket(&args);
            let filter = args.positional.first().map(String::as_str);
            let resp = match esf::server::client::status(&socket, filter) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("esf: {e}");
                    return ExitCode::FAILURE;
                }
            };
            if args.has("json") {
                println!("{resp}");
                return ExitCode::SUCCESS;
            }
            let mut t = esf::util::table::Table::new(
                "esfd jobs",
                &["job", "phase", "cells", "done", "cached", "granted", "error"],
            );
            if let Some(jobs) = resp.get("jobs").and_then(esf::util::json::Json::as_arr) {
                for j in jobs {
                    t.row(&[
                        j.str_or("id", "?").to_string(),
                        j.str_or("phase", "?").to_string(),
                        j.u64_or("cells", 0).to_string(),
                        j.u64_or("done_cells", 0).to_string(),
                        j.u64_or("cached_cells", 0).to_string(),
                        j.u64_or("granted", 0).to_string(),
                        j.str_or("error", "").to_string(),
                    ]);
                }
            }
            t.note(format!(
                "budget {} thread(s), {} in use (peak {}), {} job(s) running (peak {})",
                resp.u64_or("budget", 0),
                resp.u64_or("in_use", 0),
                resp.u64_or("peak_in_use", 0),
                resp.u64_or("running", 0),
                resp.u64_or("peak_running", 0)
            ));
            if args.has("csv") {
                println!("{}", t.to_csv());
            } else {
                println!("{}", t.render());
            }
            ExitCode::SUCCESS
        }
        Some("attach") => {
            let Some(job) = args.positional.first() else {
                eprintln!("usage: esf attach <job> [--socket <path>] [--csv] [--json <file|->]");
                return ExitCode::FAILURE;
            };
            let socket = daemon_socket(&args);
            // Per-cell progress goes to stderr as rows stream in
            // (completion order); stdout stays reserved for the final
            // assembled output.
            let results = match esf::server::client::attach(&socket, job, |idx, cached, r| {
                eprintln!(
                    "esf: cell {idx} done{}: {}",
                    if cached { " (cached)" } else { "" },
                    r.label
                );
            }) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("esf: {e}");
                    return ExitCode::FAILURE;
                }
            };
            // Identical rendering path to one-shot `esf sweep`: same
            // table/CSV on stdout, same trailing-newline JSON dump — the
            // byte-identity contract the daemon integration tests pin.
            let table = esf::sweep::results_table(&results);
            if args.has("csv") {
                println!("{}", table.to_csv());
            } else {
                println!("{}", table.render());
            }
            if let Some(out) = args.get("json") {
                let mut dump = esf::sweep::results_json(&results).to_string();
                dump.push('\n');
                if out == "-" {
                    print!("{dump}");
                } else if let Err(e) = std::fs::write(out, dump) {
                    eprintln!("esf: writing {out}: {e}");
                    return ExitCode::FAILURE;
                }
            }
            ExitCode::SUCCESS
        }
        Some("shutdown") => {
            let socket = daemon_socket(&args);
            match esf::server::client::shutdown(&socket) {
                Ok(()) => {
                    eprintln!("esf: daemon on {} is draining and will exit", socket.display());
                    ExitCode::SUCCESS
                }
                Err(e) => {
                    eprintln!("esf: {e}");
                    ExitCode::FAILURE
                }
            }
        }
        Some("apsp-check") => {
            let n = args.u64_or("n", 64) as usize;
            let kind = esf::interconnect::TopologyKind::parse(args.str_or("kind", "spine-leaf"))
                .unwrap_or(esf::interconnect::TopologyKind::SpineLeaf);
            let fabric =
                esf::interconnect::build(kind, n / 4, esf::interconnect::LinkCfg::default());
            let nodes = fabric.topo.n();
            let adj = fabric.topo.adjacency_matrix(esf::runtime::UNREACH);
            let native = esf::runtime::apsp_native(&adj, nodes);
            match esf::runtime::Runtime::load_default() {
                Ok(mut rt) => match rt.apsp(&adj, nodes) {
                    Ok(pjrt) => {
                        let mismatches = native
                            .iter()
                            .zip(&pjrt)
                            .filter(|(a, b)| (**a - **b).abs() > 1e-3)
                            .count();
                        println!(
                            "fabric {} nodes: PJRT Pallas APSP vs native: {} mismatches / {} entries",
                            nodes,
                            mismatches,
                            native.len()
                        );
                        if mismatches == 0 {
                            println!("apsp-check OK");
                            ExitCode::SUCCESS
                        } else {
                            ExitCode::FAILURE
                        }
                    }
                    Err(e) => {
                        eprintln!("esf: PJRT APSP failed: {e}");
                        ExitCode::FAILURE
                    }
                },
                Err(e) => {
                    eprintln!("esf: PJRT unavailable: {e}");
                    ExitCode::FAILURE
                }
            }
        }
        _ => {
            eprintln!(
                "esf — extensible simulation framework for CXL-enabled systems\n\
                 commands: list | exp <id> | all | run --config <f> | sweep --config <grid> | topo | apsp-check\n\
                 \x20         lint [--root <dir>] [--json] [--rules] | check <config|grid|job|snapshot> [--json]\n\
                 \x20         submit <grid> | status [job] | attach <job> | shutdown   (daemon: esfd, --socket <path>)\n\
                 flags: --full (paper-scale runs), --csv, --pjrt, --jobs N (parallel sweeps; 0 = all cores),\n\
                        --intra-jobs N (partitioned event domains inside one simulation; byte-identical),\n\
                        --barrier adaptive|fixed|speculative (domain sync protocol; byte-identical, wall-clock only),\n\
                        --json <file|-> (sweep result dump; bare --json on run/check = JSON to stdout,\n\
                        run output includes the intra_stats exchange accounting), --cache-dir <dir> (sweep cache/resume),\n\
                        --checkpoint <file> / --checkpoint-every <ns> / --restore <file> (resumable run checkpoints)"
            );
            ExitCode::FAILURE
        }
    }
}
