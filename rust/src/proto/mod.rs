//! CXL protocol model: sub-protocols, opcodes, packet structure and flit
//! sizing.
//!
//! ESF models the transaction-layer view of the three CXL sub-protocols
//! (CXL.io / CXL.cache / CXL.mem). Requests and responses are carried as
//! `Packet`s over the interconnect layer; the link/physical behaviour
//! (serialization at link bandwidth, duplex, header overhead) is modelled
//! by `interconnect::links`.

use crate::engine::time::Ps;

/// Node identifier in the interconnect topology (requester / switch /
/// memory endpoint). PBR edge-port ids map 1:1 onto these in ESF.
pub type NodeId = usize;

/// CXL sub-protocol a packet travels on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SubProtocol {
    /// PCIe-compatible I/O (enumeration, configuration).
    Io,
    /// Device -> host coherent access.
    Cache,
    /// Host -> device memory semantics; also carries the dedicated
    /// BISnp/BIRsp channels in CXL 3.x HDM-DB mode.
    Mem,
}

/// Transaction-layer opcodes (subset sufficient for the paper's studies).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Opcode {
    /// CXL.mem read request (MemRd): header downstream, data upstream.
    MemRd,
    /// CXL.mem write request (MemWr): header+data downstream, ack upstream.
    MemWr,
    /// Read response with payload (MemData).
    MemRdData,
    /// Write completion (Cmp).
    MemWrCmp,
    /// Back-invalidate snoop, HDM-DB device-managed coherence. `len` is the
    /// InvBlk run length (1 = plain BISnp, 2..=4 = InvBlk of contiguous
    /// cachelines).
    BISnp { len: u8 },
    /// Back-invalidate response; `dirty` carries a writeback payload.
    BIRsp { dirty: bool },
    /// CXL.io configuration read/write (used by enumeration paths).
    IoCfg,
}

impl Opcode {
    pub fn protocol(&self) -> SubProtocol {
        match self {
            Opcode::IoCfg => SubProtocol::Io,
            // BISnp/BIRsp ride the two dedicated CXL.mem channels (CXL 3.1
            // HDM-DB), NOT CXL.cache — see paper §II-A.
            _ => SubProtocol::Mem,
        }
    }

    pub fn is_request(&self) -> bool {
        matches!(self, Opcode::MemRd | Opcode::MemWr | Opcode::BISnp { .. } | Opcode::IoCfg)
    }

    pub fn is_response(&self) -> bool {
        matches!(self, Opcode::MemRdData | Opcode::MemWrCmp | Opcode::BIRsp { .. })
    }
}

/// Cacheline granularity of CXL.cache / CXL.mem transfers.
pub const CACHELINE: u64 = 64;

/// One operation of a replayable memory trace (trace-based requester mode
/// and the gem5-substitute CPU frontend share this record).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceOp {
    pub addr: u64,
    pub is_write: bool,
    /// Issue gap after the previous op (0 = back-to-back).
    pub gap_ps: u64,
}

/// Flit/packet sizing for one message on a link.
///
/// CXL 3.x uses 256B flits over PCIe 6.0 FLIT mode; header overhead
/// (protocol + CRC + FEC) is configurable as the paper's evaluation treats
/// it as a swept parameter ("normalized to payload length", Fig 16/17).
#[derive(Clone, Copy, Debug)]
pub struct WireSize {
    pub header_bytes: u64,
    pub payload_bytes: u64,
}

impl WireSize {
    pub fn total(&self) -> u64 {
        self.header_bytes + self.payload_bytes
    }
}

/// Latency breakdown accumulated along the packet's path (Fig 11's grouped
/// queue/switch/bus decomposition).
#[derive(Clone, Copy, Debug, Default)]
pub struct Breakdown {
    pub queue_ps: Ps,
    pub switch_ps: Ps,
    pub bus_ps: Ps,
    pub device_ps: Ps,
    pub hops: u32,
}

/// A transaction-layer message in flight.
#[derive(Clone, Debug)]
pub struct Packet {
    /// Unique transaction id (request and its response share it).
    pub id: u64,
    pub op: Opcode,
    /// Issuing node (requester or DCOH for BISnp).
    pub src: NodeId,
    /// Destination edge port / node.
    pub dst: NodeId,
    /// Physical address of the access (HDM address space).
    pub addr: u64,
    /// Payload size on the wire for this message (0 for header-only).
    pub payload_bytes: u64,
    /// Issue timestamp of the original request (for end-to-end latency).
    pub issued_at: Ps,
    /// Node currently holding the packet (updated per hop).
    pub at: NodeId,
    /// True when the requester caches this line, i.e. the access must be
    /// tracked by the destination's device coherency agent (DCOH).
    pub coherent: bool,
    /// Posted write: no completion message (background write-backs).
    pub posted: bool,
    pub breakdown: Breakdown,
}

impl Packet {
    pub fn request(
        id: u64,
        op: Opcode,
        src: NodeId,
        dst: NodeId,
        addr: u64,
        issued_at: Ps,
    ) -> Packet {
        let payload = match op {
            Opcode::MemWr => CACHELINE,
            _ => 0,
        };
        Packet {
            id,
            op,
            src,
            dst,
            addr,
            payload_bytes: payload,
            issued_at,
            at: src,
            coherent: false,
            posted: false,
            breakdown: Breakdown::default(),
        }
    }

    /// Build the response for this request, sent dst -> src.
    pub fn response(&self, dirty_wb: bool) -> Packet {
        let (op, payload) = match self.op {
            Opcode::MemRd => (Opcode::MemRdData, CACHELINE),
            Opcode::MemWr => (Opcode::MemWrCmp, 0),
            Opcode::BISnp { .. } => (
                Opcode::BIRsp { dirty: dirty_wb },
                if dirty_wb { CACHELINE } else { 0 },
            ),
            Opcode::IoCfg => (Opcode::IoCfg, 0),
            _ => panic!("response() on a response packet: {:?}", self.op),
        };
        Packet {
            id: self.id,
            op,
            src: self.dst,
            dst: self.src,
            addr: self.addr,
            payload_bytes: payload,
            issued_at: self.issued_at,
            at: self.dst,
            coherent: self.coherent,
            posted: false,
            breakdown: self.breakdown,
        }
    }

    pub fn is_write_kind(&self) -> bool {
        matches!(self.op, Opcode::MemWr | Opcode::MemWrCmp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_has_no_request_payload_but_data_response() {
        let p = Packet::request(1, Opcode::MemRd, 0, 5, 0x1000, 0);
        assert_eq!(p.payload_bytes, 0);
        let r = p.response(false);
        assert_eq!(r.op, Opcode::MemRdData);
        assert_eq!(r.payload_bytes, CACHELINE);
        assert_eq!((r.src, r.dst), (5, 0));
        assert_eq!(r.id, p.id);
    }

    #[test]
    fn write_carries_payload_down_ack_up() {
        let p = Packet::request(2, Opcode::MemWr, 1, 6, 0x40, 0);
        assert_eq!(p.payload_bytes, CACHELINE);
        let r = p.response(false);
        assert_eq!(r.op, Opcode::MemWrCmp);
        assert_eq!(r.payload_bytes, 0);
    }

    #[test]
    fn bisnp_rides_mem_channels() {
        // CXL 3.1: BISnp/BIRsp are CXL.mem channels, not CXL.cache.
        assert_eq!(Opcode::BISnp { len: 1 }.protocol(), SubProtocol::Mem);
        assert_eq!(Opcode::BIRsp { dirty: true }.protocol(), SubProtocol::Mem);
    }

    #[test]
    fn dirty_birsp_carries_writeback() {
        let snp = Packet::request(3, Opcode::BISnp { len: 2 }, 7, 2, 0x80, 10);
        let rsp = snp.response(true);
        assert_eq!(rsp.payload_bytes, CACHELINE);
        let clean = snp.response(false);
        assert_eq!(clean.payload_bytes, 0);
    }
}
