//! Partitioned event domains: deterministic intra-scenario parallelism.
//!
//! [`run_partitioned`] splits one simulation across worker threads. The
//! fabric is graph-cut into event domains (`interconnect::Partition`);
//! each domain owns its nodes' components, a private ladder [`EventQueue`],
//! a private `NetState` shard (it only ever touches the link directions
//! whose **sender** lives in the domain — every `transmit` happens on the
//! forwarding node's side), and the per-node schedule/txn counters of its
//! nodes. Cross-domain packets travel through bounded SPSC channels and
//! are exchanged at a conservative barrier.
//!
//! ## Why the result is byte-identical to the sequential engine
//!
//! * Every event's key `(time, src, seq)` is minted from the scheduling
//!   node's private counter — identical in both engines as long as each
//!   node's handlers run in the same order with the same inputs.
//! * The barrier advances in windows `[.., tmin + lookahead)` where
//!   `tmin` is the globally earliest pending event and `lookahead` the
//!   minimum propagation latency over cut links. Any cross-domain packet
//!   sent during a window departs at `>= tmin`, so it arrives at
//!   `>= tmin + lookahead` — never inside the window. Hence when a domain
//!   drains its window in key order, it interleaves its own events
//!   exactly as the sequential engine's global key order would have.
//! * Handler side effects stay inside the domain: components, owned link
//!   directions, per-node counters. Half-duplex links (shared medium) and
//!   zero-latency links are never cut, by construction of the partition.
//!
//! Warm-up runs sequentially: the epoch flip (`warmup_done`) is a global
//! zero-latency effect that no conservative lookahead covers, so the
//! engine executes the exact sequential prefix until collection starts,
//! then splits. The split point is identical in both engines, so this
//! costs determinism nothing (and Amdahl very little — warm-up is a small
//! request fraction).
//!
//! The protocol was additionally validated against a Python model of this
//! exact design (sequential vs partitioned on 400 randomized fabrics with
//! zero-latency links, link queueing state, and zero-delay self events —
//! per-node event orders, states, and link accounting all byte-identical).

use super::{Component, Engine, Ev, EventQueue, Shared};
use crate::engine::time::Ps;
use crate::interconnect::{Dir, Partition};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;

/// Coordinator -> worker command: drain events strictly before the window
/// end, then exchange; or stop.
enum Cmd {
    Window(Ps),
    Stop,
}

/// One window's worth of cross-domain events for one destination.
type Batch = Vec<Ev>;
type BatchTx = SyncSender<Batch>;
type BatchRx = Receiver<Batch>;
/// Full-length component table; only the owning domain's nodes are `Some`.
type CompTable = Vec<Option<Box<dyn Component>>>;

/// One event domain's runtime state, moved onto its worker thread.
struct DomainRunner {
    dom: usize,
    shared: Shared,
    comps: CompTable,
    domain_of: Arc<Vec<u32>>,
    processed: u64,
}

impl DomainRunner {
    /// Drain every local event strictly before `end` in canonical key
    /// order. Handlers may schedule further local events inside the
    /// window (zero-delay self events included) — the loop picks them up.
    fn drain_window(&mut self, end: Ps) {
        while let Some(ev) = self.shared.queue.pop_if_before(end) {
            debug_assert!(ev.time >= self.shared.now, "time went backwards");
            self.shared.now = ev.time;
            self.shared.cur = ev.target;
            self.comps[ev.target]
                .as_mut()
                .expect("event targeted a foreign node")
                .handle(ev.payload, &mut self.shared);
            self.processed += 1;
        }
    }
}

/// Worker thread body: lockstep windows. Per window: drain, send one
/// (possibly empty) batch to every peer, receive one from every peer,
/// report the next local event time. The all-to-all is deadlock-free:
/// every worker sends all its batches before receiving any, and each pair
/// channel carries exactly one message per window.
fn worker_loop(
    mut r: DomainRunner,
    ndom: usize,
    cmd_rx: Receiver<Cmd>,
    out_tx: Vec<Option<BatchTx>>,
    in_rx: Vec<Option<BatchRx>>,
    report_tx: Sender<(usize, Option<Ps>)>,
) -> DomainRunner {
    let report = |r: &mut DomainRunner| {
        report_tx
            .send((r.dom, r.shared.queue.next_time()))
            .expect("coordinator alive");
    };
    report(&mut r);
    loop {
        match cmd_rx.recv().expect("coordinator alive") {
            Cmd::Stop => break,
            Cmd::Window(end) => {
                r.drain_window(end);
                let mut batches: Vec<Batch> = (0..ndom).map(|_| Vec::new()).collect();
                for ev in r.shared.take_outbound() {
                    batches[r.domain_of[ev.target] as usize].push(ev);
                }
                for (j, batch) in batches.into_iter().enumerate() {
                    if j != r.dom {
                        out_tx[j].as_ref().expect("peer channel").send(batch).expect("peer alive");
                    }
                }
                for (j, rx) in in_rx.iter().enumerate() {
                    if j == r.dom {
                        continue;
                    }
                    for ev in rx.as_ref().expect("peer channel").recv().expect("peer alive") {
                        r.shared.queue.push(ev);
                    }
                }
                report(&mut r);
            }
        }
    }
    r
}

/// Entry point behind [`Engine::run_partitioned`]. Runs the engine to
/// completion on up to `intra_jobs` worker threads (0 = all cores) and
/// returns the number of events processed. Falls back to the sequential
/// loop when the fabric cannot be cut or one job is requested.
pub fn run_partitioned(engine: &mut Engine, intra_jobs: usize) -> u64 {
    let jobs = if intra_jobs == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        intra_jobs
    };
    if jobs <= 1 {
        return engine.run(u64::MAX);
    }
    let part = Partition::compute(&engine.shared.topo, jobs);
    if part.n_domains() <= 1 {
        return engine.run(u64::MAX);
    }
    assert!(
        !engine.started,
        "run_partitioned must be an engine's first (and only) run"
    );

    // ---- Phase A: exact sequential prefix until the epoch opens.
    engine.start_components();
    let mut prefix = 0u64;
    while !engine.shared.collecting {
        let Some(ev) = engine.shared.queue.pop() else { break };
        debug_assert!(ev.time >= engine.shared.now, "time went backwards");
        engine.shared.now = ev.time;
        engine.shared.cur = ev.target;
        engine.components[ev.target].handle(ev.payload, &mut engine.shared);
        prefix += 1;
    }
    let n_nodes = engine.shared.topo.n();
    engine.shared.set_origin(n_nodes);
    if engine.shared.queue.is_empty() {
        // Drained before (or exactly when) collection started.
        let now = engine.shared.now;
        engine.shared.net.end_epoch(now);
        engine.events_processed += prefix;
        return prefix;
    }

    // ---- Split: per-domain queues, components, and Shared shards.
    let ndom = part.n_domains();
    let domain_of: Arc<Vec<u32>> = Arc::new(part.domain_of.clone());
    let mut queues: Vec<EventQueue> = (0..ndom).map(|_| EventQueue::default()).collect();
    while let Some(ev) = engine.shared.queue.pop() {
        queues[domain_of[ev.target] as usize].push(ev);
    }
    let mut comp_split: Vec<CompTable> =
        (0..ndom).map(|_| (0..n_nodes).map(|_| None).collect()).collect();
    for (id, c) in engine.components.drain(..).enumerate() {
        comp_split[domain_of[id] as usize][id] = Some(c);
    }
    let mut runners: Vec<DomainRunner> = Vec::with_capacity(ndom);
    for (dom, (queue, comps)) in queues.into_iter().zip(comp_split).enumerate() {
        runners.push(DomainRunner {
            dom,
            shared: engine
                .shared
                .domain_shard(queue, dom as u32, Arc::clone(&domain_of)),
            comps,
            domain_of: Arc::clone(&domain_of),
            processed: 0,
        });
    }

    // ---- Channels: pairwise SPSC batches + command/report star.
    let mut out_tx: Vec<Vec<Option<BatchTx>>> =
        (0..ndom).map(|_| (0..ndom).map(|_| None).collect()).collect();
    let mut in_rx: Vec<Vec<Option<BatchRx>>> =
        (0..ndom).map(|_| (0..ndom).map(|_| None).collect()).collect();
    for i in 0..ndom {
        for j in 0..ndom {
            if i != j {
                // Capacity 2 > the single in-flight batch per window.
                let (tx, rx) = sync_channel(2);
                out_tx[i][j] = Some(tx);
                in_rx[j][i] = Some(rx);
            }
        }
    }
    let (report_tx, report_rx) = channel::<(usize, Option<Ps>)>();
    let mut cmd_txs: Vec<SyncSender<Cmd>> = Vec::with_capacity(ndom);
    let mut cmd_rxs: Vec<Receiver<Cmd>> = Vec::with_capacity(ndom);
    for _ in 0..ndom {
        let (tx, rx) = sync_channel(1);
        cmd_txs.push(tx);
        cmd_rxs.push(rx);
    }

    // ---- Run: workers in lockstep windows, coordinator on this thread.
    let lookahead = part.lookahead;
    let runners: Vec<DomainRunner> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(ndom);
        let mut out_tx = out_tx;
        let mut in_rx = in_rx;
        let mut cmd_rxs = cmd_rxs;
        for r in runners.into_iter().rev() {
            let txs = out_tx.pop().expect("tx row per domain");
            let rxs = in_rx.pop().expect("rx row per domain");
            let cmd = cmd_rxs.pop().expect("cmd channel per domain");
            let rep = report_tx.clone();
            handles.push(s.spawn(move || worker_loop(r, ndom, cmd, txs, rxs, rep)));
        }
        handles.reverse(); // spawned in reverse domain order
        loop {
            let mut tmin: Option<Ps> = None;
            for _ in 0..ndom {
                let (_, next) = report_rx.recv().expect("worker alive");
                tmin = match (tmin, next) {
                    (a, None) => a,
                    (None, b) => b,
                    (Some(a), Some(b)) => Some(a.min(b)),
                };
            }
            match tmin {
                None => {
                    for tx in &cmd_txs {
                        tx.send(Cmd::Stop).expect("worker alive");
                    }
                    break;
                }
                Some(t) => {
                    let end = t.saturating_add(lookahead);
                    for tx in &cmd_txs {
                        tx.send(Cmd::Window(end)).expect("worker alive");
                    }
                }
            }
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked"))
            .collect()
    });

    // ---- Merge: components back in node order, owned link directions,
    // per-node counters, drop counts, global clock.
    let dir_owner: Vec<[u32; 2]> = engine
        .shared
        .topo
        .links
        .iter()
        .map(|l| [domain_of[l.a], domain_of[l.b]])
        .collect();
    let mut comps_back: CompTable = (0..n_nodes).map(|_| None).collect();
    let mut total = 0u64;
    let mut max_now = engine.shared.now;
    for mut r in runners {
        total += r.processed;
        max_now = max_now.max(r.shared.now);
        engine.shared.dropped += r.shared.dropped;
        let dom = r.dom as u32;
        debug_assert_eq!(Dir::AtoB as usize, 0);
        engine
            .shared
            .net
            .adopt_owned(&r.shared.net, |link, dir| dir_owner[link][dir as usize] == dom);
        for &node in &part.domains[r.dom] {
            engine.shared.sched_seq[node] = r.shared.sched_seq[node];
            engine.shared.txn_seq[node] = r.shared.txn_seq[node];
            comps_back[node] = r.comps[node].take();
        }
    }
    engine.components = comps_back
        .into_iter()
        .map(|c| c.expect("every component returns from its domain"))
        .collect();
    engine.shared.now = max_now;
    engine.shared.net.end_epoch(max_now);
    engine.events_processed += prefix + total;
    prefix + total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Payload, Shared};
    use crate::interconnect::{LinkCfg, NodeKind, Routing, Strategy, Topology};
    use crate::proto::{NodeId, Opcode, Packet};
    use std::any::Any;

    /// Ping-pong component: every node fires requests at a deterministic
    /// subset of peers and bounces responses, recording each handled
    /// event's (time, src-key) so the processing ORDER itself can be
    /// compared between engines — stricter than comparing aggregates.
    struct Chatter {
        id: NodeId,
        n: usize,
        rounds: u64,
        log: Vec<(Ps, u64)>,
    }

    impl Component for Chatter {
        fn start(&mut self, ctx: &mut Shared) {
            ctx.after((self.id as u64 % 3) * 100, self.id, Payload::Timer(0, 0));
        }
        fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
            match payload {
                Payload::Timer(round, _) => {
                    self.log.push((ctx.now, round));
                    if round >= self.rounds {
                        return;
                    }
                    let dst = (self.id + 1 + (round as usize % (self.n - 1))) % self.n;
                    let id = ctx.txn_id();
                    let mut pkt =
                        Packet::request(id, Opcode::MemRd, self.id, dst, round, ctx.now);
                    pkt.payload_bytes = 64;
                    ctx.forward(pkt, 0);
                    // Zero-delay self event: stresses same-window re-pops.
                    ctx.after(0, self.id, Payload::Timer(round + 1, 1));
                }
                Payload::Packet(pkt) => {
                    self.log.push((ctx.now, 1_000_000 + pkt.addr));
                    if matches!(pkt.op, Opcode::MemRd) && pkt.addr % 2 == 0 {
                        let rsp = pkt.response(false);
                        ctx.forward(rsp, 50);
                    }
                }
                _ => {}
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    /// Ring of directly linked nodes — every node pair routable, cuts
    /// guaranteed for >= 2 domains.
    fn chatter_engine(n: usize, rounds: u64) -> Engine {
        let mut t = Topology::new();
        for i in 0..n {
            t.add_node(format!("n{i}"), NodeKind::Switch);
        }
        for i in 0..n {
            t.add_link(i, (i + 1) % n, LinkCfg::default());
        }
        let routing = Routing::build_bfs(&t);
        let mut e = Engine::new(Shared::new(t, routing, Strategy::Oblivious));
        for i in 0..n {
            e.register(Box::new(Chatter {
                id: i,
                n,
                rounds,
                log: Vec::new(),
            }));
        }
        e
    }

    fn logs(e: &Engine) -> Vec<Vec<(Ps, u64)>> {
        (0..e.shared.topo.n())
            .map(|i| e.component::<Chatter>(i).unwrap().log.clone())
            .collect()
    }

    #[test]
    fn partitioned_matches_sequential_event_orders_exactly() {
        for jobs in [2, 3, 4, 8] {
            let mut seq = chatter_engine(12, 40);
            let n_seq = seq.reference_sequential();
            let mut par = chatter_engine(12, 40);
            let n_par = par.run_partitioned(jobs);
            assert_eq!(n_seq, n_par, "event counts diverged at jobs={jobs}");
            assert_eq!(
                logs(&seq),
                logs(&par),
                "per-node event order diverged at jobs={jobs}"
            );
            assert_eq!(seq.shared.now, par.shared.now);
            assert_eq!(seq.shared.dropped, par.shared.dropped);
            for l in 0..seq.shared.topo.links.len() {
                assert_eq!(
                    seq.shared.net.payload_bytes(l),
                    par.shared.net.payload_bytes(l),
                    "link {l} payload diverged at jobs={jobs}"
                );
                assert_eq!(
                    seq.shared.net.bus_utility(l).to_bits(),
                    par.shared.net.bus_utility(l).to_bits(),
                    "link {l} utility diverged at jobs={jobs}"
                );
            }
        }
    }

    #[test]
    fn single_job_partitioned_is_the_sequential_path() {
        let mut a = chatter_engine(6, 10);
        let na = a.run(u64::MAX);
        let mut b = chatter_engine(6, 10);
        let nb = b.run_partitioned(1);
        assert_eq!(na, nb);
        assert_eq!(logs(&a), logs(&b));
    }

    #[test]
    fn empty_engine_partitioned_run_terminates() {
        // No components schedule anything after start when rounds == 0
        // budget is still >= 1 event per node (the initial timer).
        let mut e = chatter_engine(4, 0);
        let n = e.run_partitioned(4);
        assert!(n >= 4);
        assert!(e.shared.queue.is_empty());
    }
}
