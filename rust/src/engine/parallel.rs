//! Partitioned event domains: deterministic intra-scenario parallelism.
//!
//! [`run_partitioned`] splits one simulation across worker threads. The
//! fabric is graph-cut into event domains (`interconnect::Partition`,
//! balanced by expected traffic — spine switches count for more than leaf
//! endpoints — with the PR 4 node-count rule kept as the A/B oracle);
//! each domain owns its nodes' components, a private ladder [`EventQueue`],
//! a private `NetState` shard (it only ever touches the link directions
//! whose **sender** lives in the domain — every `transmit` happens on the
//! forwarding node's side), and the per-node schedule/txn counters of its
//! nodes. Cross-domain packets travel through bounded SPSC channels and
//! are exchanged at a conservative barrier.
//!
//! ## Sparse neighbor exchange
//!
//! Cross-domain events are only ever born from a `forward` over a cut
//! link: components schedule their own timers/self-events locally, and
//! contracted links (half-duplex, zero-latency) never cross a cut. So a
//! domain can only ever need to talk to the domains it shares cut links
//! with — the exchange opens channels for exactly those pairs
//! ([`Partition::exchange_peers`]) instead of the previous all-to-all
//! mesh. On a spine-leaf cut the peer graph is nearly a star around the
//! spine domains, so channel count drops from `ndom * (ndom - 1)` to
//! roughly `2 * ndom`. The accounting lands in [`IntraStats`]
//! (`Engine::intra_stats`).
//!
//! ## Barrier modes
//!
//! [`BarrierMode::FixedWindow`] is the PR 4/5 lockstep protocol: every
//! round, every domain drains `[.., tmin + lookahead)` and sends exactly
//! one message per neighbor channel (a compact [`Msg::Quiet`] token when
//! it has no traffic), then receives one from each. Simple, but on a
//! 162-node spine-leaf 8-domain run more than half the barrier traffic
//! is quiet tokens, and event-free stretches still cost one lookahead
//! per round.
//!
//! [`BarrierMode::Adaptive`] (the default) removes both costs without
//! touching the event order:
//!
//! * **Adaptive window widening.** The coordinator keeps, per domain,
//!   the earliest time it could possibly act: `seed[d] = min(next local
//!   event, earliest in-flight batch headed to d)`. A min-plus
//!   relaxation of the seeds over the cut-neighbor graph (edge weight =
//!   minimum cut-link latency between the pair, [`Partition::
//!   horizon_graph`]) yields `dist[d]`, the earliest time domain `d`
//!   could process *any* event this round — then `H[d] = min over peers
//!   p of (dist[p] + lat(p, d))` is a certified lower bound on the next
//!   inbound arrival, covering multi-hop relays (a relay chain through
//!   `p` only adds latency). Each domain drains `[.., H[d])`: a domain
//!   whose neighbors are quiet far into the future jumps many
//!   lookaheads in one barrier round (`IntraStats::widened_windows`).
//!   `H[d] >= tmin + lookahead` always, so no round is ever narrower
//!   than the fixed-window protocol's.
//! * **Quiet-run elision.** A domain with nothing to do this round
//!   (`seed[d] >= H[d]`, e.g. an empty queue) is simply not scheduled:
//!   its one report already published its horizon, and the coordinator
//!   leaves it parked until a neighbor actually sends it a batch. Only
//!   non-empty event batches ever cross a channel — quiet tokens are
//!   elided entirely (`IntraStats::elided_tokens`), and batches are
//!   delivered at the *start* of the receiver's next round, before its
//!   drain. Senders report the minimum event time of each batch so the
//!   coordinator can fold in-flight events into the seeds.
//!
//! [`BarrierMode::Speculative`] layers bounded optimism on top of the
//! adaptive protocol without touching its certified schedule:
//!
//! * **Off-critical-path speculation.** After a domain finishes its
//!   certified round — drain to the granted horizon, send the certified
//!   outbound batches, report — it keeps executing local events up to
//!   `end + speculation_window` *after* the report, while the
//!   coordinator and the other domains are still working. Outbound
//!   events born past the horizon are held (never sent), so no other
//!   domain can observe speculative state; the coordinator sees exactly
//!   the adaptive trace (same seeds, same horizons, same batches), which
//!   is why the committed schedule — and therefore the output — is
//!   byte-identical to [`BarrierMode::Adaptive`] by construction.
//! * **Deterministic rollback.** Before speculating, the domain captures
//!   an in-memory checkpoint of its shard (queue with exact keys, owned
//!   components, net shard, per-node counters) at the certified
//!   frontier — so the capture point dominates every optimistically
//!   executed event, the first ESF-C015 side-condition. Next round, the
//!   speculation is adopted iff no delivered batch carries an event
//!   behind the speculative frontier (a *straggler*) and the new
//!   certified horizon covers the whole speculated range; otherwise the
//!   domain restores the checkpoint and re-executes deterministically
//!   ([`IntraStats::rollbacks`] / [`IntraStats::wasted_events`]). Both
//!   triggers are pure functions of the deterministic event flow —
//!   never of thread timing — so the rollback counts themselves are
//!   reproducible.
//! * **Commit frontier.** The coordinator tracks the global minimum of
//!   every domain's earliest pending/in-flight event time — the
//!   deterministic GVT analogue. It is monotone (every granted horizon
//!   exceeds it by at least one lookahead) and checkpoints are only
//!   recaptured when a domain's certified frontier has advanced past
//!   its previous capture, so committed state is never recaptured
//!   ([`IntraStats::committed_frontier_advances`]; monotonicity is the
//!   second ESF-C015 side-condition).
//!
//! Speculation wins when cuts are quiet: wide adaptive horizons adopt
//! almost every speculated stint, and the speculated work overlaps
//! barrier coordination instead of extending it. On traffic-heavy cuts
//! horizons stay near one lookahead, stints rarely get covered, and the
//! rollback/recapture overhead makes Speculative *lose* to Adaptive —
//! measured honestly in `BENCH_hotpath.json` (`intra_speculative`),
//! which is why Adaptive stays the default.
//!
//! ## Why the result is byte-identical to the sequential engine
//!
//! * Every event's key `(time, src, seq)` is minted from the scheduling
//!   node's private counter — identical in both engines as long as each
//!   node's handlers run in the same order with the same inputs.
//! * Fixed windows: the barrier advances in windows `[.., tmin +
//!   lookahead)` where `tmin` is the globally earliest pending event and
//!   `lookahead` the minimum propagation latency over cut links
//!   (saturating add: disconnected multi-domain fabrics have no cut
//!   links and an unbounded `Ps::MAX` lookahead). Any cross-domain
//!   packet sent during a window departs at `>= tmin`, so it arrives at
//!   `>= tmin + lookahead` — never inside the window.
//! * Adaptive windows generalize the same argument per domain: every
//!   event domain `p` processes this round departs at `>= seed[p] >=
//!   dist[p]`, so anything it sends (or relays) toward `d` arrives at
//!   `>= dist[p] + lat(p, d) >= H[d]` — never inside `d`'s window
//!   `[.., H[d])`. Hence when a domain drains its window in key order,
//!   it interleaves its events exactly as the sequential engine's
//!   global key order would have. The worker asserts the property at
//!   every delivery (no batch event behind the receiver's drained
//!   horizon), and `esf check` rule ESF-C013 proves the horizon graph
//!   the relaxation runs on mirrors the physical cut set.
//! * Handler side effects stay inside the domain: components, owned link
//!   directions, per-node counters. Half-duplex links (shared medium) and
//!   zero-latency links are never cut, by construction of the partition.
//! * The domain weighting only moves nodes between domains; every
//!   weighting yields the same per-node event streams, so the model is
//!   free to chase balance without touching output (pinned in
//!   `tests/partition.rs`).
//!
//! Warm-up runs sequentially: the epoch flip (`warmup_done`) is a global
//! zero-latency effect that no conservative lookahead covers, so the
//! engine executes the exact sequential prefix until collection starts,
//! then splits. The split point is identical in both engines, so this
//! costs determinism nothing (and Amdahl very little — warm-up is a small
//! request fraction).
//!
//! The protocol was additionally validated against a Python model of this
//! exact design (sequential vs fixed-window vs adaptive on randomized
//! fabrics with zero-latency links, multi-hop relays, and zero-delay
//! self events — per-node event orders byte-identical across all three,
//! delivery-behind-horizon never observed, message accounting exact).

use super::{snapshot, Component, Engine, Ev, EventQueue, IntraStats, Shared};
use crate::engine::time::Ps;
use crate::interconnect::{Dir, Partition, WeightModel};
use crate::util::snap::{SnapReader, SnapWriter};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::Arc;

/// Which barrier protocol [`run_partitioned`] drives (see module docs).
/// Every mode is byte-identical to [`Engine::reference_sequential`];
/// only wall-clock, window count and exchange volume move.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BarrierMode {
    /// One lookahead per window, one message per channel per window —
    /// the PR 4/5 lockstep protocol, kept as the A/B oracle.
    FixedWindow,
    /// Horizon-driven window widening + quiet-token elision.
    #[default]
    Adaptive,
    /// Adaptive plus bounded optimistic execution past the certified
    /// horizon, with deterministic rollback on straggler arrivals. Wins
    /// on quiet cuts, loses on traffic-heavy ones — Adaptive stays the
    /// default.
    Speculative,
}

impl BarrierMode {
    /// CLI spelling (`esf run/sweep --barrier <name>`).
    pub fn parse(s: &str) -> Option<BarrierMode> {
        match s {
            "adaptive" => Some(BarrierMode::Adaptive),
            "fixed" | "fixed-window" => Some(BarrierMode::FixedWindow),
            "speculative" => Some(BarrierMode::Speculative),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BarrierMode::FixedWindow => "fixed",
            BarrierMode::Adaptive => "adaptive",
            BarrierMode::Speculative => "speculative",
        }
    }
}

/// How far past its certified horizon a speculative domain may execute.
/// A pure function of the partition's lookahead so the speculative
/// frontier — and with it every rollback decision — is deterministic.
/// Saturating: a disconnected fabric's `Ps::MAX` lookahead must clamp,
/// not wrap (`esf check` rule ESF-C015 proves both side-conditions on
/// the concrete partition before a run).
pub fn speculation_window(lookahead: Ps) -> Ps {
    lookahead.saturating_mul(4)
}

/// Coordinator -> worker command.
enum Cmd {
    /// Fixed-window round: drain events strictly before the window end,
    /// send one `Msg` per neighbor channel, receive one from each.
    Window(Ps),
    /// Adaptive round: first receive the pending batch on every peer
    /// slot flagged in `recv`, then drain strictly before `end`, then
    /// send only the non-empty outbound batches.
    Adaptive { end: Ps, recv: Vec<bool> },
    /// Speculative round: the adaptive round, then — after the report,
    /// off the critical path — capture a rollback checkpoint and execute
    /// local events up to `end + speculation_window`, holding their
    /// outbound. The received batches decide the previous stint's fate
    /// first: adopt, or restore the checkpoint and re-execute.
    Spec { end: Ps, recv: Vec<bool> },
    Stop,
}

/// One window's worth of cross-domain events for one cut-neighbor. The
/// fixed-window protocol sends exactly one `Msg` per directed neighbor
/// channel per window (`Quiet` when there is no traffic); the adaptive
/// protocol only ever sends `Events` and elides the rest.
enum Msg {
    Quiet,
    Events(Vec<Ev>),
}

/// Worker -> coordinator report: sent once at startup and after every
/// round the worker takes part in. `sent[slot]` carries the minimum
/// event time of the batch just pushed onto that peer channel (`None` =
/// nothing sent; always empty in fixed-window mode) so the coordinator
/// can account for in-flight events when it seeds the next horizon
/// relaxation.
struct Report {
    dom: usize,
    next: Option<Ps>,
    sent: Vec<Option<Ps>>,
}

type MsgTx = SyncSender<Msg>;
type MsgRx = Receiver<Msg>;
/// Full-length component table; only the owning domain's nodes are `Some`.
type CompTable = Vec<Option<Box<dyn Component>>>;

/// One event domain's runtime state, moved onto its worker thread.
struct DomainRunner {
    dom: usize,
    shared: Shared,
    comps: CompTable,
    domain_of: Arc<Vec<u32>>,
    processed: u64,
    /// Highest window end this domain has drained past. Deliveries are
    /// asserted against it: the conservative safety condition is
    /// precisely "no delivered event is behind the receiver's drained
    /// horizon".
    drained_to: Ps,
    /// Exchange accounting (summed into [`IntraStats`] at the merge).
    msgs_sent: u64,
    quiet_sent: u64,
    events_sent: u64,
    /// Speculative-mode state; `None` in the conservative modes.
    spec: Option<SpecState>,
}

/// Per-domain speculation state: the in-memory rollback checkpoint plus
/// the pending stint's bookkeeping. Buffers are reused across captures
/// (`SnapWriter::reuse`) so steady-state speculation allocates nothing.
struct SpecState {
    /// How far past the certified horizon a stint may run.
    window: Ps,
    /// Serialized shard state at `ckpt_at` — the rollback target. Every
    /// speculatively executed event has time >= `ckpt_at`, so the
    /// capture point dominates the whole stint (ESF-C015).
    ckpt: Vec<u8>,
    /// Certified frontier the checkpoint captures. Captures happen only
    /// when the frontier advanced past this, so committed state is
    /// never recaptured.
    ckpt_at: Ps,
    /// Exclusive end of the pending stint; `== ckpt_at` when no stint
    /// is pending.
    spec_to: Ps,
    /// Events executed by the pending stint (already counted into
    /// `processed`; subtracted again on rollback).
    spec_processed: u64,
    /// Outbound events born during the pending stint, held until the
    /// stint is adopted (dropped on rollback — nothing speculative ever
    /// crosses a channel).
    held: Vec<Ev>,
    /// Certified state changed since the last capture for a reason other
    /// than a frontier advance (a delivery was pushed), so the next
    /// stint must recapture even at an unchanged frontier.
    dirty: bool,
    /// Reusable staging for the queue's events during a capture.
    ev_scratch: Vec<Ev>,
    // Stats, summed into IntraStats at the merge.
    speculative_windows: u64,
    rollbacks: u64,
    wasted_events: u64,
}

impl SpecState {
    fn new(window: Ps) -> SpecState {
        SpecState {
            window,
            ckpt: Vec::new(),
            ckpt_at: 0,
            spec_to: 0,
            spec_processed: 0,
            held: Vec::new(),
            dirty: false,
            ev_scratch: Vec::new(),
            speculative_windows: 0,
            rollbacks: 0,
            wasted_events: 0,
        }
    }

    fn pending(&self) -> bool {
        self.spec_to > self.ckpt_at
    }
}

impl DomainRunner {
    /// Drain every local event strictly before `end` in canonical key
    /// order. Handlers may schedule further local events inside the
    /// window (zero-delay self events included) — the loop picks them up.
    fn drain_window(&mut self, end: Ps) {
        while let Some(ev) = self.shared.queue.pop_if_before(end) {
            debug_assert!(ev.time >= self.shared.now, "time went backwards");
            self.shared.now = ev.time;
            self.shared.cur = ev.target;
            self.comps[ev.target]
                .as_mut()
                .expect("event targeted a foreign node")
                .handle(ev.payload, &mut self.shared);
            self.processed += 1;
        }
        self.drained_to = self.drained_to.max(end);
    }

    /// Split the outbound buffer into per-peer-slot batches.
    fn batch_outbound(&mut self, peer_slot: &[Option<usize>], n_slots: usize) -> Vec<Vec<Ev>> {
        let mut batches: Vec<Vec<Ev>> = (0..n_slots).map(|_| Vec::new()).collect();
        for ev in self.shared.take_outbound() {
            // Cross-domain events can only arise from a forward over a
            // cut link, whose far side is a cut-neighbor by construction
            // (Partition::exchange_peers).
            let slot = peer_slot[self.domain_of[ev.target] as usize]
                .expect("cross-domain event targets a non-neighbor domain");
            batches[slot].push(ev);
        }
        batches
    }

    /// Capture the shard's mutable state — clock, owned per-node
    /// counters, event queue with exact `(time, src, seq)` keys, net
    /// shard, owned components — into the reusable checkpoint buffer.
    /// Pure in-memory serialization, no file I/O; steady-state captures
    /// reuse both the byte buffer and the event scratch vector.
    fn spec_capture(&mut self) {
        let DomainRunner {
            dom,
            shared,
            comps,
            domain_of,
            drained_to,
            spec,
            ..
        } = self;
        let spec = spec.as_mut().expect("speculative mode");
        let mut w = SnapWriter::reuse(std::mem::take(&mut spec.ckpt));
        w.u64(shared.now);
        w.usize(shared.cur);
        w.u64(shared.dropped);
        for node in 0..shared.topo.n() {
            if domain_of[node] == *dom as u32 {
                w.u64(shared.sched_seq[node]);
                w.u64(shared.txn_seq[node]);
            }
        }
        w.u64(shared.queue.next_seq);
        let evs = &mut spec.ev_scratch;
        while let Some(ev) = shared.queue.pop() {
            evs.push(ev);
        }
        w.usize(evs.len());
        for ev in evs.iter() {
            snapshot::write_ev(&mut w, ev);
        }
        for ev in evs.drain(..) {
            shared.queue.push(ev);
        }
        shared.net.snapshot(&mut w);
        for c in comps.iter().flatten() {
            c.snapshot(&mut w);
        }
        spec.ckpt = w.into_bytes();
        spec.ckpt_at = *drained_to;
        spec.spec_to = *drained_to;
        spec.dirty = false;
    }

    /// Undo the pending speculative stint: restore the checkpoint
    /// captured at the certified frontier, drop the held outbound, and
    /// back the accounting out. The caller re-executes deterministically
    /// by draining the (restored) window as usual.
    fn spec_rollback(&mut self) {
        let DomainRunner {
            dom,
            shared,
            comps,
            domain_of,
            processed,
            spec,
            ..
        } = self;
        let spec = spec.as_mut().expect("speculative mode");
        let mut r = SnapReader::new(&spec.ckpt);
        let restored: Result<(), String> = (|| {
            shared.now = r.u64()?;
            shared.cur = r.usize()?;
            shared.dropped = r.u64()?;
            for node in 0..shared.topo.n() {
                if domain_of[node] == *dom as u32 {
                    shared.sched_seq[node] = r.u64()?;
                    shared.txn_seq[node] = r.u64()?;
                }
            }
            shared.queue.next_seq = r.u64()?;
            while shared.queue.pop().is_some() {}
            let n_ev = r.usize()?;
            for _ in 0..n_ev {
                shared.queue.push(snapshot::read_ev(&mut r)?);
            }
            shared.net.restore(&mut r)?;
            for c in comps.iter_mut().flatten() {
                c.restore(&mut r)?;
            }
            r.expect_eof()
        })();
        restored.expect("in-memory rollback checkpoint decodes");
        if let Some(p) = shared.part.as_mut() {
            p.outbound.clear();
        }
        *processed -= spec.spec_processed;
        spec.wasted_events += spec.spec_processed;
        spec.rollbacks += 1;
        spec.spec_processed = 0;
        spec.spec_to = spec.ckpt_at;
        spec.held.clear();
    }

    /// Execute local events past the certified horizon `end`, up to
    /// `end + speculation_window`, holding their outbound. Called after
    /// the round's report, so this runs while the coordinator and the
    /// other domains are still working — off the critical path. The
    /// checkpoint is (re)captured first iff the certified state moved
    /// since the last capture, so committed state is never recaptured.
    fn speculate(&mut self, end: Ps) {
        let spec = self.spec.as_ref().expect("speculative mode");
        let spec_end = end.saturating_add(spec.window);
        if !self.shared.queue.next_time().is_some_and(|t| t < spec_end) {
            return; // nothing to run ahead on — no capture, no stint
        }
        if self.drained_to > spec.ckpt_at || spec.dirty || spec.ckpt.is_empty() {
            self.spec_capture();
        }
        let mut consumed = 0u64;
        while let Some(ev) = self.shared.queue.pop_if_before(spec_end) {
            debug_assert!(ev.time >= self.shared.now, "time went backwards");
            self.shared.now = ev.time;
            self.shared.cur = ev.target;
            self.comps[ev.target]
                .as_mut()
                .expect("event targeted a foreign node")
                .handle(ev.payload, &mut self.shared);
            consumed += 1;
        }
        self.processed += consumed;
        let held = self.shared.take_outbound();
        let spec = self.spec.as_mut().expect("speculative mode");
        debug_assert!(consumed > 0, "stint guard saw a nearer event");
        spec.spec_to = spec_end;
        spec.spec_processed = consumed;
        spec.held = held;
        spec.speculative_windows += 1;
    }
}

/// Worker thread body. Fixed-window rounds: drain, send one `Msg` to
/// every cut-neighbor, receive one from every cut-neighbor, report the
/// next local event time. Adaptive rounds: receive the flagged pending
/// batches, drain, send only non-empty batches, report next time plus
/// per-slot batch minima. Both exchanges are deadlock-free: a worker
/// sends all its messages before anyone needs to receive them, and each
/// neighbor channel carries at most one undelivered message per round
/// (capacity 2 keeps sends non-blocking even when a new batch lands
/// while the previous one is still being collected). `peer_slot` maps a
/// domain id to its slot in the parallel `out_tx` / `in_rx` vectors
/// (ascending peer-domain order).
fn worker_loop(
    mut r: DomainRunner,
    peer_slot: Vec<Option<usize>>,
    cmd_rx: Receiver<Cmd>,
    out_tx: Vec<MsgTx>,
    in_rx: Vec<MsgRx>,
    report_tx: Sender<Report>,
) -> DomainRunner {
    let report = |r: &mut DomainRunner, sent: Vec<Option<Ps>>| {
        report_tx
            .send(Report {
                dom: r.dom,
                next: r.shared.queue.next_time(),
                sent,
            })
            .expect("coordinator alive");
    };
    report(&mut r, Vec::new());
    loop {
        match cmd_rx.recv().expect("coordinator alive") {
            Cmd::Stop => {
                // The coordinator only stops once every certified queue
                // drained and nothing is in flight — and a stint only
                // starts when the certified queue is non-empty at report
                // time, which keeps the domain's seed alive. So no stint
                // can be pending here; a violation would merge
                // speculative (unvalidated) state into the engine.
                if let Some(spec) = &r.spec {
                    assert!(!spec.pending(), "stopped with an unresolved speculative stint");
                }
                break;
            }
            Cmd::Window(end) => {
                r.drain_window(end);
                let batches = r.batch_outbound(&peer_slot, out_tx.len());
                for (slot, batch) in batches.into_iter().enumerate() {
                    r.msgs_sent += 1;
                    let msg = if batch.is_empty() {
                        r.quiet_sent += 1;
                        Msg::Quiet
                    } else {
                        r.events_sent += batch.len() as u64;
                        Msg::Events(batch)
                    };
                    out_tx[slot].send(msg).expect("peer alive");
                }
                for rx in &in_rx {
                    if let Msg::Events(evs) = rx.recv().expect("peer alive") {
                        for ev in evs {
                            r.shared.queue.push(ev);
                        }
                    }
                }
                report(&mut r, Vec::new());
            }
            Cmd::Adaptive { end, recv } => {
                for (slot, rx) in in_rx.iter().enumerate() {
                    if !recv[slot] {
                        continue;
                    }
                    match rx.recv().expect("peer alive") {
                        Msg::Events(evs) => {
                            for ev in evs {
                                // The elision-safety property: quiet-run
                                // elision (and window widening) must
                                // never have advanced this domain past a
                                // neighbor's published horizon. Always
                                // on: a violated bound here would
                                // otherwise surface as silent event
                                // reordering.
                                assert!(
                                    ev.time >= r.drained_to,
                                    "delivery behind drained horizon: {} < {}",
                                    ev.time,
                                    r.drained_to
                                );
                                r.shared.queue.push(ev);
                            }
                        }
                        Msg::Quiet => unreachable!("adaptive exchange elides quiet tokens"),
                    }
                }
                r.drain_window(end);
                let batches = r.batch_outbound(&peer_slot, out_tx.len());
                let mut sent: Vec<Option<Ps>> = vec![None; out_tx.len()];
                for (slot, batch) in batches.into_iter().enumerate() {
                    if batch.is_empty() {
                        continue;
                    }
                    r.msgs_sent += 1;
                    r.events_sent += batch.len() as u64;
                    sent[slot] = batch.iter().map(|e| e.time).min();
                    out_tx[slot].send(Msg::Events(batch)).expect("peer alive");
                }
                report(&mut r, sent);
            }
            Cmd::Spec { end, recv } => {
                // Phase 1 — collect this round's deliveries WITHOUT
                // pushing them: the rollback decision must come first,
                // because the checkpoint predates these events and a
                // restore would lose any already-pushed delivery.
                let mut pending: Vec<Ev> = Vec::new();
                for (slot, rx) in in_rx.iter().enumerate() {
                    if !recv[slot] {
                        continue;
                    }
                    match rx.recv().expect("peer alive") {
                        Msg::Events(evs) => pending.extend(evs),
                        Msg::Quiet => unreachable!("speculative exchange elides quiet tokens"),
                    }
                }
                let spec = r.spec.as_ref().expect("speculative worker without SpecState");
                let mut straggler = false;
                for ev in &pending {
                    // Same always-on elision-safety bound as Adaptive.
                    assert!(
                        ev.time >= r.drained_to,
                        "delivery behind drained horizon: {} < {}",
                        ev.time,
                        r.drained_to
                    );
                    // A straggler is a delivery that lands inside the
                    // pending stint's optimistically-executed range.
                    if ev.time < spec.spec_to {
                        straggler = true;
                    }
                }
                // Phase 2 — resolve the pending stint. Adopt only when
                // the certified window covers the whole stint and no
                // straggler landed inside it; otherwise restore the
                // checkpoint and re-execute deterministically below.
                let mut adopted: Vec<Ev> = Vec::new();
                if spec.pending() {
                    if straggler || end < spec.spec_to {
                        r.spec_rollback();
                    } else {
                        let spec = r.spec.as_mut().expect("speculative mode");
                        adopted = std::mem::take(&mut spec.held);
                        spec.spec_processed = 0;
                        spec.spec_to = spec.ckpt_at; // stint retired
                    }
                }
                // Phase 3 — deliveries enter the queue. They postdate
                // the checkpoint, so the next stint must recapture.
                if !pending.is_empty() {
                    r.spec.as_mut().expect("speculative mode").dirty = true;
                    for ev in pending {
                        r.shared.queue.push(ev);
                    }
                }
                // Phase 4 — the certified drain + exchange, exactly the
                // adaptive round. After an adopted stint the queue holds
                // nothing before `spec_to`, so this continues from the
                // stint's frontier; after a rollback it replays the
                // stint's range in canonical order.
                r.drain_window(end);
                let mut batches = r.batch_outbound(&peer_slot, out_tx.len());
                for ev in adopted {
                    let slot = peer_slot[r.domain_of[ev.target] as usize]
                        .expect("cross-domain event targets a non-neighbor domain");
                    batches[slot].push(ev);
                }
                let mut sent: Vec<Option<Ps>> = vec![None; out_tx.len()];
                for (slot, batch) in batches.into_iter().enumerate() {
                    if batch.is_empty() {
                        continue;
                    }
                    r.msgs_sent += 1;
                    r.events_sent += batch.len() as u64;
                    sent[slot] = batch.iter().map(|e| e.time).min();
                    out_tx[slot].send(Msg::Events(batch)).expect("peer alive");
                }
                // Phase 5 — report the certified state. This unblocks
                // the coordinator before the stint starts, which is what
                // keeps speculation off the critical path.
                report(&mut r, sent);
                // Phase 6 — run ahead while everyone else is busy.
                r.speculate(end);
            }
        }
    }
    r
}

/// Entry point behind [`Engine::run_partitioned`]. Runs the engine to
/// completion on up to `intra_jobs` worker threads (0 = all cores) and
/// returns the number of events processed. Falls back to the sequential
/// loop when the fabric cannot be cut or one job is requested.
pub fn run_partitioned(
    engine: &mut Engine,
    intra_jobs: usize,
    model: WeightModel,
    mode: BarrierMode,
) -> u64 {
    let jobs = if intra_jobs == 0 {
        std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
    } else {
        intra_jobs
    };
    if jobs <= 1 {
        return engine.run(u64::MAX);
    }
    let part =
        Partition::compute_weighted(&engine.shared.topo, &engine.shared.routing, jobs, model);
    if part.n_domains() <= 1 {
        return engine.run(u64::MAX);
    }
    // A quiescent-restored engine (Engine::restore of a snapshot taken by
    // run_until_collecting) re-enters here exactly at the Phase A
    // boundary: collecting is already true with the epoch open, so the
    // prefix loop below no-ops and the split proceeds as if the prefix
    // had just been executed in-process. Mid-run checkpoints are NOT
    // barrier-quiescent and must continue sequentially via run().
    if engine.started {
        assert!(
            engine.restored_quiescent,
            "run_partitioned on a started engine requires a quiescent \
             (warm-up boundary) snapshot restore; mid-run checkpoints \
             resume with the sequential engine"
        );
    } else {
        // ---- Phase A: exact sequential prefix until the epoch opens.
        engine.start_components();
    }
    let mut prefix = 0u64;
    while !engine.shared.collecting {
        let Some(ev) = engine.shared.queue.pop() else { break };
        debug_assert!(ev.time >= engine.shared.now, "time went backwards");
        engine.shared.now = ev.time;
        engine.shared.cur = ev.target;
        engine.components[ev.target].handle(ev.payload, &mut engine.shared);
        prefix += 1;
    }
    let n_nodes = engine.shared.topo.n();
    engine.shared.set_origin(n_nodes);
    if engine.shared.queue.is_empty() {
        // Drained before (or exactly when) collection started.
        let now = engine.shared.now;
        engine.shared.net.end_epoch(now);
        engine.events_processed += prefix;
        return prefix;
    }

    // ---- Split: per-domain queues, components, and Shared shards.
    let ndom = part.n_domains();
    let domain_of: Arc<Vec<u32>> = Arc::new(part.domain_of.clone());
    let mut queues: Vec<EventQueue> = (0..ndom).map(|_| EventQueue::default()).collect();
    while let Some(ev) = engine.shared.queue.pop() {
        queues[domain_of[ev.target] as usize].push(ev);
    }
    let mut comp_split: Vec<CompTable> =
        (0..ndom).map(|_| (0..n_nodes).map(|_| None).collect()).collect();
    for (id, c) in engine.components.drain(..).enumerate() {
        comp_split[domain_of[id] as usize][id] = Some(c);
    }
    let mut runners: Vec<DomainRunner> = Vec::with_capacity(ndom);
    for (dom, (queue, comps)) in queues.into_iter().zip(comp_split).enumerate() {
        runners.push(DomainRunner {
            dom,
            shared: engine
                .shared
                .domain_shard(queue, dom as u32, Arc::clone(&domain_of)),
            comps,
            domain_of: Arc::clone(&domain_of),
            processed: 0,
            drained_to: 0,
            msgs_sent: 0,
            quiet_sent: 0,
            events_sent: 0,
            spec: (mode == BarrierMode::Speculative)
                .then(|| SpecState::new(speculation_window(part.lookahead))),
        });
    }

    // ---- Channels: sparse neighbor wiring from the cut set, plus the
    // command/report star. Only cut-adjacent domain pairs get a channel
    // pair; a fully disconnected multi-domain fabric gets none at all.
    let peers = part.exchange_peers(&engine.shared.topo);
    // Per-domain (peer, min cut latency) edges for the adaptive horizon
    // relaxation — same order as `peers` (ESF-C013 proves the mirror).
    let hg = part.horizon_graph(&engine.shared.topo);
    debug_assert!(
        peers
            .iter()
            .zip(&hg)
            .all(|(ps, es)| ps.iter().copied().eq(es.iter().map(|&(p, _)| p))),
        "horizon graph must mirror the exchange peer lists"
    );
    let channels: usize = peers.iter().map(Vec::len).sum();
    let mut peer_slots: Vec<Vec<Option<usize>>> = (0..ndom).map(|_| vec![None; ndom]).collect();
    for (d, ps) in peers.iter().enumerate() {
        for (slot, &p) in ps.iter().enumerate() {
            peer_slots[d][p] = Some(slot);
        }
    }
    let mut out_tx: Vec<Vec<Option<MsgTx>>> =
        peers.iter().map(|ps| ps.iter().map(|_| None).collect()).collect();
    let mut in_rx: Vec<Vec<Option<MsgRx>>> =
        peers.iter().map(|ps| ps.iter().map(|_| None).collect()).collect();
    for (i, ps) in peers.iter().enumerate() {
        for (si, &j) in ps.iter().enumerate() {
            if j > i {
                let sj = peer_slots[j][i].expect("peer relation is symmetric");
                // Capacity 2 > the single undelivered message per round.
                let (tij, rij) = sync_channel(2);
                let (tji, rji) = sync_channel(2);
                out_tx[i][si] = Some(tij);
                in_rx[j][sj] = Some(rij);
                out_tx[j][sj] = Some(tji);
                in_rx[i][si] = Some(rji);
            }
        }
    }
    let (report_tx, report_rx) = channel::<Report>();
    let mut cmd_txs: Vec<SyncSender<Cmd>> = Vec::with_capacity(ndom);
    let mut cmd_rxs: Vec<Receiver<Cmd>> = Vec::with_capacity(ndom);
    for _ in 0..ndom {
        let (tx, rx) = sync_channel(1);
        cmd_txs.push(tx);
        cmd_rxs.push(rx);
    }

    // ---- Run: workers in barrier rounds, coordinator on this thread.
    let lookahead = part.lookahead;
    let mut windows = 0u64;
    let mut widened_windows = 0u64;
    // Satellite of the conservation identity: count elisions as they
    // happen (coordinator-side) instead of deriving them by subtraction,
    // so a protocol miscount trips the assert below rather than wrapping.
    let mut elided_tokens = 0u64;
    // Deterministic GVT analogue: the global minimum of the per-domain
    // seeds. Everything before it is committed — no rollback can reach
    // behind it, so speculative mode never recaptures committed state.
    let mut last_commit: Option<Ps> = None;
    let mut committed_frontier_advances = 0u64;
    let runners: Vec<DomainRunner> = std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(ndom);
        let mut worker_slots = peer_slots;
        let mut out_tx = out_tx;
        let mut in_rx = in_rx;
        let mut cmd_rxs = cmd_rxs;
        for r in runners.into_iter().rev() {
            let slots = worker_slots.pop().expect("slot row per domain");
            let txs: Vec<MsgTx> = out_tx
                .pop()
                .expect("tx row per domain")
                .into_iter()
                .map(|t| t.expect("every peer slot wired"))
                .collect();
            let rxs: Vec<MsgRx> = in_rx
                .pop()
                .expect("rx row per domain")
                .into_iter()
                .map(|t| t.expect("every peer slot wired"))
                .collect();
            let cmd = cmd_rxs.pop().expect("cmd channel per domain");
            let rep = report_tx.clone();
            handles.push(s.spawn(move || worker_loop(r, slots, cmd, txs, rxs, rep)));
        }
        handles.reverse(); // spawned in reverse domain order

        // Coordinator state: last reported next-event time per domain,
        // and (adaptive) the minimum event time of the batch in flight
        // on each inbound peer slot.
        let mut next: Vec<Option<Ps>> = vec![None; ndom];
        let mut inflight: Vec<Vec<Option<Ps>>> =
            peers.iter().map(|ps| vec![None; ps.len()]).collect();
        for _ in 0..ndom {
            let rep = report_rx.recv().expect("worker alive");
            next[rep.dom] = rep.next;
        }
        loop {
            // Earliest possible activity per domain: local queue or an
            // undelivered inbound batch.
            let seeds: Vec<Option<Ps>> = (0..ndom)
                .map(|d| {
                    inflight[d]
                        .iter()
                        .flatten()
                        .fold(next[d], |acc, &m| Some(acc.map_or(m, |a| a.min(m))))
                })
                .collect();
            let Some(tmin) = seeds.iter().flatten().copied().min() else {
                // All domains idle, nothing in flight: done.
                for tx in &cmd_txs {
                    tx.send(Cmd::Stop).expect("worker alive");
                }
                break;
            };
            windows += 1;
            if mode == BarrierMode::Speculative && last_commit.map_or(true, |c| tmin > c) {
                committed_frontier_advances += 1;
                last_commit = Some(tmin);
            }
            match mode {
                BarrierMode::FixedWindow => {
                    // Saturating: a disconnected multi-domain fabric has
                    // no cut links and an unbounded Ps::MAX lookahead —
                    // the window must clamp, not wrap.
                    let end = tmin.saturating_add(lookahead);
                    for tx in &cmd_txs {
                        tx.send(Cmd::Window(end)).expect("worker alive");
                    }
                    for _ in 0..ndom {
                        let rep = report_rx.recv().expect("worker alive");
                        next[rep.dom] = rep.next;
                    }
                }
                BarrierMode::Adaptive | BarrierMode::Speculative => {
                    // Speculative shares the whole certified protocol
                    // with Adaptive — same seeds, horizons, batches and
                    // reports — and only changes what a worker does with
                    // its idle time after reporting. That is what makes
                    // its coordinator trace (windows, widened windows,
                    // messages, elisions) provably identical to
                    // Adaptive's.
                    // Min-plus relaxation of the seeds over the horizon
                    // graph: dist[d] = earliest time d could process any
                    // event this round, including relayed ones. Positive
                    // edge weights (cut links are never zero-latency)
                    // make this a Bellman-Ford fixpoint in <= ndom
                    // passes.
                    let mut dist = seeds.clone();
                    for _ in 0..ndom {
                        let mut changed = false;
                        for d in 0..ndom {
                            for &(p, lat) in &hg[d] {
                                if let Some(dp) = dist[p] {
                                    let v = dp.saturating_add(lat);
                                    if dist[d].map_or(true, |cur| v < cur) {
                                        dist[d] = Some(v);
                                        changed = true;
                                    }
                                }
                            }
                        }
                        if !changed {
                            break;
                        }
                    }
                    // Certified inbound horizon = granted window end.
                    let classic = tmin.saturating_add(lookahead);
                    let mut widened = false;
                    let mut participants = 0usize;
                    for d in 0..ndom {
                        let horizon = hg[d]
                            .iter()
                            .filter_map(|&(p, lat)| dist[p].map(|dp| dp.saturating_add(lat)))
                            .min()
                            .unwrap_or(Ps::MAX);
                        let active = seeds[d].is_some_and(|sd| sd < horizon);
                        let pending = inflight[d].iter().any(Option::is_some);
                        if !active && !pending {
                            continue; // parked: horizon already published
                        }
                        participants += 1;
                        if active && horizon > classic {
                            widened = true;
                        }
                        let recv: Vec<bool> =
                            inflight[d].iter().map(Option::is_some).collect();
                        for slot in inflight[d].iter_mut() {
                            *slot = None;
                        }
                        let cmd = if mode == BarrierMode::Speculative {
                            Cmd::Spec { end: horizon, recv }
                        } else {
                            Cmd::Adaptive { end: horizon, recv }
                        };
                        cmd_txs[d].send(cmd).expect("worker alive");
                    }
                    if widened {
                        widened_windows += 1;
                    }
                    assert!(participants > 0, "adaptive barrier made no progress");
                    let mut round_sent = 0u64;
                    for _ in 0..participants {
                        let rep = report_rx.recv().expect("worker alive");
                        next[rep.dom] = rep.next;
                        for (slot, &m) in rep.sent.iter().enumerate() {
                            let Some(m) = m else { continue };
                            round_sent += 1;
                            let p = peers[rep.dom][slot];
                            let back = peers[p]
                                .binary_search(&rep.dom)
                                .expect("peer relation is symmetric");
                            debug_assert!(
                                inflight[p][back].is_none(),
                                "neighbor channel overrun"
                            );
                            inflight[p][back] = Some(m);
                        }
                    }
                    // Every channel-round either carried a batch or was
                    // elided — parked domains' slots included.
                    elided_tokens += channels as u64 - round_sent;
                }
            }
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("worker thread panicked"))
            .collect()
    });

    // ---- Merge: components back in node order, owned link directions,
    // per-node counters, drop counts, exchange stats, global clock.
    let dir_owner: Vec<[u32; 2]> = engine
        .shared
        .topo
        .links
        .iter()
        .map(|l| [domain_of[l.a], domain_of[l.b]])
        .collect();
    let mut comps_back: CompTable = (0..n_nodes).map(|_| None).collect();
    let mut total = 0u64;
    let mut max_now = engine.shared.now;
    let mut stats = IntraStats {
        domains: ndom,
        windows,
        widened_windows,
        channels,
        ..IntraStats::default()
    };
    stats.elided_tokens = elided_tokens;
    stats.committed_frontier_advances = committed_frontier_advances;
    for mut r in runners {
        total += r.processed;
        max_now = max_now.max(r.shared.now);
        engine.shared.dropped += r.shared.dropped;
        stats.messages += r.msgs_sent;
        stats.quiet_messages += r.quiet_sent;
        stats.events_exchanged += r.events_sent;
        if let Some(spec) = &r.spec {
            stats.speculative_windows += spec.speculative_windows;
            stats.rollbacks += spec.rollbacks;
            stats.wasted_events += spec.wasted_events;
        }
        let dom = r.dom as u32;
        debug_assert_eq!(Dir::AtoB as usize, 0);
        engine
            .shared
            .net
            .adopt_owned(&r.shared.net, |link, dir| dir_owner[link][dir as usize] == dom);
        for &node in &part.domains[r.dom] {
            engine.shared.sched_seq[node] = r.shared.sched_seq[node];
            engine.shared.txn_seq[node] = r.shared.txn_seq[node];
            comps_back[node] = r.comps[node].take();
        }
    }
    // Conservation identity: every window offers every channel exactly
    // one send opportunity; each was either used (a message) or elided.
    // Elisions are counted coordinator-side as they happen, so a
    // protocol miscount trips here instead of silently wrapping a
    // post-hoc subtraction.
    assert_eq!(
        stats.messages + stats.elided_tokens,
        windows * channels as u64,
        "exchange conservation identity violated"
    );
    engine.components = comps_back
        .into_iter()
        .map(|c| c.expect("every component returns from its domain"))
        .collect();
    engine.shared.now = max_now;
    engine.shared.net.end_epoch(max_now);
    engine.events_processed += prefix + total;
    engine.intra_stats = Some(stats);
    prefix + total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{Payload, Shared};
    use crate::interconnect::{LinkCfg, NodeKind, Routing, Strategy, Topology};
    use crate::proto::{NodeId, Opcode, Packet};
    use std::any::Any;

    /// Ping-pong component: every node fires requests at a deterministic
    /// subset of peers and bounces responses, recording each handled
    /// event's (time, src-key) so the processing ORDER itself can be
    /// compared between engines — stricter than comparing aggregates.
    struct Chatter {
        id: NodeId,
        n: usize,
        rounds: u64,
        log: Vec<(Ps, u64)>,
    }

    impl Component for Chatter {
        fn start(&mut self, ctx: &mut Shared) {
            ctx.after((self.id as u64 % 3) * 100, self.id, Payload::Timer(0, 0));
        }
        fn handle(&mut self, payload: Payload, ctx: &mut Shared) {
            match payload {
                Payload::Timer(round, _) => {
                    self.log.push((ctx.now, round));
                    if round >= self.rounds {
                        return;
                    }
                    let dst = (self.id + 1 + (round as usize % (self.n - 1))) % self.n;
                    let id = ctx.txn_id();
                    let mut pkt =
                        Packet::request(id, Opcode::MemRd, self.id, dst, round, ctx.now);
                    pkt.payload_bytes = 64;
                    ctx.forward(pkt, 0);
                    // Zero-delay self event: stresses same-window re-pops.
                    ctx.after(0, self.id, Payload::Timer(round + 1, 1));
                }
                Payload::Packet(pkt) => {
                    self.log.push((ctx.now, 1_000_000 + pkt.addr));
                    if matches!(pkt.op, Opcode::MemRd) && pkt.addr % 2 == 0 {
                        let rsp = pkt.response(false);
                        ctx.forward(rsp, 50);
                    }
                }
                _ => {}
            }
        }
        // Faithful state capture: speculative-mode rollback restores
        // components through this pair, so the mutable `log` must round-
        // trip exactly or rolled-back entries would survive.
        fn snapshot(&self, w: &mut SnapWriter) {
            w.usize(self.log.len());
            for &(t, k) in &self.log {
                w.u64(t);
                w.u64(k);
            }
        }
        fn restore(&mut self, r: &mut SnapReader<'_>) -> Result<(), String> {
            let n = r.usize()?;
            self.log.clear();
            for _ in 0..n {
                let t = r.u64()?;
                let k = r.u64()?;
                self.log.push((t, k));
            }
            Ok(())
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    /// Ring of directly linked nodes — every node pair routable, cuts
    /// guaranteed for >= 2 domains.
    fn chatter_engine(n: usize, rounds: u64) -> Engine {
        let mut t = Topology::new();
        for i in 0..n {
            t.add_node(format!("n{i}"), NodeKind::Switch);
        }
        for i in 0..n {
            t.add_link(i, (i + 1) % n, LinkCfg::default());
        }
        let routing = Routing::build_bfs(&t);
        let mut e = Engine::new(Shared::new(t, routing, Strategy::Oblivious));
        for i in 0..n {
            e.register(Box::new(Chatter {
                id: i,
                n,
                rounds,
                log: Vec::new(),
            }));
        }
        e
    }

    fn logs(e: &Engine) -> Vec<Vec<(Ps, u64)>> {
        (0..e.shared.topo.n())
            .map(|i| e.component::<Chatter>(i).unwrap().log.clone())
            .collect()
    }

    #[test]
    fn partitioned_matches_sequential_event_orders_exactly() {
        for model in [WeightModel::Traffic, WeightModel::NodeCount] {
            for mode in [
                BarrierMode::Adaptive,
                BarrierMode::FixedWindow,
                BarrierMode::Speculative,
            ] {
                for jobs in [2, 3, 4, 8] {
                    let mut seq = chatter_engine(12, 40);
                    let n_seq = seq.reference_sequential();
                    let mut par = chatter_engine(12, 40);
                    let n_par = par.run_partitioned_opts(jobs, model, mode);
                    assert_eq!(
                        n_seq, n_par,
                        "event counts diverged at jobs={jobs} {model:?} {mode:?}"
                    );
                    assert_eq!(
                        logs(&seq),
                        logs(&par),
                        "per-node event order diverged at jobs={jobs} {model:?} {mode:?}"
                    );
                    assert_eq!(seq.shared.now, par.shared.now);
                    assert_eq!(seq.shared.dropped, par.shared.dropped);
                    for l in 0..seq.shared.topo.links.len() {
                        assert_eq!(
                            seq.shared.net.payload_bytes(l),
                            par.shared.net.payload_bytes(l),
                            "link {l} payload diverged at jobs={jobs}"
                        );
                        assert_eq!(
                            seq.shared.net.bus_utility(l).to_bits(),
                            par.shared.net.bus_utility(l).to_bits(),
                            "link {l} utility diverged at jobs={jobs}"
                        );
                    }
                }
            }
        }
    }

    /// The sparse exchange must open strictly fewer channels than the
    /// all-to-all mesh whenever the cut graph is not complete, and the
    /// accounting must be self-consistent: every channel-round either
    /// carried a message or was elided, quiet tokens a subset of
    /// messages. On a ring cut into 4 arcs every domain has exactly two
    /// cut-neighbors.
    #[test]
    fn sparse_exchange_opens_neighbor_channels_only() {
        let mut e = chatter_engine(12, 40);
        let events = e.run_partitioned(4);
        assert!(events > 0);
        let s = e.intra_stats.expect("partitioned run records stats");
        assert_eq!(s.domains, 4);
        // Ring arcs: 2 neighbors per domain -> 8 directed channels, vs
        // 4 * 3 = 12 all-to-all.
        assert_eq!(s.channels, 8);
        assert!(s.channels < s.domains * (s.domains - 1));
        assert!(s.windows > 0);
        assert_eq!(s.messages + s.elided_tokens, s.windows * s.channels as u64);
        assert_eq!(s.quiet_messages, 0, "adaptive mode elides quiet tokens");
        assert!(s.events_exchanged > 0, "chatter must cross domains");
        // Sequential runs leave no stats behind.
        let mut seq = chatter_engine(12, 40);
        seq.reference_sequential();
        assert!(seq.intra_stats.is_none());
        let mut one = chatter_engine(12, 40);
        one.run_partitioned(1);
        assert!(one.intra_stats.is_none(), "fallback path must not record");
    }

    /// Fixed-window mode keeps the PR 5 accounting exactly (one message
    /// per channel per window); adaptive mode must beat it on both
    /// windows and messages while exchanging the same events.
    #[test]
    fn adaptive_mode_elides_tokens_and_widens_windows() {
        let mut fixed = chatter_engine(12, 40);
        fixed.run_partitioned_opts(4, WeightModel::Traffic, BarrierMode::FixedWindow);
        let f = fixed.intra_stats.expect("stats");
        assert_eq!(f.messages, f.windows * f.channels as u64);
        assert_eq!(f.elided_tokens, 0);
        assert_eq!(f.widened_windows, 0);
        assert!(f.quiet_messages <= f.messages);

        let mut adaptive = chatter_engine(12, 40);
        adaptive.run_partitioned_opts(4, WeightModel::Traffic, BarrierMode::Adaptive);
        let a = adaptive.intra_stats.expect("stats");
        assert_eq!(a.channels, f.channels);
        assert_eq!(a.events_exchanged, f.events_exchanged);
        assert!(a.windows <= f.windows, "adaptive needed more rounds");
        assert!(a.messages < f.messages, "no message reduction");
        assert!(a.widened_windows > 0, "no window ever widened");
        assert!(a.elided_tokens > 0, "no token ever elided");
        assert_eq!(a.messages + a.elided_tokens, a.windows * a.channels as u64);
        assert_eq!(logs(&fixed), logs(&adaptive));
    }

    #[test]
    fn single_job_partitioned_is_the_sequential_path() {
        let mut a = chatter_engine(6, 10);
        let na = a.run(u64::MAX);
        let mut b = chatter_engine(6, 10);
        let nb = b.run_partitioned(1);
        assert_eq!(na, nb);
        assert_eq!(logs(&a), logs(&b));
    }

    #[test]
    fn empty_engine_partitioned_run_terminates() {
        // No components schedule anything after start when rounds == 0
        // budget is still >= 1 event per node (the initial timer).
        let mut e = chatter_engine(4, 0);
        let n = e.run_partitioned(4);
        assert!(n >= 4);
        assert!(e.shared.queue.is_empty());
    }

    /// Two disconnected chatter rings: the partitioner splits them into
    /// domains with an empty cut set (lookahead Ps::MAX, zero channels);
    /// the saturating window must drain everything in one shot and still
    /// match the sequential order exactly.
    #[test]
    fn disconnected_fabric_runs_with_unbounded_windows() {
        let build = || {
            let mut t = Topology::new();
            for i in 0..8 {
                t.add_node(format!("n{i}"), NodeKind::Switch);
            }
            for c in 0..2usize {
                let base = c * 4;
                for i in 0..4 {
                    t.add_link(base + i, base + (i + 1) % 4, LinkCfg::default());
                }
            }
            let routing = Routing::build_bfs(&t);
            let mut e = Engine::new(Shared::new(t, routing, Strategy::Oblivious));
            for i in 0..8 {
                e.register(Box::new(Chatter {
                    id: i,
                    n: 8,
                    rounds: 12,
                    log: Vec::new(),
                }));
            }
            e
        };
        // Chatter picks dst in 0..8, so cross-component packets exist —
        // they are unroutable and dropped, identically in both engines.
        let mut seq = build();
        let n_seq = seq.reference_sequential();
        for mode in [
            BarrierMode::Adaptive,
            BarrierMode::FixedWindow,
            BarrierMode::Speculative,
        ] {
            for jobs in [2, 4] {
                let mut par = build();
                let n_par = par.run_partitioned_opts(jobs, WeightModel::Traffic, mode);
                assert_eq!(
                    n_seq, n_par,
                    "disconnected fabric diverged at jobs={jobs} {mode:?}"
                );
                assert_eq!(logs(&seq), logs(&par));
                assert_eq!(seq.shared.dropped, par.shared.dropped);
                if let Some(s) = par.intra_stats {
                    // Both rings are internally connected, so a 2-domain
                    // cut may have zero channels; assert the accounting
                    // holds either way.
                    assert_eq!(
                        s.messages + s.elided_tokens,
                        s.windows * s.channels as u64
                    );
                }
            }
        }
    }

    /// Speculative mode's certified protocol IS the adaptive protocol:
    /// the coordinator-visible trace (window count, widened windows,
    /// message and elision counts, events exchanged) must be identical,
    /// because speculation only changes what a worker does with its idle
    /// time after reporting. On the chatter ring the cut is busy —
    /// horizons advance by roughly one lookahead per round, far short of
    /// the 4x speculation window — so stints start and then roll back,
    /// exercising the checkpoint/restore path while the output stays
    /// exactly sequential.
    #[test]
    fn speculative_matches_adaptive_trace_and_rolls_back() {
        let mut seq = chatter_engine(12, 40);
        let n_seq = seq.reference_sequential();

        let mut adaptive = chatter_engine(12, 40);
        adaptive.run_partitioned_opts(4, WeightModel::Traffic, BarrierMode::Adaptive);
        let a = adaptive.intra_stats.expect("stats");

        let mut spec = chatter_engine(12, 40);
        let n_spec = spec.run_partitioned_opts(4, WeightModel::Traffic, BarrierMode::Speculative);
        let s = spec.intra_stats.expect("stats");

        // Output identity: same event count, same per-node order, same
        // clock — rollback re-execution is invisible in the result.
        assert_eq!(n_seq, n_spec);
        assert_eq!(logs(&seq), logs(&spec));
        assert_eq!(seq.shared.now, spec.shared.now);

        // Certified-trace identity with Adaptive.
        assert_eq!(s.windows, a.windows);
        assert_eq!(s.widened_windows, a.widened_windows);
        assert_eq!(s.channels, a.channels);
        assert_eq!(s.messages, a.messages);
        assert_eq!(s.elided_tokens, a.elided_tokens);
        assert_eq!(s.events_exchanged, a.events_exchanged);
        assert_eq!(s.messages + s.elided_tokens, s.windows * s.channels as u64);

        // Adaptive never speculates; its new counters stay zero.
        assert_eq!(a.speculative_windows, 0);
        assert_eq!(a.rollbacks, 0);
        assert_eq!(a.wasted_events, 0);
        assert_eq!(a.committed_frontier_advances, 0);

        // The busy cut forces speculation AND rollback; the accounting
        // is self-consistent either way.
        assert!(s.speculative_windows > 0, "no stint ever started");
        assert!(s.rollbacks > 0, "busy cut never forced a rollback");
        assert!(s.rollbacks <= s.speculative_windows);
        assert!(s.wasted_events >= s.rollbacks, "every rollback wastes >= 1 event");
        assert_eq!(s.rollbacks == 0, s.wasted_events == 0);
        assert!(s.committed_frontier_advances > 0);
        assert!(s.committed_frontier_advances <= s.windows);
    }

    /// The speculation window is a fixed multiple of the cut lookahead,
    /// saturating instead of wrapping at the Ps range limit (disconnected
    /// fabrics publish a Ps::MAX lookahead).
    #[test]
    fn speculation_window_scales_and_saturates() {
        assert_eq!(speculation_window(0), 0);
        assert_eq!(speculation_window(1_000), 4_000);
        assert_eq!(speculation_window(Ps::MAX), Ps::MAX);
        assert_eq!(speculation_window(Ps::MAX / 2), Ps::MAX);
        // MAX/4 == 2^62 - 1, which still fits: no saturation.
        assert_eq!(speculation_window(Ps::MAX / 4), Ps::MAX - 3);
    }
}
